"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

Multi-device benches need >1 host device; when launched with a single CPU
device this driver re-execs itself with 8 host devices (opt out with
REPRO_BENCH_NO_REEXEC=1 or --single-device).
"""
import os
import sys


def _ensure_devices():
    if os.environ.get("REPRO_BENCH_NO_REEXEC"):
        return
    if "--single-device" in sys.argv:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
        os.environ["REPRO_BENCH_NO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable, "-m", "benchmarks.run"]
                 + sys.argv[1:])


def main() -> None:
    _ensure_devices()
    from benchmarks import b_eff, lm_roofline, resources, swe_scaling

    print("name,us_per_call,derived")
    modules = [("b_eff(fig4)", b_eff), ("resources(fig3)", resources),
               ("swe(fig9,fig10,table1)", swe_scaling),
               ("lm_roofline", lm_roofline)]
    only = None
    for a in sys.argv[1:]:
        if a.startswith("--only="):
            only = a.split("=", 1)[1]
    for label, mod in modules:
        if only and only not in label:
            continue
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{label}_ERROR,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
