"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the same rows to
``BENCH_comm.json`` (override with --json=PATH, disable with --json=) so the
perf trajectory is machine-trackable across PRs.

Multi-device benches need >1 host device; when launched with a single CPU
device this driver re-execs itself with 8 host devices (opt out with
REPRO_BENCH_NO_REEXEC=1 or --single-device).
"""
import json
import os
import sys


def _ensure_devices():
    if os.environ.get("REPRO_BENCH_NO_REEXEC"):
        return
    if "--single-device" in sys.argv:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
        os.environ["REPRO_BENCH_NO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable, "-m", "benchmarks.run"]
                 + sys.argv[1:])


def main() -> None:
    _ensure_devices()
    from benchmarks import (b_eff, e2e_objective, fault_tolerance,
                            lm_collectives, lm_roofline, plan_store,
                            reliability, resources, serving, swe_scaling,
                            topology_hops)

    print("name,us_per_call,derived")
    modules = [("b_eff(fig4)", b_eff), ("resources(fig3)", resources),
               ("swe(fig9,fig10,table1)", swe_scaling),
               ("lm_roofline", lm_roofline),
               ("lm_collectives", lm_collectives),
               ("e2e_objective", e2e_objective),
               ("topology_hops", topology_hops),
               ("plan_store", plan_store),
               ("fault_tolerance", fault_tolerance),
               ("reliability", reliability),
               ("serving", serving)]
    only = None
    json_path = "BENCH_comm.json"
    for a in sys.argv[1:]:
        if a.startswith("--only="):
            only = a.split("=", 1)[1]
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
    results = {}
    ok_labels = []
    for label, mod in modules:
        if only and only not in label:
            continue
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
                results[name] = {"us_per_call": round(us, 3),
                                 "derived": derived}
            ok_labels.append(label)
        except Exception as e:  # noqa: BLE001
            print(f"{label}_ERROR,0,{type(e).__name__}:{e}")
            results[f"{label}_ERROR"] = {
                "us_per_call": 0.0, "derived": f"{type(e).__name__}:{e}"}
    # Overlap report: the Eq. 2 overlap term's predicted fused->overlapped
    # speedup next to the measured one (rows from swe_scaling.fig11).
    overlap_rows = {k: v for k, v in results.items()
                    if k.startswith("fig11_speedup")}
    for name, row in sorted(overlap_rows.items()):
        print(f"# overlap {name}: measured {row['us_per_call']:.2f}x, "
              f"{row['derived']}", file=sys.stderr)
    # E2E-objective report: how much e2e the bare-latency winner leaves on
    # the table per consumer loop (rows from e2e_objective).
    for name, row in sorted(results.items()):
        if name.startswith("e2e_gain_"):
            print(f"# e2e objective {name}: lat-winner/e2e-winner = "
                  f"{row['us_per_call']:.2f}x, {row['derived']}",
                  file=sys.stderr)
    # Hop-scaling report: measured multi-hop cost next to the Eq. 1
    # prediction (rows from topology_hops on the virtual 2x4 torus).
    for name, row in sorted(results.items()):
        if name.startswith("topo_hop_ratio"):
            print(f"# hop scaling {name}: measured "
                  f"{row['us_per_call']:.2f}x, {row['derived']}",
                  file=sys.stderr)
    # Plan-store report: what disk persistence saves a fresh process
    # (rows from plan_store; smaller ratio = better warm start).
    for name, row in sorted(results.items()):
        if name == "pstore_warm_ratio":
            print(f"# plan store {name}: fresh-process warm/cold = "
                  f"{row['us_per_call']:.2f}x, {row['derived']}",
                  file=sys.stderr)
    # Fault-tolerance report: model-based re-selection vs the resweep the
    # elastic recovery path avoids (rows from fault_tolerance).
    for name, row in sorted(results.items()):
        if name == "ft_reselect_speedup":
            print(f"# fault tolerance {name}: resweep/reselect = "
                  f"{row['us_per_call']:.0f}x, {row['derived']}",
                  file=sys.stderr)
    # Serving report: decode cost under its own winner vs the prefill
    # winner, and whether 48 ranks resolved phase-distinct configs
    # (rows from serving).
    for name, row in sorted(results.items()):
        if name in ("srv_phase_win", "srv_distinct_48"):
            print(f"# serving {name}: {row['us_per_call']:.2f}, "
                  f"{row['derived']}", file=sys.stderr)
    if json_path:
        # Merge into any existing file so a partial (--only=...) run updates
        # its rows without destroying the rest of the benchmark record.
        rows = {}
        if os.path.exists(json_path):
            try:
                with open(json_path) as f:
                    rows = json.load(f).get("rows", {})
            except (json.JSONDecodeError, OSError):
                rows = {}
        rows.update(results)
        for label in ok_labels:   # a clean run clears the module's old error
            rows.pop(f"{label}_ERROR", None)
        with open(json_path, "w") as f:
            json.dump({"schema": "repro-bench-v1", "rows": rows}, f,
                      indent=1, sort_keys=True)
        print(f"# wrote {len(results)} rows ({len(rows)} total) -> {json_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
