"""Virtual-torus hop-scaling rows: measured vs predicted per-edge latency.

The paper's per-edge result in benchmark form: the same sendrecv pattern is
measured at several hop distances on a virtual 2x4 torus (each extra hop is
one physically executed permute — ``repro.core.topology``'s store-and-forward
lowering), next to the hop-aware Eq. 1 prediction:

- ``topo_hops_sendrecv_h<d>_<size>B`` — measured µs/op at hop distance d
  (derived column: the calibrated-model prediction at the same distance);
- ``topo_hop_ratio_sendrecv_<size>B`` — measured t(max_hop)/t(1) ratio
  (non-latency row: a *smaller* ratio means better hop hiding, not a
  regression).

New rows ride this PR report-only (``benchmarks.diff --report-only-prefixes
topo_``) until a second committed baseline lands.
"""
from __future__ import annotations

HOPS = (1, 2, 3)
SIZES = (1 << 16, 1 << 20)


def run():
    import jax
    if jax.device_count() < 8:
        return [("topo_hops", 0.0, "skipped_lt8devices")]
    from repro import compat
    from repro.core import latmodel
    from repro.core.config import OPTIMIZED_CONFIG, V5E
    from repro.core.topology import TorusSpec
    from repro.tune import sweep as tune_sweep
    from repro.tune.space import config_to_dict

    mesh = compat.make_mesh((8,), ("x",))
    spec = TorusSpec((2, 4))
    from repro.core.communicator import Communicator
    comm = Communicator.from_mesh(mesh, "x", topo=spec)
    cfg = OPTIMIZED_CONFIG
    hw = spec.hardware(V5E)
    rows = []
    measured: dict[tuple[int, int], float] = {}
    for size in SIZES:
        for d in HOPS:
            op = tune_sweep._build_op("sendrecv", comm, cfg, hop_distance=d)
            sec = tune_sweep._time_program(
                op, mesh, size, cfg, reps=3, inner=4,
                cache_key=("bench_topo", spec.name, d,
                           tune_sweep._mesh_key(mesh), "sendrecv",
                           tuple(sorted(config_to_dict(cfg).items())), size))
            measured[(size, d)] = sec
            pred = latmodel.pingping_latency(size, cfg, hw, hops=d)
            rows.append((f"topo_hops_sendrecv_h{d}_{size}B", sec * 1e6,
                         f"pred{pred * 1e6:.1f}us"))
        ratio = measured[(size, HOPS[-1])] / max(measured[(size, 1)], 1e-12)
        pred_ratio = (latmodel.pingping_latency(size, cfg, hw, HOPS[-1])
                      / latmodel.pingping_latency(size, cfg, hw, 1))
        rows.append((f"topo_hop_ratio_sendrecv_{size}B", ratio,
                     f"h{HOPS[-1]}/h1_pred{pred_ratio:.2f}x"))
    return rows
