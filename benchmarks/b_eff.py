"""Fig. 4 reproduction: b_eff ping-ping latency/throughput over message size.

Modeled latencies (Eq. 1 with TPU constants) for every communication
approach, plus two measured calibrations on this host:
  - l_k (host dispatch) via scheduler.measure_dispatch_overhead — the 30 µs
    XRT analogue;
  - relative fused-vs-host-scheduled wall time of a real 8-device ring
    exchange (CPU devices; the RATIO is the meaningful number).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import latmodel, scheduler
from repro.core.config import (CommConfig, CommMode, Scheduling, Transport,
                               V5E)

SIZES = [64, 256, 1024, 4096, 16384, 65536, 262144, 1048576, 4194304]

CONFIGS = {
    "buffered_host": CommConfig(mode=CommMode.BUFFERED,
                                scheduling=Scheduling.HOST),
    "buffered_pl": CommConfig(mode=CommMode.BUFFERED,
                              scheduling=Scheduling.FUSED),
    "streaming_host": CommConfig(mode=CommMode.STREAMING,
                                 scheduling=Scheduling.HOST),
    "streaming_pl": CommConfig(mode=CommMode.STREAMING,
                               scheduling=Scheduling.FUSED),
}


def modeled_rows():
    rows = []
    for name, cfg in CONFIGS.items():
        for hops, suffix in ((1, ""), (3, "_ES")):   # ES = via-switch analogue
            for size in SIZES:
                lat = latmodel.pingping_latency(size, cfg, V5E, hops=hops)
                bw = size / lat
                rows.append((f"beff_{name}{suffix}_{size}B",
                             lat * 1e6, f"{bw/1e9:.3f}GB/s"))
    rows.append(("beff_buffered_peak_bw", 0.0,
                 f"{latmodel.buffered_peak_bw(V5E)/1e9:.2f}GB/s"))
    return rows


def measured_rows():
    import jax
    from repro import compat
    import jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core.communicator import Communicator

    rows = []
    lk = scheduler.measure_dispatch_overhead()
    rows.append(("beff_measured_dispatch_lk", lk * 1e6, "host_l_k"))

    if jax.device_count() < 2:
        rows.append(("beff_measured_ring", 0.0, "skipped_1device"))
        return rows

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("x",))
    comm = Communicator.from_mesh(mesh, "x")
    from repro.core import collectives
    cfg = CommConfig()
    x = jnp.zeros((n, 1 << 14), jnp.float32)

    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def ring_once(xs):
        return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]

    # fused: K exchanges inside ONE program
    def many(xs, k=20):
        for _ in range(k):
            xs = ring_once(xs)
        return xs

    fused = jax.jit(many)
    x = jax.block_until_ready(fused(x))
    t0 = time.perf_counter()
    for _ in range(5):
        x = fused(x)
    jax.block_until_ready(x)
    fused_t = (time.perf_counter() - t0) / (5 * 20)

    single = jax.jit(ring_once)
    x = jax.block_until_ready(single(x))
    t0 = time.perf_counter()
    for _ in range(100):
        x = jax.block_until_ready(single(x))
    host_t = (time.perf_counter() - t0) / 100

    rows.append(("beff_measured_ring_fused", fused_t * 1e6, "per_exchange"))
    rows.append(("beff_measured_ring_hostsched", host_t * 1e6, "per_exchange"))
    rows.append(("beff_measured_sched_speedup", 0.0,
                 f"{host_t/fused_t:.2f}x"))
    return rows


def run():
    return modeled_rows() + measured_rows()
