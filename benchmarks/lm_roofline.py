"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For each recorded (arch × shape × mesh) cell: the three roofline terms in
seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and the
per-device HBM need.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import latmodel
from repro.core.config import V5E

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh_filter: str = "16x16", tag: str = ""):
    cells = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh_filter:
            continue
        if tag and f"__{tag}" not in p.stem:
            continue
        if not tag and "__opt" in p.stem:
            continue
        cells.append(rec)
    return cells


def wire_bytes(rec: dict) -> float:
    """Actual bytes-on-ICI from the per-type operand counts.

    all-reduce moves 2(n-1)/n of its operand (ring RS+AG); reduce-scatter
    (n-1)/n; all-gather (n-1)x its (shard) operand; permute 1x.  n = tp (the
    collectives here run within the model axis / data axis of equal size 16).
    The bf16 wire compression factor is applied analytically: the CPU
    backend promotes sub-f32 collectives to f32 in the compiled HLO, a
    backend artifact a TPU build does not share.
    """
    n = 16.0
    b = rec["scaled"]["collective_bytes"]
    total = (b.get("all-reduce", 0.0) * 2 * (n - 1) / n
             + b.get("reduce-scatter", 0.0) * (n - 1) / n
             + b.get("all-gather", 0.0) * (n - 1)
             + b.get("all-to-all", 0.0) * (n - 1) / n
             + b.get("collective-permute", 0.0))
    if rec.get("comm", {}).get("compression") == "bf16":
        total *= 0.5
    elif rec.get("opts", {}).get("seq_parallel"):
        # SP's AG/RS ride the bf16 activation dtype; the CPU backend promotes
        # sub-f32 collectives to f32 in HLO (a TPU build keeps bf16 wire).
        ag_rs = (b.get("reduce-scatter", 0.0) * (n - 1) / n
                 + b.get("all-gather", 0.0) * (n - 1))
        total -= 0.5 * ag_rs
    return total


def analyse(rec: dict) -> dict:
    n = rec["n_chips"]
    # trip-count-aware per-device totals (launch.hlo_analysis)
    flops = rec["scaled"]["flops"]
    # Memory estimate: matmul operand/result traffic + parameters read once
    # (TPU-fusion-friendly lower bound). The raw per-op total (hbm_hi) is the
    # upper bound — CPU fusion boundaries overcount elementwise chains.
    bytes_lo = (rec["scaled"].get("dot_bytes", 0.0)
                + rec["memory"]["argument_bytes"])
    bytes_hi = rec["scaled"]["hbm_bytes"]
    bytes_acc = bytes_lo if bytes_lo > 0 else bytes_hi
    coll = wire_bytes(rec)
    terms = latmodel.roofline_terms(flops, bytes_acc, coll, 1, V5E)
    # MODEL_FLOPS: 6·N·D for train, 2·N·D for inference forward
    n_active = rec["active_param_count"]
    tokens = rec["tokens"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    model_flops = mult * n_active * tokens / n   # per device
    util = model_flops / flops if flops else 0.0
    step_bound = terms.bound_s
    mfu = model_flops / (step_bound * V5E.peak_flops) if step_bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "memory_hi_s": bytes_hi / V5E.hbm_bw,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "model_hlo_ratio": util, "mfu_bound": mfu,
        "hbm_gib": (rec["memory"]["argument_bytes"]
                    + rec["memory"]["temp_bytes"]) / 2**30,
        "fits_16g": (rec["memory"]["argument_bytes"]
                     + rec["memory"]["temp_bytes"]) < 16 * 2**30,
    }


def run():
    rows = []
    for rec in load_cells("16x16"):
        if rec.get("status") != "ok":
            rows.append((f"roofline_{rec['arch']}_{rec['shape']}", 0.0,
                         rec.get("status")))
            continue
        a = analyse(rec)
        rows.append((
            f"roofline_{a['arch']}_{a['shape']}",
            a[a["dominant"] + "_s"] * 1e6,
            f"dom={a['dominant']},mfu={a['mfu_bound']:.3f},"
            f"useful={a['model_hlo_ratio']:.2f},hbm={a['hbm_gib']:.1f}GiB"))
    return rows
