"""LM-collective overlap rows: predicted vs measured, overlap vs fused.

The paper's levers applied to the LM training path's two latency-sensitive
collectives:

- **TP reduce** — the per-layer row-parallel combine
  (``streaming.overlapped_matmul_allreduce``): fused = one psum after the
  full matmul; overlapped = chunked, double-buffered reduce pipelined
  against the matmul.
- **MoE all-to-all** — the dispatch/combine exchange
  (``streaming.chunked_all_to_all`` via ``collectives.all_to_all``):
  fused = one all-to-all; overlapped = independent wire chunks.

Each row reports the measured wall clock on this host's devices with the
chunk-aware Eq. 1 prediction in the derived column; the ``*_speedup`` rows
pair the measured fused/overlap ratio with the predicted one.  Like the
fig11 rows, host-CPU collectives execute synchronously — the prediction
says what a latency-hiding scheduler buys, the measurement what this
substrate pays; the rows make both machine-trackable across PRs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import latmodel
from repro.core.config import (CommConfig, CommMode, OVERLAPPED_CONFIG,
                               Scheduling, V5E)

# Fused reference: buffered combine (single psum / single all-to-all).
TP_FUSED = CommConfig(mode=CommMode.BUFFERED, scheduling=Scheduling.FUSED)
TP_OVERLAP = OVERLAPPED_CONFIG

# Workload shapes (small enough for host-CPU wall clocks, large enough for
# multiple wire chunks under the overlapped config's 1 MiB segments when
# scaled by _CHUNK override below).
TOKENS, D_FF, D_MODEL = 512, 512, 256
MOE_CAP, MOE_D = 64, 256

# Chunk size used for the overlapped rows: small enough that the bench
# messages split into several chunks (the production default of 1 MiB would
# leave these CPU-sized payloads unchunked).
_CHUNK = 1 << 14


def _overlap_cfg() -> CommConfig:
    import dataclasses
    return dataclasses.replace(TP_OVERLAP, chunk_bytes=_CHUNK)


def _predicted_us(msg_bytes: int, cfg: CommConfig) -> float:
    return latmodel.pingping_latency(msg_bytes, cfg, V5E) * 1e6


def _predicted_layer_us(msg_bytes: int, cfg: CommConfig, flops: float) -> float:
    """Eq. 2-style layer prediction: compute + combine, with the overlapped
    schedule hiding the wire under the matmul (max instead of sum) while
    still paying one scheduled command per wire chunk."""
    t_mm = flops / V5E.peak_flops
    if cfg.scheduling == Scheduling.OVERLAPPED:
        t_wire = latmodel.l_c(msg_bytes, cfg, V5E)
        t_issue = latmodel.n_commands(msg_bytes, cfg) * latmodel.l_k(cfg, V5E)
        return (max(t_mm, t_wire) + t_issue) * 1e6
    return (t_mm + latmodel.pingping_latency(msg_bytes, cfg, V5E)) * 1e6


def _time(fn, args, reps: int = 3) -> float:
    """Seconds per call of the jit-compiled fn (compile+warmup excluded)."""
    import jax
    out = jax.block_until_ready(fn(*args))           # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def tp_reduce_rows():
    """Row-parallel TP combine: fused psum vs chunk-overlapped reduce."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.models import layers
    from repro.models.common import MeshContext, ModelConfig, Runtime

    n = jax.device_count()
    if n < 2:
        return [("lmcoll_tp_reduce", 0.0, "skipped_1device")]
    tp = min(4, n)
    mesh = jax.make_mesh((tp,), ("model",))
    cfg_model = ModelConfig(name="bench", family="dense", n_layers=1,
                            d_model=D_MODEL, n_heads=4, n_kv_heads=4,
                            d_ff=D_FF, vocab_size=1024)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(TOKENS, D_FF), jnp.float32)
    w = jnp.asarray(rng.randn(D_FF, D_MODEL), jnp.float32)
    msg_bytes = TOKENS * D_MODEL * 4          # the reduced partial sum

    flops = 2.0 * TOKENS * D_FF * D_MODEL     # per-device matmul FLOPs
    rows = []
    measured = {}
    for name, cc in (("fused", TP_FUSED), ("overlap", _overlap_cfg())):
        rt = Runtime(cfg=cfg_model,
                     mesh=MeshContext(data_axes=(), model_size=tp,
                                      data_sizes=()),
                     comm=cc)

        @partial(compat.shard_map, mesh=mesh,
                 in_specs=(P(None, "model"), P("model", None)),
                 out_specs=P(), check_vma=False)
        def f(xs, ws, rt=rt):
            return layers.row_parallel(xs, ws, rt)

        sec = _time(jax.jit(f), (x, w))
        measured[name] = sec
        rows.append((f"lmcoll_tp_reduce_{name}_tp{tp}", sec * 1e6,
                     f"pred{_predicted_layer_us(msg_bytes, cc, flops):.1f}us"))
    pred = (_predicted_layer_us(msg_bytes, TP_FUSED, flops)
            / _predicted_layer_us(msg_bytes, _overlap_cfg(), flops))
    rows.append((f"lmcoll_tp_reduce_speedup_tp{tp}",
                 measured["fused"] / measured["overlap"],
                 f"predicted{pred:.2f}x"))
    return rows


def moe_a2a_rows():
    """MoE dispatch-shaped all-to-all: fused vs chunk-overlapped."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import collectives
    from repro.core.communicator import Communicator

    n = jax.device_count()
    if n < 2:
        return [("lmcoll_moe_a2a", 0.0, "skipped_1device")]
    dp = min(4, n)
    mesh = jax.make_mesh((dp,), ("data",))
    comm = Communicator.from_mesh(mesh, "data")
    rng = np.random.RandomState(1)
    # (dp, cap, D) bucketed dispatch payload per device
    x = jnp.asarray(rng.randn(dp * dp, MOE_CAP, MOE_D), jnp.float32)
    msg_bytes = dp * MOE_CAP * MOE_D * 4

    rows = []
    measured = {}
    for name, cc in (("fused", TP_FUSED), ("overlap", _overlap_cfg())):
        @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"), check_vma=False)
        def f(v, cc=cc):
            return collectives.all_to_all(v, comm, cc, split_axis=0,
                                          concat_axis=0)

        sec = _time(jax.jit(f), (x,))
        measured[name] = sec
        rows.append((f"lmcoll_moe_a2a_{name}_dp{dp}", sec * 1e6,
                     f"pred{_predicted_us(msg_bytes, cc):.1f}us"))
    pred = (_predicted_us(msg_bytes, TP_FUSED)
            / _predicted_us(msg_bytes, _overlap_cfg()))
    rows.append((f"lmcoll_moe_a2a_speedup_dp{dp}",
                 measured["fused"] / measured["overlap"],
                 f"predicted{pred:.2f}x"))
    return rows


def run():
    return tp_reduce_rows() + moe_a2a_rows()
