"""Disk-backed plan store rows: cold sweep vs fresh-process warm start.

The ACCL+ restart story in benchmark form: a sweep populates a plan
directory (``REPRO_PLAN_DIR``), then a *separate process* runs the identical
sweep against it.  The warm process replays schedule plans from JSON,
deserializes AOT-compiled programs, and hits the XLA compilation cache — so
its wall clock measures exactly what persistence saves a new CLI invocation,
CI job, or serving replica:

- ``pstore_cold_sweep_us`` — cold-process sweep wall clock (empty store;
  derived column: disk misses it wrote);
- ``pstore_warm_sweep_us`` — fresh-process sweep wall clock against the
  populated store (derived: disk hits it replayed);
- ``pstore_warm_ratio`` — warm/cold ratio (non-latency row: smaller is
  better; the CI gate asserts <= 0.7 on the same configuration).

Each leg is a subprocess so "fresh process" is literal — nothing in this
driver's in-memory plan cache can leak into the measurement.  New rows ride
this PR report-only until a second committed baseline lands.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

SWEEP_ARGS = ("--fast", "--devices", "8", "--collectives", "sendrecv",
              "--sizes", "small")


def _run_sweep(plan_dir: str, out_db: str, stats_path: str) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["REPRO_TUNE_NO_REEXEC"] = "1"
    env["REPRO_SWEEP_STATS_JSON"] = stats_path
    env["REPRO_PLAN_DIR"] = plan_dir
    repo = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(repo / "src")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.tune.sweep", *SWEEP_ARGS,
         "--out", out_db],
        capture_output=True, text=True, timeout=600, env=env, cwd=str(repo))
    wall = time.perf_counter() - t0
    if proc.returncode != 0:
        raise RuntimeError(f"sweep subprocess failed (rc={proc.returncode}): "
                           f"{proc.stderr[-500:]}")
    return wall


def run():
    import jax
    if jax.device_count() < 8:
        return [("pstore", 0.0, "skipped_lt8devices")]
    with tempfile.TemporaryDirectory(prefix="repro-pstore-bench-") as td:
        plan_dir = os.path.join(td, "store")
        stats_cold = os.path.join(td, "cold.json")
        stats_warm = os.path.join(td, "warm.json")
        cold_s = _run_sweep(plan_dir, os.path.join(td, "db-cold.json"),
                            stats_cold)
        warm_s = _run_sweep(plan_dir, os.path.join(td, "db-warm.json"),
                            stats_warm)
        with open(stats_cold) as f:
            cold = json.load(f)
        with open(stats_warm) as f:
            warm = json.load(f)
    return [
        ("pstore_cold_sweep_us", cold_s * 1e6,
         f"disk_misses{cold.get('disk_misses', 0)}"),
        ("pstore_warm_sweep_us", warm_s * 1e6,
         f"disk_hits{warm.get('disk_hits', 0)}"),
        ("pstore_warm_ratio", warm_s / max(cold_s, 1e-9),
         f"fresh_process_warm/cold_hits{warm.get('disk_hits', 0)}"),
    ]
