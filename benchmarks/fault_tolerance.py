"""Fault-tolerance rows: recovery wall clock + model reselect vs resweep.

The elastic runtime's pitch is quantitative: when a rank dies, re-selecting
CommConfigs by extrapolating the calibrated Eq. 1 model over the TuneDB
(``repro.tune.elastic.model_reselect``) costs milliseconds, while
re-measuring (a sweep) costs seconds of wall clock exactly while the job is
down.  These rows pin both sides of that trade:

- ``ft_recovery_us``  — end-to-end rank-loss recovery inside the elastic SWE
  segment loop (snapshot unwind + shrink + repartition + model reselect +
  rebuild; derived: survivors and whether configs changed);
- ``ft_reselect_us``  — model-based re-selection alone on the populated DB
  (the recovery path's tuning cost);
- ``ft_resweep_us``   — what re-measuring instead would cost: a fast sweep
  of the same collective over the same config space;
- ``ft_reselect_speedup`` — resweep/reselect ratio (non-latency row: bigger
  means the no-resweep recovery policy buys more).

New rows ride this PR report-only until a second committed baseline lands.
"""
from __future__ import annotations

import time


def run():
    import jax
    if jax.device_count() < 8:
        return [("ft", 0.0, "skipped_lt8devices")]
    from repro.core.topology import TorusSpec
    from repro.runtime.elastic import run_swe_elastic
    from repro.runtime.faults import FaultSchedule
    from repro.tune.elastic import reselect_round_configs
    from repro.tune.sweep import run_sweep
    from repro.core.communicator import Communicator

    rows = []
    topo = TorusSpec.parse("4x2")

    # -- end-to-end rank-loss recovery wall clock ----------------------
    rep = run_swe_elastic(240, 8, topo, n_steps=20, segment=5,
                          schedule=FaultSchedule.parse("rank_lost@5=r5"))
    if rep.recoveries:
        r = rep.recoveries[0]
        rows.append(("ft_recovery_us", r.wall_s * 1e6,
                     f"survivors{rep.n_parts[-1]}_"
                     f"cfg_changed{int(r.config_changed())}_"
                     f"sweeps{rep.sweep_runs_delta}"))

    # -- model reselect vs a fresh sweep on the same fabric ------------
    # The sweep populates the DB (and is timed: the cost recovery avoids);
    # the reselect then re-ranks the measured space from the fitted model.
    t0 = time.perf_counter()
    db = run_sweep(collectives=("sendrecv", "multi_neighbor"), fast=True,
                   topology=topo, hop_distances=(1, 2))
    resweep_s = time.perf_counter() - t0

    comm = Communicator(("data",), (8,), topo=topo)
    rounds = [[(0, 1)], [(0, 5)]]     # a near round and a routed round
    t0 = time.perf_counter()
    reselect_round_configs(rounds, comm, 1 << 14, db=db)
    reselect_s = time.perf_counter() - t0

    rows.append(("ft_resweep_us", resweep_s * 1e6,
                 f"entries{len(db.entries)}"))
    rows.append(("ft_reselect_us", reselect_s * 1e6,
                 f"cands_from{len(db.entries)}entries"))
    rows.append(("ft_reselect_speedup", resweep_s / max(reselect_s, 1e-9),
                 "resweep/reselect"))
    return rows
