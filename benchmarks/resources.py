"""Fig. 3 reproduction: 'resource utilization' of the comm stack per config.

FPGA LUT/FF/DSP → TPU analogues: HLO op count, collective op count,
generated-code bytes and temp (live-buffer) bytes of a fixed gradient
all-reduce program, per ACCL-X build:

  full      ring + compression + arithmetic plugins
  minimal   plugins compiled out (native psum)
  tcp_opt   ordered transport, window scaling, jumbo chunks
  udp       unordered transport
"""
from __future__ import annotations

import numpy as np


def run():
    import jax
    from repro import compat
    import jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core import collectives
    from repro.core.communicator import Communicator
    from repro.core.config import (CommConfig, CommMode, Compression,
                                   Transport)

    if jax.device_count() < 2:
        return [("fig3", 0.0, "skipped_1device")]

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("x",))
    comm = Communicator.from_mesh(mesh, "x")
    builds = {
        "full_int8ring": CommConfig(algorithm="ring",
                                    compression=Compression.INT8),
        "full_ring": CommConfig(algorithm="ring"),
        "minimal": CommConfig(enable_compression_plugin=False,
                              enable_arithmetic_plugin=False),
        "tcp_opt": CommConfig(mode=CommMode.STREAMING,
                              transport=Transport.ORDERED, window=8,
                              chunk_bytes=1 << 20),
        "udp": CommConfig(mode=CommMode.STREAMING,
                          transport=Transport.UNORDERED),
    }
    x = jnp.zeros((n, 1 << 16), jnp.float32)
    rows = []
    for name, cfg in builds.items():
        @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
        def f(xs):
            return collectives.all_reduce(xs[0], comm, cfg)[None]

        lowered = jax.jit(f).lower(x)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        ops_total = hlo.count(" = ")
        colls = sum(hlo.count(k) for k in
                    ("all-reduce", "collective-permute", "all-gather",
                     "reduce-scatter"))
        rows.append((f"fig3_{name}_hlo_ops", float(ops_total),
                     f"colls{colls}"))
        rows.append((f"fig3_{name}_code_bytes",
                     float(mem.generated_code_size_in_bytes), ""))
        rows.append((f"fig3_{name}_temp_bytes",
                     float(mem.temp_size_in_bytes), ""))
    return rows
