"""Reliable-transport rows: what chunk-level loss recovery costs.

The reliable wire's contract has two quantitative halves.  First, the
zero-fault fast path is free: a GUARANTEED config on a clean wire compiles
the exact same program as BEST_EFFORT (``plan_for`` returns None), so
``rt_guaranteed_overhead`` should sit at ~1.0x.  Second, recovery has a
real latency price: injected chunk loss adds retransmit / timeout-hold /
backoff permute rounds to the traced program, and the ``rt_loss*`` rows
measure that price at the paper's TCP-vs-UDP knob settings.

- ``rt_clean_us``            — best-effort chunked ring permute, clean wire;
- ``rt_guaranteed_clean_us`` — same message, GUARANTEED, clean wire (the
  fast path: must not pay for reliability it never uses);
- ``rt_loss1_us``            — GUARANTEED under 1% injected chunk loss;
- ``rt_loss5_us``            — GUARANTEED under 5% injected chunk loss;
- ``rt_guaranteed_overhead`` — guaranteed-clean / clean ratio (non-latency:
  ~1.0 is the contract);
- ``rt_loss5_penalty``       — loss5 / clean ratio (non-latency: the
  recovery rounds' cost, bigger = more expensive wire).

Loss rows pin the first transmission dropped (the injector's own
determinism rule): a single traced message at a low seeded rate would
usually draw no faults at all, and a row that sometimes measures the clean
program is noise, not data.  Rows ride report-only until a second
committed baseline lands.
"""
from __future__ import annotations

import time


def _time_permute(cfg, faults, x, mesh, perm, reps=30):
    import jax
    import numpy as np
    from repro import compat
    from repro.core import reliable, streaming

    spec = jax.sharding.PartitionSpec("x")
    body = lambda v: streaming.chunked_permute(v[0], perm, "x", cfg)[None]
    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=spec,
                                 out_specs=spec, check_vma=False))
    with reliable.inject(faults):
        jax.block_until_ready(f(x))          # trace bakes recovery rounds in
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    import jax
    if jax.device_count() < 4:
        return [("rt", 0.0, "skipped_lt4devices")]
    import jax.numpy as jnp
    from repro import compat
    from repro.core import reliable
    from repro.core.config import (CommConfig, CommMode, Reliability,
                                   Scheduling, Transport)

    n = 4
    mesh = compat.make_mesh((n,), ("x",))
    perm = [(i, (i + 1) % n) for i in range(n)]
    N = 16 * 256                              # 16 x 1 KiB wire chunks
    x = jnp.arange(n * N, dtype=jnp.float32).reshape(n, N) * 0.5 + 1.0

    def cfg(reliability):
        return CommConfig(mode=CommMode.STREAMING,
                          scheduling=Scheduling.OVERLAPPED,
                          transport=Transport.UNORDERED, window=4,
                          chunk_bytes=1024, reliability=reliability,
                          ack_timeout=2, max_retransmits=4,
                          backoff_base=1, backoff_cap=4)

    def lossy(rate):
        return reliable.WireFaults(seed=11, drop=rate,
                                   drop_events=frozenset({(0, 0, 0)}))

    clean_s = _time_permute(cfg(Reliability.BEST_EFFORT), None, x, mesh, perm)
    guar_s = _time_permute(cfg(Reliability.GUARANTEED), None, x, mesh, perm)
    loss1_s = _time_permute(cfg(Reliability.GUARANTEED), lossy(0.01),
                            x, mesh, perm)
    loss5_s = _time_permute(cfg(Reliability.GUARANTEED), lossy(0.05),
                            x, mesh, perm)

    chunks = "16chunks_1KiB"
    return [
        ("rt_clean_us", clean_s * 1e6, f"best_effort_{chunks}"),
        ("rt_guaranteed_clean_us", guar_s * 1e6, f"fast_path_{chunks}"),
        ("rt_loss1_us", loss1_s * 1e6, "drop1pct_pinned_first_loss"),
        ("rt_loss5_us", loss5_s * 1e6, "drop5pct_pinned_first_loss"),
        ("rt_guaranteed_overhead", guar_s / max(clean_s, 1e-9),
         "guaranteed_clean/clean"),
        ("rt_loss5_penalty", loss5_s / max(clean_s, 1e-9),
         "loss5/clean"),
    ]
