"""Compare two ``BENCH_comm.json`` files and flag latency regressions.

The benchmark driver (``python -m benchmarks.run``) writes machine-readable
rows; this tool closes the loop across PRs: regenerate the JSON, diff it
against the committed one, and fail (exit non-zero) when any latency row got
more than ``--threshold`` (default 20 %) slower.  ``--report-only`` prints
the same report but always exits 0 — the CI mode, since host-CPU timings are
noisy; the hard gate is for local/perf-lab use.

Usage::

    PYTHONPATH=src python -m benchmarks.run --json=BENCH_new.json
    PYTHONPATH=src python -m benchmarks.diff --old BENCH_comm.json \
        --new BENCH_new.json [--threshold 0.2] [--report-only]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

# Rows whose us_per_call is not a latency (ratios, byte counts, op counts):
# a bigger number is not a regression there.
_NON_LATENCY_PREFIXES = ("fig3_", "table1_", "fig11_speedup")


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != "repro-bench-v1":
        raise ValueError(f"{path}: not a repro-bench-v1 file")
    return payload.get("rows", {})


def is_latency_row(name: str) -> bool:
    return not (name.endswith("_ERROR")
                or any(name.startswith(p) for p in _NON_LATENCY_PREFIXES))


def compare(old_rows: dict, new_rows: dict, threshold: float = 0.2):
    """Returns (regressions, improvements, missing) over latency rows.

    A regression is new > old * (1 + threshold); rows absent from either
    side, zero-valued baselines, and non-latency rows are skipped.
    """
    regressions, improvements, missing = [], [], []
    for name, old in sorted(old_rows.items()):
        if not is_latency_row(name):
            continue
        old_us = float(old.get("us_per_call", 0.0))
        if old_us <= 0.0:
            continue
        new = new_rows.get(name)
        if new is None:
            missing.append(name)
            continue
        new_us = float(new.get("us_per_call", 0.0))
        ratio = new_us / old_us
        if ratio > 1.0 + threshold:
            regressions.append((name, old_us, new_us, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, old_us, new_us, ratio))
    return regressions, improvements, missing


def report(regressions, improvements, missing, threshold: float,
           out=None) -> None:
    out = out if out is not None else sys.stdout
    for name, old_us, new_us, ratio in regressions:
        print(f"REGRESSION {name}: {old_us:.3f} -> {new_us:.3f} us "
              f"({ratio:.2f}x)", file=out)
    for name, old_us, new_us, ratio in improvements:
        print(f"improved   {name}: {old_us:.3f} -> {new_us:.3f} us "
              f"({ratio:.2f}x)", file=out)
    for name in missing:
        print(f"missing    {name}: no row in the new results", file=out)
    print(f"{len(regressions)} regression(s) > {threshold * 100:.0f}%, "
          f"{len(improvements)} improvement(s), {len(missing)} missing",
          file=out)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.diff",
        description="Diff two BENCH_comm.json files; non-zero exit on "
                    "latency regressions.")
    ap.add_argument("--old", default="BENCH_comm.json",
                    help="baseline JSON (default: the committed one)")
    ap.add_argument("--new", required=True, help="freshly generated JSON")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative slowdown that counts as a regression")
    ap.add_argument("--report-only", action="store_true",
                    help="print the report but always exit 0 (CI mode)")
    args = ap.parse_args(argv)

    try:
        old_rows = load_rows(args.old)
        new_rows = load_rows(args.new)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"benchmarks.diff: {e}", file=sys.stderr)
        return 0 if args.report_only else 2

    regressions, improvements, missing = compare(
        old_rows, new_rows, args.threshold)
    report(regressions, improvements, missing, args.threshold)
    if regressions and not args.report_only:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
