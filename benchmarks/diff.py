"""Compare ``BENCH_comm.json`` baselines against fresh results and flag
latency regressions.

The benchmark driver (``python -m benchmarks.run``) writes machine-readable
rows; this tool closes the loop across PRs: regenerate the JSON, diff it
against the committed baseline(s), and fail (exit non-zero) when any
enforced latency row got more than ``--threshold`` (default 20 %) slower.

Enforcement tiers:

- ``--old`` may be given several times (committed baseline snapshots under
  ``benchmarks/baselines/``).  With two or more baselines a row is
  **enforced** only when it appears in at least two of them — a row with a
  single committed measurement has no noise floor yet and is report-only.
  The reference value is the most lenient (slowest) baseline, so a row must
  regress past *every* committed measurement to fail.
- Rows matching ``--report-only-prefixes`` (default: the new ``topo_``
  hop-scaling rows) are report-only regardless — new rows ride one PR as
  report-only before their second committed baseline makes them enforced.
- ``--report-only`` downgrades everything (local what-if mode).

Usage::

    PYTHONPATH=src python -m benchmarks.run --json=BENCH_new.json
    PYTHONPATH=src python -m benchmarks.diff \
        --old benchmarks/baselines/bench_pr2.json \
        --old benchmarks/baselines/bench_pr3.json \
        --new BENCH_new.json [--threshold 0.2] [--report-only]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

# Rows whose us_per_call is not a latency (ratios, byte counts, op counts):
# a bigger number is not a regression there.
_NON_LATENCY_PREFIXES = ("fig3_", "table1_", "fig11_speedup",
                         "lmcoll_tp_reduce_speedup", "lmcoll_moe_a2a_speedup",
                         "e2e_gain_", "topo_hop_ratio", "ft_reselect_speedup",
                         "rt_guaranteed_overhead", "rt_loss5_penalty",
                         "srv_phase_win", "srv_distinct_48",
                         "srv_tok_s_rank_48")

# New rows that stay report-only until they have >= 2 committed baselines.
# The e2e_ rows graduated with bench_pr5.json; the topo_ hop-scaling rows
# graduated with their second committed baseline (bench_pr6.json;
# topo_hop_ratio stays a non-latency ratio).  The ft_ fault-tolerance rows
# are new this PR (recovery wall clock is dominated by jit rebuilds and
# noisy on shared CI hosts — they ride report-only until a noise floor
# exists; ft_reselect_speedup stays a non-latency ratio).  The rt_
# reliable-transport rows are likewise new (rt_guaranteed_overhead and
# rt_loss5_penalty stay non-latency ratios).  The srv_ serving rows are new
# this PR (srv_phase_win, srv_distinct_48 and srv_tok_s_rank_48 stay
# non-latency: ratios/flags/throughput, bigger is not a regression).
DEFAULT_REPORT_ONLY_PREFIXES = ("ft_", "rt_", "srv_")


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != "repro-bench-v1":
        raise ValueError(f"{path}: not a repro-bench-v1 file")
    return payload.get("rows", {})


def is_latency_row(name: str) -> bool:
    return not (name.endswith("_ERROR")
                or any(name.startswith(p) for p in _NON_LATENCY_PREFIXES))


def compare(old_rows: dict, new_rows: dict, threshold: float = 0.2):
    """Returns (regressions, improvements, missing) over latency rows.

    A regression is new > old * (1 + threshold); rows absent from either
    side, zero-valued baselines, and non-latency rows are skipped.
    """
    regressions, improvements, missing = [], [], []
    for name, old in sorted(old_rows.items()):
        if not is_latency_row(name):
            continue
        old_us = float(old.get("us_per_call", 0.0))
        if old_us <= 0.0:
            continue
        new = new_rows.get(name)
        if new is None:
            missing.append(name)
            continue
        new_us = float(new.get("us_per_call", 0.0))
        ratio = new_us / old_us
        if ratio > 1.0 + threshold:
            regressions.append((name, old_us, new_us, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, old_us, new_us, ratio))
    return regressions, improvements, missing


def merge_baselines(baselines: Sequence[dict]) -> tuple[dict, dict]:
    """Fold several baseline row dicts into one reference.

    Returns ``(rows, counts)``: per row the most lenient (largest) baseline
    latency and the number of baselines that measured it — a row must exist
    in >= 2 committed baselines before it can hard-fail the gate.
    """
    rows: dict = {}
    counts: dict = {}
    for rowset in baselines:
        for name, row in rowset.items():
            us = float(row.get("us_per_call", 0.0))
            if name not in rows or us > float(rows[name]["us_per_call"]):
                rows[name] = {"us_per_call": us,
                              "derived": row.get("derived", "")}
            counts[name] = counts.get(name, 0) + 1
    return rows, counts


def split_enforced(regressions, counts: dict, n_baselines: int,
                   report_only_prefixes: Sequence[str]):
    """(hard, soft) partition of the regressions per the enforcement tiers."""
    need = 2 if n_baselines > 1 else 1
    hard, soft = [], []
    for reg in regressions:
        name = reg[0]
        if (counts.get(name, 0) < need
                or any(name.startswith(p) for p in report_only_prefixes)):
            soft.append(reg)
        else:
            hard.append(reg)
    return hard, soft


def report(regressions, improvements, missing, threshold: float,
           out=None, soft_regressions=()) -> None:
    out = out if out is not None else sys.stdout
    for name, old_us, new_us, ratio in regressions:
        print(f"REGRESSION {name}: {old_us:.3f} -> {new_us:.3f} us "
              f"({ratio:.2f}x)", file=out)
    for name, old_us, new_us, ratio in soft_regressions:
        print(f"REGRESSION (report-only) {name}: {old_us:.3f} -> "
              f"{new_us:.3f} us ({ratio:.2f}x)", file=out)
    for name, old_us, new_us, ratio in improvements:
        print(f"improved   {name}: {old_us:.3f} -> {new_us:.3f} us "
              f"({ratio:.2f}x)", file=out)
    for name in missing:
        print(f"missing    {name}: no row in the new results", file=out)
    print(f"{len(regressions)} enforced regression(s) > "
          f"{threshold * 100:.0f}%, {len(soft_regressions)} report-only, "
          f"{len(improvements)} improvement(s), {len(missing)} missing",
          file=out)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.diff",
        description="Diff BENCH_comm.json baselines against fresh results; "
                    "non-zero exit on enforced latency regressions.")
    ap.add_argument("--old", action="append", default=None,
                    help="baseline JSON; repeat for several committed "
                    "baselines (default: BENCH_comm.json). Rows must appear "
                    "in >= 2 baselines to be enforced when several are given")
    ap.add_argument("--new", required=True, help="freshly generated JSON")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative slowdown that counts as a regression")
    ap.add_argument("--report-only", action="store_true",
                    help="print the report but always exit 0")
    ap.add_argument("--report-only-prefixes",
                    default=",".join(DEFAULT_REPORT_ONLY_PREFIXES),
                    help="comma list of row-name prefixes that are never "
                    "enforced (new rows riding one PR before their second "
                    "baseline)")
    args = ap.parse_args(argv)
    olds = args.old or ["BENCH_comm.json"]
    prefixes = tuple(p for p in args.report_only_prefixes.split(",") if p)

    try:
        baselines = [load_rows(p) for p in olds]
        new_rows = load_rows(args.new)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"benchmarks.diff: {e}", file=sys.stderr)
        return 0 if args.report_only else 2

    old_rows, counts = merge_baselines(baselines)
    regressions, improvements, missing = compare(
        old_rows, new_rows, args.threshold)
    hard, soft = split_enforced(regressions, counts, len(baselines), prefixes)
    report(hard, improvements, missing, args.threshold,
           soft_regressions=soft)
    if hard and not args.report_only:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
