"""Figs. 9 & 10 + Table 1 reproduction: shallow-water scaling.

- fig9  (weak scaling, ~6000 elements/partition, up to 48 partitions):
  modeled Eq. 2 throughput for MPI+PCIe-baseline / ACCL-UDP-ish (streaming,
  unordered) / ACCL-TCP-ish (streaming, ordered window), plus MEASURED
  multi-device wall time on this host's CPU devices at small scale.
- fig10 (strong scaling, fixed meshes): modeled throughput vs partitions,
  annotated with N_max — reproducing the step-wise degradation when extra
  neighbors enter the latency term.  The overlapped series uses the Eq. 2
  overlap term (latmodel.eq2_throughput_overlap): the knee moves to higher
  partition counts because L_comm hides behind interior compute.
- fig11: overlap predicted-vs-measured — wall time of the fused vs the
  overlapped (double-buffered, interior/boundary split) step on this host's
  CPU devices next to the model's predicted speedup.
- table1: "resource utilization" analogue — compiled-program stats of the
  SWE step for the configurations.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import latmodel
from repro.core.config import (BASELINE_CONFIG, OVERLAPPED_CONFIG, CommConfig,
                               CommMode, Scheduling, Transport, V5E)

ACCL_UDP = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.FUSED,
                      transport=Transport.UNORDERED)
ACCL_TCP = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.FUSED,
                      transport=Transport.ORDERED, window=8)
ACCL_OVERLAP = OVERLAPPED_CONFIG

# Host-MPI baseline: buffered + host scheduling (l_k = 30 µs twice + copy).
BASE = BASELINE_CONFIG

_N_MAX_TABLE = {1: 0, 2: 1, 4: 3, 8: 4, 12: 5, 16: 5, 24: 6, 32: 6, 48: 7}


def _nmax(p: int) -> int:
    ks = sorted(_N_MAX_TABLE)
    for k in reversed(ks):
        if p >= k:
            return _N_MAX_TABLE[k]
    return 0


def _workload(e_total: int, parts: int, freq=256e6) -> latmodel.SWEWorkload:
    e_local = e_total // parts
    boundary = int(3.5 * np.sqrt(max(e_local, 1)))  # perimeter elements
    n_max = _nmax(parts) if parts > 1 else 0
    return latmodel.SWEWorkload(
        e_total=e_total, e_core=max(e_local - boundary, 1),
        e_send=boundary, e_recv=boundary, d_ext=0, l_pipe=100,
        n_max=max(n_max, 1) if parts > 1 else 0,
        flop_per_element=260.0, freq=freq,
        msg_bytes=max(boundary // max(n_max, 1), 1) * 12 if parts > 1 else 64)


def fig9_weak_scaling():
    rows = []
    for parts in (1, 2, 4, 8, 16, 24, 32, 48):
        e_total = 6000 * parts
        w = _workload(e_total, parts)
        for name, cfg in (("base_mpi", BASE), ("accl_udp", ACCL_UDP),
                          ("accl_tcp", ACCL_TCP),
                          ("accl_overlap", ACCL_OVERLAP)):
            if parts == 1:
                thr = w.freq * w.flop_per_element  # no comm at all
                stall = 0.0
            else:
                thr = latmodel.eq2_throughput_overlap(w, cfg, V5E) * parts
                stall = latmodel.stall_fraction_overlap(w, cfg, V5E)
            rows.append((f"fig9_{name}_p{parts}",
                         1e6 * e_total * w.flop_per_element / thr,
                         f"{thr/1e12:.3f}TFLOPs_stall{stall:.2f}"))
    return rows


def fig10_strong_scaling():
    rows = []
    for e_total in (27_000, 108_000):
        for parts in (2, 4, 8, 16, 24, 32, 48):
            w = _workload(e_total, parts)
            for name, cfg in (("", ACCL_UDP), ("_overlap", ACCL_OVERLAP)):
                thr = latmodel.eq2_throughput_overlap(w, cfg, V5E) * parts
                rows.append((f"fig10_{e_total//1000}k{name}_p{parts}",
                             1e6 * e_total * w.flop_per_element / thr,
                             f"{thr/1e12:.3f}TFLOPs_Nmax{w.n_max}"))
    return rows


def fig11_overlap_predicted_vs_measured():
    """Fused vs overlapped SWE step: measured wall clock on this host's CPU
    devices next to the Eq. 2 overlap-term prediction (same workload)."""
    import jax
    rows = []
    n = jax.device_count()
    if n < 2:
        return [("fig11_overlap", 0.0, "skipped_1device")]
    from repro.swe import driver
    for parts in (2, 4, 8):
        if parts > n:
            break
        dmesh = jax.make_mesh((parts,), ("data",))
        measured = {}
        w = None
        for name, cfg in (("fused", ACCL_UDP), ("overlapped", ACCL_OVERLAP)):
            sim = driver.build_simulation(600 * parts, dmesh, cfg)
            run = driver.make_sim_runner(sim, n_inner=20)
            s = jax.block_until_ready(run(sim.state, 0.0))   # compile+warm
            t0 = time.perf_counter()
            for _ in range(3):
                s = run(s, 0.0)
            jax.block_until_ready(s)
            measured[name] = (time.perf_counter() - t0) / (3 * 20)
            if w is None:
                w = driver.build_workload(sim)
        pred = {name: 1.0 / latmodel.eq2_throughput_overlap(w, cfg, V5E)
                for name, cfg in (("fused", ACCL_UDP),
                                  ("overlapped", ACCL_OVERLAP))}
        pred_speedup = pred["fused"] / pred["overlapped"]
        meas_speedup = measured["fused"] / measured["overlapped"]
        for name in ("fused", "overlapped"):
            rows.append((f"fig11_{name}_p{parts}", measured[name] * 1e6,
                         "measured_us_per_step"))
        rows.append((f"fig11_speedup_p{parts}", meas_speedup,
                     f"predicted{pred_speedup:.2f}x"))
    return rows


def fig9_measured():
    """Measured weak scaling on this host's CPU devices (relative numbers)."""
    import jax
    rows = []
    n = jax.device_count()
    if n < 2:
        return [("fig9_measured", 0.0, "skipped_1device")]
    from repro.swe import driver
    for parts in (1, 2, 4, 8):
        if parts > n:
            break
        dmesh = jax.make_mesh((parts,), ("data",))
        sim = driver.build_simulation(600 * parts, dmesh, ACCL_UDP)
        run = driver.make_sim_runner(sim, n_inner=20)
        s = jax.block_until_ready(run(sim.state, 0.0))
        t0 = time.perf_counter()
        for _ in range(3):
            s = run(s, 0.0)
        jax.block_until_ready(s)
        dt_step = (time.perf_counter() - t0) / (3 * 20)
        rows.append((f"fig9_measured_p{parts}", dt_step * 1e6,
                     f"{sim.mesh.n_elements}elems"))
    return rows


def table1_resources():
    """Compiled-program stats of one SWE step per comm config (the FPGA
    LUT/BRAM table's TPU analogue: code size + temp memory + op counts)."""
    import jax
    rows = []
    if jax.device_count() < 2:
        return [("table1", 0.0, "skipped_1device")]
    from repro.swe import driver
    dmesh = jax.make_mesh((jax.device_count(),), ("data",))
    for name, cfg in (("base", BASE), ("accl_udp", ACCL_UDP),
                      ("accl_tcp", ACCL_TCP), ("accl_overlap", ACCL_OVERLAP)):
        sim = driver.build_simulation(2000, dmesh, cfg)
        # lower one fused inner step
        run = driver.make_sim_runner(sim, n_inner=1)
        import jax.numpy as jnp
        args = driver._static_args(sim)
        lowered = jax.jit(lambda s: run(s, 0.0)).lower(sim.state)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        n_coll = hlo.count("collective-permute")
        rows.append((f"table1_{name}_codebytes",
                     float(mem.generated_code_size_in_bytes), f"permutes{n_coll}"))
        rows.append((f"table1_{name}_tempbytes",
                     float(mem.temp_size_in_bytes), ""))
    return rows


def run():
    return (fig9_weak_scaling() + fig10_strong_scaling() + fig9_measured()
            + fig11_overlap_predicted_vs_measured() + table1_resources())
