"""End-to-end objective rows: does the bare-latency winner win end-to-end?

The paper's §5 result in benchmark form.  For each consumer-loop benchmark
(the row-parallel matmul+reduce layer, the halo-fold step) a small candidate
set is measured twice — bare collective latency (the microbenchmark the
tuner's default objective ranks by) and the consumer loop end-to-end — then
``select_config`` answers under both objectives and the rows record the
measured e2e time of each winner:

- ``e2e_<consumer>_lat_winner_us``  — e2e µs/iter of the bare-latency winner
- ``e2e_<consumer>_e2e_winner_us``  — e2e µs/iter of the e2e-objective winner
- ``e2e_gain_<consumer>``           — their ratio (>1: the microbench winner
  loses end-to-end, the §5 disagreement)

The row-parallel candidate set is chosen so the bare microbenchmark
*cannot* rank it: a native all-reduce executes the identical program under
buffered/streaming mode and fused/overlapped scheduling — only the consumer
loop (which chunks the matmul+reduce pipeline under streaming/overlapped)
separates the candidates.  The derived column carries the overlap-aware
Eq. 2 prediction (``latmodel.e2e_consumer_latency``, v5e constants): on
hardware with async collectives the model favors the overlapped config;
this host's synchronous CPU collectives pay the chunking without the
overlap win — both sides of that story are machine-tracked.
"""
from __future__ import annotations

from repro.core import latmodel
from repro.core.config import (CommConfig, CommMode, Scheduling, Transport,
                               V5E)

MSG_BYTES = 1 << 14

# Row-parallel candidates: identical bare all_reduce programs (native psum
# ignores mode/chunking), distinct consumer loops.
_ROWPAR_CANDS = (
    ("buffered_fused", CommConfig(mode=CommMode.BUFFERED,
                                  scheduling=Scheduling.FUSED)),
    ("streaming_fused_4k", CommConfig(chunk_bytes=1 << 12)),
    ("streaming_fused_16k", CommConfig(chunk_bytes=1 << 14)),
    ("streaming_overlap_4k", CommConfig(scheduling=Scheduling.OVERLAPPED,
                                        chunk_bytes=1 << 12)),
    ("streaming_overlap_16k", CommConfig(scheduling=Scheduling.OVERLAPPED,
                                         chunk_bytes=1 << 14)),
)

# Halo-fold candidates: here the bare multi_neighbor programs do differ.
_HALO_CANDS = (
    ("buffered_fused", CommConfig(mode=CommMode.BUFFERED,
                                  scheduling=Scheduling.FUSED,
                                  transport=Transport.ORDERED, window=1)),
    ("streaming_fused", CommConfig(chunk_bytes=1 << 12)),
    ("streaming_overlap", CommConfig(scheduling=Scheduling.OVERLAPPED,
                                     chunk_bytes=1 << 12)),
)

_CONSUMER_SETS = {"all_reduce": ("rowpar", _ROWPAR_CANDS),
                  "multi_neighbor": ("halo", _HALO_CANDS)}


def _predicted_e2e_us(collective: str, cfg: CommConfig) -> float:
    from repro.tune.sweep import consumer_flops
    compute_s = consumer_flops(collective, MSG_BYTES) / V5E.peak_flops
    return latmodel.e2e_consumer_latency(MSG_BYTES, cfg, compute_s, V5E) * 1e6


def _bench_collective(collective: str, tag: str, cands) -> list:
    import jax
    from repro import compat
    from repro.core.communicator import Communicator
    from repro.tune.db import TuneDB, TuneEntry, select_config, topology_key
    from repro.tune.space import config_to_dict
    from repro.tune import sweep as tune_sweep

    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("x",))
    comm = Communicator.from_mesh(mesh, "x")
    topo = topology_key(mesh)
    db = TuneDB()
    named = {}
    for name, cfg in cands:
        op = tune_sweep._build_op(collective, comm, cfg)
        mkey = tune_sweep._mesh_key(mesh)
        lat_s = tune_sweep._time_program(
            op, mesh, MSG_BYTES, cfg, reps=3, inner=4,
            cache_key=("bench_e2e", topo, mkey, collective,
                       tuple(sorted(config_to_dict(cfg).items())),
                       MSG_BYTES))
        cop, shape = tune_sweep._build_consumer_op(collective, comm, cfg,
                                                   MSG_BYTES)
        e2e_s = tune_sweep._time_program(
            cop, mesh, MSG_BYTES, cfg, reps=3, inner=4, per_dev_shape=shape,
            cache_key=("bench_e2e_consumer", topo, mkey, collective,
                       tuple(sorted(config_to_dict(cfg).items())),
                       MSG_BYTES))
        named[tuple(sorted(config_to_dict(cfg).items()))] = name
        db.add(TuneEntry(topo=topo, collective=collective,
                         msg_bytes=MSG_BYTES, config=config_to_dict(cfg),
                         us_per_call=lat_s * 1e6,
                         gbps=MSG_BYTES / lat_s / 1e9,
                         e2e_us=e2e_s * 1e6))

    def lookup(objective):
        cfg = select_config(collective, MSG_BYTES, db=db, topo=topo,
                            objective=objective)
        key = tuple(sorted(config_to_dict(cfg).items()))
        entry = next(e for e in db.entries
                     if tuple(sorted(e.config.items())) == key)
        return named[key], cfg, entry

    lat_name, lat_cfg, lat_entry = lookup("latency")
    e2e_name, e2e_cfg, e2e_entry = lookup("e2e")
    gain = lat_entry.e2e_us / max(e2e_entry.e2e_us, 1e-9)
    pred_gain = (_predicted_e2e_us(collective, lat_cfg)
                 / max(_predicted_e2e_us(collective, e2e_cfg), 1e-9))
    return [
        (f"e2e_{tag}_lat_winner_us", lat_entry.e2e_us,
         f"{lat_name}_bare{lat_entry.us_per_call:.1f}us_"
         f"pred{_predicted_e2e_us(collective, lat_cfg):.1f}us"),
        (f"e2e_{tag}_e2e_winner_us", e2e_entry.e2e_us,
         f"{e2e_name}_bare{e2e_entry.us_per_call:.1f}us_"
         f"pred{_predicted_e2e_us(collective, e2e_cfg):.1f}us"),
        (f"e2e_gain_{tag}", gain,
         f"e2e_winner={e2e_name}_vs_lat_winner={lat_name}_"
         f"predicted{pred_gain:.2f}x"),
    ]


def run():
    import jax
    if jax.device_count() < 2:
        return [("e2e_objective", 0.0, "skipped_1device")]
    rows = []
    for collective, (tag, cands) in _CONSUMER_SETS.items():
        rows.extend(_bench_collective(collective, tag, cands))
    return rows
