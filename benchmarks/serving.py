"""Serving rows: per-phase auto-config vs a one-config-fits-both serve path.

The tentpole question in benchmark form: serving's two phases consume the
same TP all-reduce with opposite cost structures — decode's tiny
latency-bound per-token combine vs prefill's throughput-bound bulk reduce —
so the config that wins prefill is not necessarily the one decode should
run.  A candidate set is measured under BOTH sweep consumer loops
(``decode_step`` at the decode message size, ``prefill`` at the prefill
message size), the measurements land in one consumer-tagged TuneDB, and
``select_config(consumer=...)`` answers per phase:

- ``srv_decode_auto_us_tok``       — decode-loop µs/iter of decode's own
  (``consumer="decode_step"``) winner;
- ``srv_decode_prefillcfg_us_tok`` — decode-loop µs/iter of the config the
  *prefill* consumer selected (one-config serving's decode cost);
- ``srv_phase_win``                — their ratio (>= 1 by construction:
  decode's winner is the argmin of the decode-loop measurements; 1.0 means
  both phases honestly agree on this host);
- ``srv_tok_s_rank_48``            — tokens/s/rank of the real serving
  decode step (``build_serve_fn(comm="auto")``) on 48 emulated ranks,
  resolving per-phase configs from the DB this process measured;
- ``srv_distinct_48``              — 1.0 when the 48-rank serve path
  resolved DIFFERENT prefill/decode configs from that shared DB.

The 48-rank leg is a subprocess (``--child``) so the emulated device count
is real, not inherited.  New rows ride this PR report-only until a second
committed baseline lands.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

# Decode moves one (batch, d_model) f32 partial per layer; prefill the whole
# prompt's — the message-size axis the phases diverge along.
DEC_MSG = 4 << 10
PRE_MSG = 1 << 20

CHILD_DEVICES = 48
CHILD_STEPS = 6


def _cands():
    from repro.core.config import CommConfig, CommMode, Scheduling
    # One monolithic candidate, one jumbo-chunk streamer, and two overlapped
    # pipelines whose chunk counts differ by phase: at DEC_MSG the 512-byte
    # pipeline pays 8 per-chunk combines for nothing, at PRE_MSG it is the
    # paper's segmented overlap.  The bare all_reduce microbench cannot rank
    # any of them (identical native psum) — only the consumer loops can.
    return (
        ("buffered_fused", CommConfig(mode=CommMode.BUFFERED,
                                      scheduling=Scheduling.FUSED)),
        ("streaming_fused_64k", CommConfig(chunk_bytes=1 << 16)),
        ("streaming_overlap_64k", CommConfig(scheduling=Scheduling.OVERLAPPED,
                                             chunk_bytes=1 << 16)),
        ("streaming_overlap_512", CommConfig(scheduling=Scheduling.OVERLAPPED,
                                             chunk_bytes=512)),
    )


def _measure_db():
    """Measure every candidate under both phase consumers -> (db, named,
    per-phase {config key: e2e µs} tables)."""
    import jax
    from repro import compat
    from repro.core.communicator import Communicator
    from repro.tune.db import TuneDB, TuneEntry, topology_key
    from repro.tune.space import config_to_dict
    from repro.tune import sweep as tune_sweep

    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("x",))
    comm = Communicator.from_mesh(mesh, "x")
    topo = topology_key(mesh)
    mkey = tune_sweep._mesh_key(mesh)
    db = TuneDB()
    named = {}
    e2e = {"decode_step": {}, "prefill": {}}
    for name, cfg in _cands():
        ckey = tuple(sorted(config_to_dict(cfg).items()))
        named[ckey] = name
        for consumer, msg in (("decode_step", DEC_MSG), ("prefill", PRE_MSG)):
            op = tune_sweep._build_op("all_reduce", comm, cfg)
            lat_s = tune_sweep._time_program(
                op, mesh, msg, cfg, reps=3, inner=4,
                cache_key=("bench_srv", topo, mkey, "all_reduce", ckey, msg))
            cop, shape = tune_sweep._build_consumer_op(
                "all_reduce", comm, cfg, msg, consumer=consumer)
            e2e_s = tune_sweep._time_program(
                cop, mesh, msg, cfg, reps=3, inner=4, per_dev_shape=shape,
                cache_key=("bench_srv_consumer", topo, mkey, "all_reduce",
                           consumer, ckey, msg))
            e2e[consumer][ckey] = e2e_s * 1e6
            db.add(TuneEntry(topo=topo, collective="all_reduce",
                             msg_bytes=msg, config=config_to_dict(cfg),
                             us_per_call=lat_s * 1e6,
                             gbps=msg / lat_s / 1e9,
                             e2e_us=e2e_s * 1e6, consumer=consumer))
    return db, named, e2e


def _select(db, consumer: str, msg: int):
    from repro.tune.db import select_config, topology_key
    from repro.tune.space import config_to_dict
    cfg = select_config("all_reduce", msg, db=db, topo=topology_key(),
                        objective="e2e", consumer=consumer)
    return cfg, tuple(sorted(config_to_dict(cfg).items()))


def _child_rows(db) -> list:
    """Resolve per-phase configs and decode for real on 48 emulated ranks."""
    with tempfile.TemporaryDirectory(prefix="repro-srv-bench-") as td:
        db_path = os.path.join(td, "tunedb.json")
        db.save(db_path)
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{CHILD_DEVICES}")
        repo = Path(__file__).resolve().parents[1]
        env["PYTHONPATH"] = str(repo / "src")
        proc = subprocess.run(
            [sys.executable, str(Path(__file__).resolve()), "--child",
             db_path], capture_output=True, text=True, timeout=560, env=env,
            cwd=str(repo))
    if proc.returncode != 0:
        raise RuntimeError(f"48-rank serve child failed (rc="
                           f"{proc.returncode}): {proc.stderr[-500:]}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    return [
        ("srv_tok_s_rank_48", out["tok_s_rank"],
         f"decode{out['decode_cfg']}_steps{CHILD_STEPS}"
         f"_ranks{CHILD_DEVICES}"),
        ("srv_distinct_48", 1.0 if out["distinct"] else 0.0,
         f"prefill{out['prefill_cfg']}_decode{out['decode_cfg']}"),
    ]


def _child(db_path: str) -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_smoke_config
    from repro.launch import input_specs as isp, setup
    from repro.train import serve as serve_mod

    n = jax.device_count()
    mesh = jax.make_mesh((n // 4, 4), ("data", "model"))
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"),
                              dtype=jnp.float32)
    B, prompt, gen = n // 4, 8, CHILD_STEPS
    shape_p = isp.ShapeSpec("serve", prompt, B, "prefill")
    shape_d = isp.ShapeSpec("serve", prompt + gen, B, "decode")
    sess = setup.build_session(cfg, mesh, serve_mod.resolve_serve_comm(
        cfg, mesh, "auto", shape_d, tune_db_path=db_path), concrete=True)
    rt_p, prefill_fn, _ = serve_mod.build_serve_fn(
        cfg, mesh, "auto", shape_p, tune_db_path=db_path,
        cache_capacity=serve_mod.cache_len(cfg, shape_d))
    rt_d, decode_fn, _ = serve_mod.build_serve_fn(
        cfg, mesh, "auto", shape_d, tune_db_path=db_path)

    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (B, prompt)).astype(np.int32))
    state = jax.block_until_ready(prefill_fn(sess.params, {"tokens": toks}))
    tok = jnp.argmax(state.last_logits, axis=-1).astype(jnp.int32)
    state = jax.block_until_ready(decode_fn(sess.params, tok, state))  # warm
    t0 = time.perf_counter()
    for _ in range(CHILD_STEPS):
        tok = jnp.argmax(state.last_logits, axis=-1).astype(jnp.int32)
        state = decode_fn(sess.params, tok, state)
    jax.block_until_ready(state.last_logits)
    wall = time.perf_counter() - t0

    def tag(c):
        return f"[{c.mode.value}/{c.scheduling.value}/chunk{c.chunk_bytes}]"

    print(json.dumps({
        "prefill_cfg": tag(rt_p.comm), "decode_cfg": tag(rt_d.comm),
        "distinct": rt_p.comm != rt_d.comm,
        "tok_s_rank": B * CHILD_STEPS / wall / n}))


def run():
    import jax
    if jax.device_count() < 4:
        return [("srv", 0.0, "skipped_lt4devices")]
    db, named, e2e = _measure_db()
    _, dec_key = _select(db, "decode_step", DEC_MSG)
    _, pre_key = _select(db, "prefill", PRE_MSG)
    dec_auto = e2e["decode_step"][dec_key]
    dec_under_pre = e2e["decode_step"][pre_key]
    rows = [
        ("srv_decode_auto_us_tok", dec_auto, f"winner_{named[dec_key]}"),
        ("srv_decode_prefillcfg_us_tok", dec_under_pre,
         f"prefill_winner_{named[pre_key]}"),
        ("srv_phase_win", dec_under_pre / max(dec_auto, 1e-9),
         f"decode={named[dec_key]}_vs_prefill={named[pre_key]}"),
    ]
    rows.extend(_child_rows(db))
    return rows


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--child":
        _child(sys.argv[2])
    else:
        for r in run():
            print(r)
