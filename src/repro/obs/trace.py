"""Low-overhead comm-event span tracer with Chrome ``trace_event`` export.

The paper's argument rests on *seeing* where communication time goes — the
per-configuration breakdowns of Figs. 9–11 and the per-edge behavior at 48
FPGAs.  This module is the software analogue: every layer of the comm stack
(collective entry points, wire chunks, driver phases, watchdog events) emits
spans into a thread-safe ring buffer, exported as Chrome ``trace_event`` JSON
viewable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Enable with the ``REPRO_TRACE`` environment variable:

- unset / ``0`` — disabled (the default).  :func:`span` returns a shared
  no-op context manager and :func:`instant` returns immediately: the
  instrumented code paths are byte-for-byte the seed behavior, no events
  are recorded, and no buffer exists (asserted by ``tests/test_obs.py``).
- ``1``        — collect spans in memory (read back via :func:`events`).
- ``chrome:<path>`` — collect and export to ``<path>`` at process exit
  (or on an explicit :func:`flush`).

Span semantics: JAX traces an SPMD program once, so spans emitted inside
``shard_map``/``jit`` (collective and wire-chunk layers) measure *schedule
construction* — they record the structure the program will execute (one span
per exchange round, per wire chunk, with hop distances and byte counts),
once per compilation.  Host-level spans (sweep candidates, driver segments,
watchdog steps) measure real wall clock.  Both land on the same timeline;
the ``cat`` field tells them apart (``collective``/``wire`` = trace-time
structure, ``sweep``/``driver``/``watchdog`` = wall time).

Tracks: ``rank=`` (when the caller knows it) maps to a Chrome ``pid`` so
per-rank activity renders as separate process tracks; host threads map to
``tid`` within a track, and nested ``with span(...)`` blocks on one thread
nest by time containment — per-round spans sit inside their collective's
span, per-chunk spans inside their round's.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from collections import deque
from typing import Any, Optional

ENV_VAR = "REPRO_TRACE"
DEFAULT_CAPACITY = 1 << 16


def _jsonable(v: Any):
    """Clamp span args to JSON-serializable scalars (enums and arbitrary
    objects stringify — args must never hold live tracers or arrays)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    value = getattr(v, "value", None)   # enums carry their value
    if isinstance(value, (bool, int, float, str)):
        return value
    return str(v)


class Tracer:
    """Thread-safe ring buffer of Chrome trace events.

    The buffer is bounded (``capacity`` events); overflow drops the oldest
    event and counts it, so a long-running service can leave tracing on
    without unbounded growth — the export carries the drop count.
    """

    def __init__(self, sink: Optional[str] = None,
                 capacity: int = DEFAULT_CAPACITY):
        self.sink = sink
        self.capacity = capacity
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._dropped = 0
        self._t0 = time.perf_counter()
        self._tids: dict[int, int] = {}

    # ------------------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self._dropped += 1
            self._events.append(ev)

    def complete(self, name: str, cat: str, ts_us: float, dur_us: float,
                 rank: Optional[int], args: dict) -> None:
        self.emit({"name": name, "cat": cat, "ph": "X",
                   "ts": round(ts_us, 3), "dur": round(dur_us, 3),
                   "pid": 0 if rank is None else int(rank) + 1,
                   "tid": self._tid(),
                   "args": {k: _jsonable(v) for k, v in args.items()}})

    def instant(self, name: str, cat: str, rank: Optional[int],
                args: dict) -> None:
        self.emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                   "ts": round(self.now_us(), 3),
                   "pid": 0 if rank is None else int(rank) + 1,
                   "tid": self._tid(),
                   "args": {k: _jsonable(v) for k, v in args.items()}})

    # ------------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def to_chrome(self) -> dict:
        """The full Chrome ``trace_event`` payload: process-name metadata for
        every track, then the buffered events in emission order."""
        evs = self.events()
        pids = sorted({e["pid"] for e in evs})
        meta = [{"name": "process_name", "ph": "M", "pid": p, "tid": 0,
                 "args": {"name": "host" if p == 0 else f"rank {p - 1}"}}
                for p in pids]
        payload = {"traceEvents": meta + evs, "displayTimeUnit": "ms"}
        if self._dropped:
            payload["otherData"] = {"dropped_events": self._dropped}
        return payload

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ----------------------------------------------------------------------
# Module-level gate: one global tracer (or None = disabled)
# ----------------------------------------------------------------------

_TRACER: Optional[Tracer] = None
_ATEXIT_REGISTERED = False


class _NullSpan:
    """Shared no-op context manager — the guaranteed-cheap disabled path.
    ``span()`` returns this singleton when tracing is off: no allocation,
    no clock read, no buffer append."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records wall time between ``__enter__``/``__exit__``
    and emits a Chrome complete ("X") event.  ``set(**args)`` attaches
    results known only after the timed region (e.g. the measured latency)."""
    __slots__ = ("_tracer", "name", "cat", "rank", "args", "_ts")

    def __init__(self, tracer: Tracer, name: str, cat: str,
                 rank: Optional[int], args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.rank = rank
        self.args = args
        self._ts = 0.0

    def __enter__(self):
        self._ts = self._tracer.now_us()
        return self

    def __exit__(self, *exc):
        self._tracer.complete(self.name, self.cat, self._ts,
                              self._tracer.now_us() - self._ts,
                              self.rank, self.args)
        return False

    def set(self, **args):
        self.args.update(args)
        return self


def configure(mode: Optional[str] = None) -> Optional[Tracer]:
    """(Re)configure the global tracer from ``mode`` (or the ``REPRO_TRACE``
    env var when ``mode`` is None).  Returns the active tracer or None.
    Safe to call at runtime — tests toggle tracing on and off with it."""
    global _TRACER, _ATEXIT_REGISTERED
    if mode is None:
        mode = os.environ.get(ENV_VAR, "0")
    mode = (mode or "0").strip()
    if mode in ("", "0"):
        _TRACER = None
        return None
    sink = mode[len("chrome:"):] if mode.startswith("chrome:") else None
    if mode != "1" and sink is None:
        raise ValueError(f"{ENV_VAR} must be 0, 1, or chrome:<path>, "
                         f"got {mode!r}")
    _TRACER = Tracer(sink=sink)
    if sink and not _ATEXIT_REGISTERED:
        atexit.register(flush)
        _ATEXIT_REGISTERED = True
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def mode() -> Optional[str]:
    """The active trace mode: None (off), "1", or "chrome:<path>"."""
    t = _TRACER
    if t is None:
        return None
    return f"chrome:{t.sink}" if t.sink else "1"


def tracer() -> Optional[Tracer]:
    return _TRACER


def span(name: str, cat: str = "comm", rank: Optional[int] = None, **args):
    """Context manager timing one region; no-op singleton when disabled.

    ::

        with trace.span("sendrecv", cat="collective", hops=2, nbytes=65536):
            ...                                   # traced region
        with trace.span("sweep.candidate", cat="sweep") as sp:
            sec = measure(...)
            sp.set(us_per_call=sec * 1e6)         # late-bound results
    """
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, cat, rank, args)


def instant(name: str, cat: str = "comm", rank: Optional[int] = None,
            **args) -> None:
    """Zero-duration instant event (watchdog stragglers, checkpoint marks)."""
    t = _TRACER
    if t is not None:
        t.instant(name, cat, rank, args)


def traced(name: Optional[str] = None, cat: str = "comm", **attrs):
    """Decorator form of :func:`span`; enablement is checked per call, so a
    function decorated while tracing is off still emits spans after a later
    :func:`configure`."""
    import functools

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **k):
            t = _TRACER
            if t is None:
                return fn(*a, **k)
            with _Span(t, label, cat, None, dict(attrs)):
                return fn(*a, **k)
        return wrapper
    return deco


def events() -> list[dict]:
    """The buffered events (tests and in-process consumers); [] when off."""
    t = _TRACER
    return t.events() if t is not None else []


def clear() -> None:
    t = _TRACER
    if t is not None:
        t.clear()


def flush() -> Optional[str]:
    """Export to the configured ``chrome:<path>`` sink (no-op otherwise).
    Registered via atexit when a sink is configured, so any CLI run with
    ``REPRO_TRACE=chrome:trace.json`` leaves a loadable trace behind."""
    t = _TRACER
    if t is not None and t.sink:
        return t.export_chrome(t.sink)
    return None


# Read the env gate once at import; tests reconfigure at runtime.
configure()
