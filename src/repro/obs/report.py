"""Trace report CLI — per-edge / per-collective latency tables from a trace.

The software analogue of the paper's Fig. 9 per-configuration breakdown:
load a Chrome ``trace_event`` JSON exported by :mod:`repro.obs.trace`
(``REPRO_TRACE=chrome:trace.json``) and print, per collective and per torus
hop distance, the span statistics (count, mean, p50/p95, max).

::

    PYTHONPATH=src python -m repro.obs.report trace.json
    PYTHONPATH=src python -m repro.obs.report trace.json --cat wire
    PYTHONPATH=src python -m repro.obs.report trace.json --json

Sections:

- **per-edge collectives** — ``cat=collective`` spans grouped by
  ``(name, args.hops)``: the per-edge latency table (hop distances match the
  :class:`~repro.core.topology.TorusSpec` the run was placed on).
- **wire chunks** — ``cat=wire`` spans grouped by name.
- **phases** — driver/step phase spans (``cat`` in phase/driver/sweep).
- **watchdog** — instant events (straggler marks) with a count per name.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Optional, Sequence


def load_trace(path: str) -> list[dict]:
    """Load and minimally validate a Chrome trace_event file; returns the
    event list (raises ValueError on a malformed payload)."""
    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError(f"{path}: not a Chrome trace_event file "
                         f"(no traceEvents key)")
    evs = payload["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    for e in evs:
        if not isinstance(e, dict) or "ph" not in e or "name" not in e:
            raise ValueError(f"{path}: malformed event {e!r}")
    return evs


def _percentile(sorted_vals: Sequence[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = p / 100.0 * (len(sorted_vals) - 1)
    lo = int(idx)
    hi = min(lo + 1, len(sorted_vals) - 1)
    return sorted_vals[lo] + (idx - lo) * (sorted_vals[hi] - sorted_vals[lo])


def _stats_row(durs: list[float]) -> dict:
    s = sorted(durs)
    return {"count": len(s), "total_us": sum(s),
            "mean_us": sum(s) / len(s),
            "p50_us": _percentile(s, 50), "p95_us": _percentile(s, 95),
            "max_us": s[-1]}


def summarize(events: Sequence[dict], cat: Optional[str] = None) -> dict:
    """Aggregate complete spans (and count instants) into report tables."""
    spans = [e for e in events if e.get("ph") == "X"
             and (cat is None or e.get("cat") == cat)]
    instants = [e for e in events if e.get("ph") == "i"
                and (cat is None or e.get("cat") == cat)]

    per_edge: dict[tuple, list[float]] = defaultdict(list)
    per_name: dict[tuple, list[float]] = defaultdict(list)
    for e in spans:
        args = e.get("args", {}) or {}
        key = (e.get("cat", ""), e["name"])
        per_name[key].append(float(e.get("dur", 0.0)))
        if e.get("cat") == "collective" and "hops" in args:
            per_edge[(e["name"], int(args["hops"]))].append(
                float(e.get("dur", 0.0)))

    inst_counts: dict[tuple, int] = defaultdict(int)
    for e in instants:
        inst_counts[(e.get("cat", ""), e["name"])] += 1

    return {
        "per_edge": {f"{name}@h{hops}": dict(_stats_row(d), hops=hops,
                                             collective=name)
                     for (name, hops), d in sorted(per_edge.items())},
        "per_name": {f"{c}:{n}": dict(_stats_row(d), cat=c, name=n)
                     for (c, n), d in sorted(per_name.items())},
        "instants": {f"{c}:{n}": v
                     for (c, n), v in sorted(inst_counts.items())},
    }


def _print_table(title: str, rows: dict, key_header: str, out) -> None:
    if not rows:
        return
    print(f"\n{title}", file=out)
    width = max(len(k) for k in rows)
    width = max(width, len(key_header))
    print(f"{key_header:<{width}}  {'count':>6} {'mean us':>10} "
          f"{'p50 us':>10} {'p95 us':>10} {'max us':>10}", file=out)
    for k, r in rows.items():
        print(f"{k:<{width}}  {r['count']:>6d} {r['mean_us']:>10.1f} "
              f"{r['p50_us']:>10.1f} {r['p95_us']:>10.1f} "
              f"{r['max_us']:>10.1f}", file=out)


def report(events: Sequence[dict], cat: Optional[str] = None,
           out=None) -> dict:
    """Print the latency tables; returns the aggregated dict."""
    out = out if out is not None else sys.stdout
    agg = summarize(events, cat=cat)
    _print_table("per-edge collective latency (hop distances from the "
                 "virtual torus placement)", agg["per_edge"],
                 "collective@hops", out)
    coll = {k: v for k, v in agg["per_name"].items()
            if v["cat"] == "collective"}
    _print_table("collective spans", coll, "collective", out)
    wire = {k: v for k, v in agg["per_name"].items() if v["cat"] == "wire"}
    _print_table("wire chunk spans", wire, "wire", out)
    phase = {k: v for k, v in agg["per_name"].items()
             if v["cat"] in ("phase", "driver", "sweep", "train")}
    _print_table("driver / phase spans", phase, "phase", out)
    if agg["instants"]:
        print("\ninstant events", file=out)
        for k, v in agg["instants"].items():
            print(f"{k:<40s}  {v:>6d}", file=out)
    n_spans = sum(r["count"] for r in agg["per_name"].values())
    n_inst = sum(agg["instants"].values())
    cats = sorted({v["cat"] for v in agg["per_name"].values()}
                  | {k.split(":", 1)[0] for k in agg["instants"]})
    print(f"\n{n_spans} spans + {n_inst} instants across layers: "
          f"{', '.join(cats) if cats else '(none)'}", file=out)
    return agg


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Per-edge / per-collective latency tables from a "
                    "REPRO_TRACE=chrome:<path> export.")
    ap.add_argument("trace", help="Chrome trace_event JSON file")
    ap.add_argument("--cat", default=None,
                    help="restrict to one span category "
                    "(collective, wire, phase, driver, sweep, watchdog)")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregated tables as JSON instead")
    args = ap.parse_args(argv)
    try:
        events = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"repro.obs.report: {e}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(summarize(events, cat=args.cat), indent=1,
                         sort_keys=True))
        return 0
    report(events, cat=args.cat)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
