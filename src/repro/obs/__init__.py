"""ACCL-X observability: comm-event tracing + metrics.

The telemetry substrate under the comm stack — what lets you *see* where
communication time goes (the paper's per-configuration/per-edge breakdowns,
ACCL+'s collective-engine timing feed):

- :mod:`repro.obs.trace`   — low-overhead span tracer (``REPRO_TRACE`` env
  gate, thread-safe ring buffer, Chrome ``trace_event`` export for
  Perfetto).  Instrumented through every layer: collectives, wire chunks,
  driver phases, sweep candidates, watchdog events.
- :mod:`repro.obs.metrics` — always-on registry of counters, gauges, and
  fixed-bucket latency histograms (plan-cache hit/miss, bytes per edge,
  rounds per exchange, sweep candidates pruned, straggler events).
- :mod:`repro.obs.report`  — ``python -m repro.obs.report trace.json``
  prints per-edge / per-collective latency tables from an exported trace.
"""
from repro.obs import metrics, trace
from repro.obs.metrics import registry
from repro.obs.trace import configure, enabled, events, flush, instant, span

__all__ = ["configure", "enabled", "events", "flush", "instant", "metrics",
           "registry", "span", "trace"]
