"""Metrics registry: counters, gauges, and fixed-bucket latency histograms.

The always-on half of the observability substrate (tracing is opt-in, a
counter bump is a dict lookup + integer add): the plan cache's hit/miss
counters (including the disk tier's ``plans.disk_hits`` / ``disk_misses`` /
``disk_writes`` / ``disk_corrupt``), per-edge byte counters, exchange round
counts, sweep latency
histograms, and the watchdog's straggler/dropped-event counters all live
here.  ACCL+ exposes per-collective timing from its collective engine to
drive tuning; this registry is that feed for ACCL-X — ``snapshot()`` is what
a scraper (or the sweep summary, or the elastic runtime's re-selection
policy) reads.

Conventions:

- Names are dotted paths (``plans.plan_hits``, ``comm.edge_bytes``).
- Optional labels distinguish series of one name
  (``counter("comm.edge_bytes", hops=2)``); the snapshot renders them as
  ``name{hops=2}``.
- Histograms use fixed log-spaced bucket bounds (1-2-5 per decade over
  0.1 us .. 100 s by default) and report p50/p95/p99 by linear
  interpolation inside the bucket — O(1) memory however many observations.

Everything is host-side pure Python (no jax imports), so the comm core can
depend on it without layering cycles.
"""
from __future__ import annotations

import bisect
import threading
from typing import Optional, Sequence

_LOCK = threading.RLock()


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render(name: str, lk: tuple) -> str:
    if not lk:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in lk) + "}"


def parse_labels(rendered: str) -> tuple[str, dict]:
    """Invert :func:`_render`: ``"comm.edge_bytes{hops=2}"`` ->
    ``("comm.edge_bytes", {"hops": "2"})``.  The decoder consumers of
    ``Registry.find``/``snapshot`` use to get label values back out of a
    series name (e.g. the DegradationMonitor splitting per-hop traffic)."""
    if "{" not in rendered:
        return rendered, {}
    name, _, body = rendered.partition("{")
    labels = {}
    for pair in body.rstrip("}").split(","):
        if not pair:
            continue
        k, _, v = pair.partition("=")
        labels[k] = v
    return name, labels


class Counter:
    """Monotonic (between resets) integer/float counter."""
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n=1) -> None:
        with _LOCK:
            self._value += n

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0


class Gauge:
    """Last-write-wins scalar (queue depths, current config ids)."""
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0.0


def default_bounds() -> tuple[float, ...]:
    """1-2-5 series per decade, 0.1 .. 1e8 (microsecond latencies from
    100 ns to 100 s when observations are in us)."""
    bounds = []
    decade = 0.1
    while decade < 1e8:
        for m in (1.0, 2.0, 5.0):
            bounds.append(decade * m)
        decade *= 10.0
    return tuple(bounds)


_DEFAULT_BOUNDS = default_bounds()


class Histogram:
    """Fixed-bucket histogram with interpolated percentile summaries."""
    __slots__ = ("name", "bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else _DEFAULT_BOUNDS
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram bounds must be sorted")
        self.counts = [0] * (len(self.bounds) + 1)   # +1 overflow bucket
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        with _LOCK:
            self.counts[bisect.bisect_left(self.bounds, v)] += 1
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)

    def percentile(self, p: float) -> float:
        """Interpolated percentile (``p`` in [0, 100]) from the buckets,
        clamped to the observed min/max."""
        with _LOCK:
            if self.count == 0:
                return 0.0
            target = p / 100.0 * self.count
            seen = 0.0
            for i, c in enumerate(self.counts):
                if c == 0:
                    continue
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                if seen + c >= target:
                    frac = (target - seen) / c
                    v = lo + frac * (max(hi, lo) - lo)
                    return min(max(v, self.vmin), self.vmax)
                seen += c
            return self.vmax

    def summary(self) -> dict:
        with _LOCK:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "p50": 0.0, "p95": 0.0, "p99": 0.0,
                        "min": 0.0, "max": 0.0}
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "min": self.vmin, "max": self.vmax}

    def reset(self) -> None:
        with _LOCK:
            self.counts = [0] * (len(self.bounds) + 1)
            self.count = 0
            self.total = 0.0
            self.vmin = float("inf")
            self.vmax = float("-inf")


class Registry:
    """Get-or-create store of named instruments.

    One global instance (:func:`registry`) serves the whole process; tests
    may build private registries.  Type mismatches on an existing name raise
    — a counter never silently shadows a histogram.
    """

    def __init__(self):
        self._instruments: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (cls.__name__, name, _label_key(labels))
        with _LOCK:
            inst = self._instruments.get(key)
            if inst is None:
                other = next((k for k in self._instruments
                              if k[1:] == key[1:]), None)
                if other is not None:
                    raise TypeError(
                        f"{_render(name, key[2])} already registered as "
                        f"{other[0]}, requested {cls.__name__}")
                inst = cls(_render(name, key[2]), **kw)
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def snapshot(self) -> dict:
        """``{rendered_name: value-or-summary}`` for every instrument."""
        with _LOCK:
            items = list(self._instruments.values())
        out = {}
        for inst in items:
            if isinstance(inst, Histogram):
                out[inst.name] = inst.summary()
            else:
                out[inst.name] = inst.value
        return out

    def find(self, prefix: str) -> dict:
        """Snapshot restricted to names starting with ``prefix``."""
        return {k: v for k, v in self.snapshot().items()
                if k.startswith(prefix)}

    def reset(self) -> None:
        with _LOCK:
            items = list(self._instruments.values())
        for inst in items:
            inst.reset()


def percentile_of(samples: Sequence[float], p: float,
                  bounds: Optional[Sequence[float]] = None) -> float:
    """Interpolated percentile of a raw sample list, computed through the
    same fixed-bucket machinery the registry histograms use — so a
    per-candidate tail estimate (the sweep's ``TuneEntry.p95_us``) agrees
    bucket-for-bucket with the aggregate ``sweep.us`` series.  Empty input
    returns 0.0 (the "no tail data" sentinel ``TuneDB._rank`` respects)."""
    h = Histogram("adhoc", bounds=bounds)
    for v in samples:
        h.observe(v)
    return h.percentile(p)


_REGISTRY = Registry()


def registry() -> Registry:
    """The process-global registry every subsystem publishes into."""
    return _REGISTRY
