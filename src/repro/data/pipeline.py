"""Deterministic, shardable synthetic data pipeline.

Design mirrors a production loader:
- every (step, host) pair maps to a deterministic slice of the global batch —
  restart-safe (resume from any step without replaying) and elastic-safe
  (re-sharding after a topology change yields the same global stream);
- a background prefetch thread keeps ``prefetch`` batches ready so a slow
  host (straggler) overlaps data production with device compute;
- the token stream is a mixture of repeated n-gram "documents" so the LM loss
  actually decreases during the example runs (unlike iid-random tokens).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_documents: int = 512       # distinct synthetic documents
    ngram_order: int = 3
    prefetch: int = 2


class SyntheticLM:
    """Order-k Markov synthetic corpus with deterministic per-step access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        # ONE corpus-wide transition permutation (an order-1 Markov chain the
        # model can learn as a big lookup); documents differ by start state.
        self._k = min(4096, cfg.vocab_size)
        self._succ = rng.permutation(self._k)
        self._doc_starts = rng.randint(0, self._k, size=cfg.n_documents)

    def _document_tokens(self, doc: int, length: int, offset: int) -> np.ndarray:
        # order-1 Markov walk: t_{i+1} = succ(t_i) — exactly learnable, so
        # example losses genuinely decrease.
        state = int((self._doc_starts[doc % len(self._doc_starts)] + offset)
                    % self._k)
        out = np.empty(length, np.int64)
        for i in range(length):
            out[i] = state
            state = self._succ[state]
        return out.astype(np.int32)

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        """The deterministic (host-sharded) batch for a global step."""
        cfg = self.cfg
        per_host = cfg.global_batch // n_hosts
        rows = []
        for i in range(per_host):
            global_row = host_id * per_host + i
            doc = (step * cfg.global_batch + global_row) % cfg.n_documents
            offset = (step * 17 + global_row * 31) % 4096
            rows.append(self._document_tokens(doc, cfg.seq_len + 1, offset))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


class PrefetchLoader:
    """Background-thread prefetcher over SyntheticLM (or any batch_at)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.source = source
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._q: queue.Queue = queue.Queue(maxsize=source.cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self.host_id, self.n_hosts)
            batch["_step"] = step
            try:
                self._q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
