"""Model-based config re-selection for the elastic runtime — no cold resweep.

When the fabric changes under a running job (a rank dies and the survivors
re-form on a smaller torus; a link degrades and routes lengthen), the
previously selected CommConfigs are stale: they were measured at hop
distances and link costs that no longer exist.  The paper's answer to "which
config is fastest *here*?" is a sweep — but a sweep mid-recovery costs
seconds to minutes of wall clock exactly when the job is down.  This module
is the cheap path: **extrapolate the calibrated Eq. 1 model over the TuneDB**
instead of re-measuring.

:func:`model_reselect` fits the Eq. 1 constants from the DB's existing
measurements (:func:`repro.tune.prune.calibration_from_db` →
``fit_latency_model``), then re-ranks every config the DB has *ever measured*
for the collective at the **new** hop distance / link slowdown, and returns
the predicted winner.  No microbenchmark runs; the only inputs are the fitted
constants and the new fabric's geometry.  Recovery-time selection is
milliseconds instead of a resweep, and tests assert ``sweep.runs`` stays flat
across it.

A degraded link is priced by scaling the calibration's wire constants
(``link_bw / slowdown``, ``hop_latency * slowdown``): the model then reorders
candidates the same way the physical hold-round emulation slows them down.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.config import (CommConfig, OPTIMIZED_CONFIG, Reliability,
                               Scheduling)
from repro.obs import metrics as obs_metrics
from repro.tune.calibrate import CalibrationResult
from repro.tune.db import TuneDB, select_config
from repro.tune.prune import (calibration_from_db, predicted_e2e,
                              predicted_latency)
from repro.tune.space import config_from_dict


def degraded_calibration(calibration: CalibrationResult,
                         slowdown: float) -> CalibrationResult:
    """The calibrated substrate with one link's slowdown priced in: wire
    bandwidth divided and per-hop latency multiplied by ``slowdown``."""
    s = max(1.0, float(slowdown))
    if s == 1.0:
        return calibration
    return dataclasses.replace(calibration,
                               link_bw=calibration.link_bw / s,
                               hop_latency=calibration.hop_latency * s)


def _measured_configs(db: TuneDB, collective: str) -> list[CommConfig]:
    """Every distinct config the DB has measured for ``collective`` (any
    size / hop distance / torus) — the re-selection candidate set.  Only
    measured configs are candidates: the model interpolates constants, not
    trust — a config nobody ever ran should not win on extrapolation alone."""
    seen: dict[tuple, CommConfig] = {}
    for e in db.entries:
        if e.collective != collective:
            continue
        key = tuple(sorted(e.config.items()))
        if key not in seen:
            seen[key] = config_from_dict(e.config)
    return list(seen.values())


def model_reselect(collective: str, msg_bytes: int, *,
                   db: TuneDB,
                   hops: int = 1,
                   objective: str = "latency",
                   compute_s: float = 0.0,
                   link_slowdown: float = 1.0,
                   loss: float = 0.0,
                   calibration: Optional[CalibrationResult] = None,
                   topo: Optional[str] = None,
                   fallback: CommConfig = OPTIMIZED_CONFIG) -> CommConfig:
    """Re-select a config for a fabric the sweep never measured.

    Fits (or reuses) the Eq. 1 calibration from ``db``, prices every config
    the DB measured for ``collective`` at the new ``hops`` / ``msg_bytes`` /
    ``link_slowdown``, and returns the predicted winner.  Falls back to the
    measured :func:`~repro.tune.db.select_config` lookup when the DB is too
    cold to calibrate (< 2 points) — still no sweep, just nearest-measured.

    ``objective="e2e"`` ranks by the consumer-loop prediction with
    ``compute_s`` of hideable compute (Eq. 2), mirroring the sweep's own
    ``--objective e2e``.

    ``loss`` > 0 re-selects for a LOSSY wire: every candidate is promoted
    to ``Reliability.GUARANTEED`` (best-effort delivery cannot survive
    chunk loss) and priced with the Eq. 1 retransmit surcharge — which is
    what flips the winner from jumbo frames to small segments when the
    fabric starts dropping chunks.  Measured-DB fallbacks prefer entries
    swept at a matching loss rate.
    """
    if objective not in ("latency", "e2e"):
        raise ValueError(f"objective must be 'latency' or 'e2e', "
                         f"got {objective!r}")
    reg = obs_metrics.registry()
    reg.counter("tune.model_reselects", collective=collective).inc()
    if calibration is None:
        calibration = calibration_from_db(db, topo)
    loss = max(0.0, float(loss))
    loss_pref = loss if loss > 0.0 else None
    if calibration is None:
        # Cold DB: nothing to fit.  Nearest-measured lookup (or the paper's
        # OPTIMIZED_CONFIG on a fully cold cache) — never a sweep.
        reg.counter("tune.reselect_cold_fallbacks").inc()
        return _harden(select_config(collective, msg_bytes, db=db, topo=topo,
                                     hops=hops, objective=objective,
                                     loss=loss_pref, fallback=fallback), loss)
    cands = _measured_configs(db, collective)
    if loss > 0.0:
        promoted, seen = [], set()
        for c in cands:
            g = dataclasses.replace(c, reliability=Reliability.GUARANTEED)
            if g not in seen:
                seen.add(g)
                promoted.append(g)
        cands = promoted
    if not cands:
        reg.counter("tune.reselect_cold_fallbacks").inc()
        return _harden(select_config(collective, msg_bytes, db=db, topo=topo,
                                     hops=hops, objective=objective,
                                     loss=loss_pref, fallback=fallback), loss)
    cal = degraded_calibration(calibration, link_slowdown)
    hops = max(1, int(hops))
    if objective == "e2e":
        preds = [predicted_e2e(c, msg_bytes, cal, compute_s, collective,
                               hops=hops, loss=loss) for c in cands]
    else:
        preds = [predicted_latency(c, msg_bytes, cal, collective, hops=hops,
                                   loss=loss) for c in cands]
    return cands[min(range(len(cands)), key=preds.__getitem__)]


def _harden(cfg: CommConfig, loss: float) -> CommConfig:
    """Promote a fallback-selected config to guaranteed delivery when the
    wire is lossy — the selection layer must never hand a best-effort
    config to a fabric that drops chunks."""
    if loss > 0.0 and cfg.reliability != Reliability.GUARANTEED:
        return dataclasses.replace(cfg, reliability=Reliability.GUARANTEED)
    return cfg


def reselect_round_configs(rounds: Sequence[Sequence[tuple]], comm,
                           msg_bytes: int, *,
                           db: TuneDB,
                           objective: str = "latency",
                           compute_s: float = 0.0,
                           loss: float = 0.0,
                           calibration: Optional[CalibrationResult] = None,
                           topo: Optional[str] = None,
                           fallback: CommConfig = OPTIMIZED_CONFIG
                           ) -> tuple[CommConfig, Optional[list[CommConfig]]]:
    """Model-reselect a whole exchange pattern on a new/degraded fabric.

    The elastic twin of the SWE driver's per-round selection: one config per
    exchange round at that round's worst-case hop distance **and** worst
    traversed link slowdown (degraded hops re-rank candidates the same way
    longer routes do), all priced by the calibrated model.  Returns
    ``(representative_cfg, round_cfgs-or-None)`` with the same conventions as
    ``build_simulation``: the representative is the worst-hop round's winner,
    per-round configs share its scheduling discipline, and ``None`` means the
    uniform config is already right for every round.
    """
    spec = getattr(comm, "topo", None)
    if calibration is None:
        calibration = calibration_from_db(db, topo)

    def round_slowdown(perm) -> float:
        if spec is None or not getattr(spec, "link_slowdowns", None):
            return 1.0
        from repro.core.topology import route
        worst = 1.0
        for s, d in perm:
            if s == d:
                continue
            path = route(spec, int(s), int(d))
            for i in range(len(path) - 1):
                worst = max(worst, spec.link_slowdown(path[i], path[i + 1]))
        return worst

    per_round = []
    worst_key = (0, 1.0)
    for perm in rounds:
        hops = max(1, comm.max_hops(perm))
        slow = round_slowdown(perm)
        cfg = model_reselect("multi_neighbor", msg_bytes, db=db, hops=hops,
                             objective=objective, compute_s=compute_s,
                             link_slowdown=slow, loss=loss,
                             calibration=calibration,
                             topo=topo, fallback=fallback)
        per_round.append(cfg)
        worst_key = max(worst_key, (hops, slow))

    if not per_round:
        rep = model_reselect("multi_neighbor", msg_bytes, db=db, hops=1,
                             objective=objective, compute_s=compute_s,
                             loss=loss, calibration=calibration, topo=topo,
                             fallback=fallback)
        return rep, None
    # Representative = the worst (hops, slowdown) round's winner; unify
    # scheduling so the step keeps one discipline (as build_simulation does).
    worst_i = max(range(len(rounds)),
                  key=lambda i: (max(1, comm.max_hops(rounds[i])),
                                 round_slowdown(rounds[i])))
    rep = per_round[worst_i]
    if rep.scheduling == Scheduling.OVERLAPPED:
        # The double-buffered engine pipelines all rounds under one config.
        return rep, None
    per_round = [dataclasses.replace(c, scheduling=rep.scheduling)
                 for c in per_round]
    if all(c == rep for c in per_round):
        return rep, None
    return rep, per_round
