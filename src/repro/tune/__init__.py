"""ACCL-X autotuner — measured configuration-space search for CommConfig.

The paper's method is exactly this loop: sweep the communication framework's
configuration space with synthetic microbenchmarks (b_eff-style pingpong,
collective sweeps), calibrate the latency model against the measurements, and
use the findings to configure the application.  This package closes that loop
for the repo:

- :mod:`repro.tune.space`     — enumerate valid ``CommConfig`` candidates
  (mode x scheduling x transport x window x chunk x compression x algorithm),
  pruning combinations ``CommConfig.__post_init__`` rejects.
- :mod:`repro.tune.sweep`     — run measured microbenchmarks per collective
  and message size on the running mesh; ``python -m repro.tune.sweep``.
- :mod:`repro.tune.calibrate` — fit the Eq. 1 constants (l_k, link bandwidth,
  staging cost) from sweep measurements; model-vs-measured report.
- :mod:`repro.tune.prune`     — model-guided pruning: the calibrated Eq. 1
  model skips candidates it ranks far off the incumbent (paper-style
  calibrated search), cutting full-sweep wall clock.
- :mod:`repro.tune.db`        — persistent ``TuneDB`` JSON store and the
  ``select_config(collective, msg_bytes, mesh)`` entry point every workload
  uses to pick a fast configuration (``comm_cfg="auto"``).
"""
from repro.tune.space import (config_from_dict, config_to_dict,
                              enumerate_configs, space_size)
from repro.tune.db import (TuneDB, TuneEntry, default_db_path, select_config,
                           topology_key)
from repro.tune.calibrate import (CalibrationResult, calibrate_from_db,
                                  fit_latency_model, model_vs_measured)
from repro.tune.prune import (calibration_from_db, predicted_e2e,
                              predicted_latency, prune_candidates)
from repro.tune.elastic import (degraded_calibration, model_reselect,
                                reselect_round_configs)


def run_sweep(*args, **kwargs):
    """Lazy forward to :func:`repro.tune.sweep.run_sweep` (keeps
    ``python -m repro.tune.sweep`` free of a double-import warning)."""
    from repro.tune.sweep import run_sweep as _run_sweep
    return _run_sweep(*args, **kwargs)

__all__ = [
    "CalibrationResult", "TuneDB", "TuneEntry", "calibrate_from_db",
    "calibration_from_db", "config_from_dict", "config_to_dict",
    "default_db_path", "degraded_calibration", "enumerate_configs",
    "fit_latency_model", "model_reselect", "model_vs_measured",
    "predicted_e2e", "predicted_latency", "prune_candidates",
    "reselect_round_configs", "run_sweep", "select_config", "space_size",
    "topology_key",
]
