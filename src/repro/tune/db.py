"""Persistent autotuning results store and the auto-config selection API.

``TuneDB`` is a JSON-backed table of measured results keyed by
(topology, collective, message size).  ``select_config`` is the single entry
point every workload uses: given a collective, a message size, and the mesh it
will run on, return the fastest measured ``CommConfig`` — or fall back to the
paper's ``OPTIMIZED_CONFIG`` when the cache is cold.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from pathlib import Path
from typing import Optional, Sequence

from repro.core.config import CommConfig, OPTIMIZED_CONFIG
from repro.tune.space import config_from_dict, config_to_dict

DB_VERSION = 1


def default_db_path() -> Path:
    """Resolve the TuneDB location (``REPRO_TUNE_DB`` env overrides)."""
    env = os.environ.get("REPRO_TUNE_DB")
    if env:
        return Path(env)
    return Path.cwd() / ".repro_tune" / "tunedb.json"


def topology_key(mesh=None, n_devices: int | None = None) -> str:
    """Stable key for "the substrate this measurement ran on".

    ``platform:n_devices`` — enough to keep results from a CPU host mesh, an
    8-chip v5e slice, and a 48-FPGA cluster from cross-contaminating.
    """
    if mesh is not None:
        devs = list(mesh.devices.flat)
        return f"{devs[0].platform}:{len(devs)}"
    if n_devices is not None:
        import jax
        return f"{jax.devices()[0].platform}:{n_devices}"
    import jax
    return f"{jax.devices()[0].platform}:{jax.device_count()}"


@dataclasses.dataclass
class TuneEntry:
    """One measured (collective, message size, config) data point."""
    topo: str
    collective: str
    msg_bytes: int
    config: dict                  # config_to_dict(CommConfig)
    us_per_call: float            # bare collective latency (latency_us)
    gbps: float = 0.0             # derived effective bandwidth
    # Worst-case torus hop distance of the measured pattern
    # (Communicator.torus_hops / max_hops): 1 = direct link, >1 = routed —
    # the paper's direct-link vs Ethernet-switch distinction.  Entries
    # measured at different hop distances are distinct data points.
    hops: int = 1
    # Virtual torus the measurement ran on (TorusSpec.name, e.g. "4x4" or
    # "2x4:snake"); "" = the substrate's native flat mesh.  Kept as a
    # distinct data point per emulated placement — two tori can produce the
    # same hop distance with different routing schedules.
    torus: str = ""
    # End-to-end seconds-per-iteration (µs) of the collective's consumer
    # loop (row_parallel matmul+reduce, halo-fold step) — what the paper's
    # §5 result says actually decides the scaling config.  0.0 = not
    # measured (latency-only sweep).
    e2e_us: float = 0.0
    # p95 of the sweep's per-rep samples (µs), from the same
    # ``sweep.us{collective=}`` histogram machinery the registry exports —
    # the dispersion the variance-aware selection breaks near-ties on.
    # 0.0 = not recorded (point-estimate-only entry).
    p95_us: float = 0.0
    # Injected per-transmission chunk-loss rate the measurement ran under
    # (sweep --loss-rate); 0.0 = clean wire.  Entries measured under
    # different loss rates are distinct data points — the jumbo-vs-segment
    # winner flips with loss, so a lossy-wire answer must come from a
    # lossy-wire measurement.
    loss: float = 0.0
    # Which consumer loop produced ``e2e_us`` ("row_parallel",
    # "decode_step", "prefill", "halo_fold", "moe_loop"; "" = bare-latency
    # entry).  One collective serves phases with opposite cost structures —
    # decode's tiny latency-bound per-token combines vs prefill's
    # throughput-bound bulk reduces — so each consumer's measurement is a
    # distinct data point and selection prefers a matching one.
    consumer: str = ""

    @property
    def latency_us(self) -> float:
        """Bare collective latency — alias of ``us_per_call``."""
        return self.us_per_call

    @property
    def comm_config(self) -> CommConfig:
        return config_from_dict(self.config)

    def key(self) -> tuple:
        return (self.topo, self.collective, self.msg_bytes)

    def metric(self, objective: str = "latency") -> float:
        """Ranking metric for ``objective`` (µs); e2e falls back to bare
        latency for entries without a consumer-loop measurement."""
        if objective == "e2e" and self.e2e_us > 0.0:
            return self.e2e_us
        return self.us_per_call


class TuneDB:
    """In-memory table of TuneEntry, one *best* entry per (key, config).

    ``add`` keeps every distinct config's measurement (so calibration can fit
    across the whole space) but ``best``/``nearest`` answer with the fastest.
    """

    def __init__(self, entries: Sequence[TuneEntry] = ()):
        self.entries: list[TuneEntry] = list(entries)

    def __len__(self) -> int:
        return len(self.entries)

    def add(self, entry: TuneEntry) -> None:
        cfg_key = tuple(sorted(entry.config.items()))
        for i, e in enumerate(self.entries):
            if (e.key() == entry.key() and e.hops == entry.hops
                    and e.torus == entry.torus and e.loss == entry.loss
                    and e.consumer == entry.consumer
                    and tuple(sorted(e.config.items())) == cfg_key):
                # Merge: fastest latency wins; an e2e measurement is kept
                # even when it rides a slower latency rerun (and the
                # fastest e2e wins when both entries carry one).  p95
                # follows the winning latency measurement (dispersion is a
                # property of the run that produced the point estimate).
                e2e = (min(e.e2e_us, entry.e2e_us)
                       if e.e2e_us > 0.0 and entry.e2e_us > 0.0
                       else max(e.e2e_us, entry.e2e_us))
                best = entry if entry.us_per_call < e.us_per_call else e
                self.entries[i] = dataclasses.replace(best, e2e_us=e2e)
                return
        self.entries.append(entry)

    # ------------------------------------------------------------------
    # Queries
    # ------------------------------------------------------------------
    def candidates(self, collective: str, topo: str | None = None,
                   hops: int | None = None,
                   torus: str | None = None,
                   loss: float | None = None,
                   consumer: str | None = None) -> list[TuneEntry]:
        """Entries for ``collective`` (optionally per topology).

        With ``torus`` given (a ``TorusSpec.name``), prefer entries measured
        on that virtual placement: a flat-mesh "2-hop" ring measurement never
        routed and must not outrank a routed 2-hop measurement when the
        caller IS on the torus (and vice versa); when none match, relax to
        every entry.  With ``hops`` given, prefer entries measured at
        exactly that hop distance; when none exist, relax to the nearest
        measured distance — a 3-hop edge is better served by a 2-hop
        measurement than a 1-hop one (the direct-link vs routed cost
        structures differ).  ``loss`` works the same way for the injected
        chunk-loss rate: a lossy caller prefers lossy-wire measurements
        (jumbo frames win clean links, small segments win lossy ones) and
        relaxes to the nearest measured rate.  ``consumer`` prefers entries
        whose ``e2e_us`` was measured inside that consumer loop (a decode
        caller must not be answered by a prefill-loop measurement when a
        decode-loop one exists) and relaxes to every entry when the
        consumer was never swept.
        """
        cands = [e for e in self.entries
                 if e.collective == collective
                 and (topo is None or e.topo == topo)]
        if consumer is not None:
            matched = [e for e in cands if e.consumer == consumer]
            if matched:
                cands = matched
        if torus is not None:
            matched = [e for e in cands if e.torus == torus]
            if matched:
                cands = matched
        if loss is not None and cands:
            matched = [e for e in cands if e.loss == loss]
            if matched:
                cands = matched
            else:
                nearest_l = min({e.loss for e in cands},
                                key=lambda l: abs(l - loss))
                cands = [e for e in cands if e.loss == nearest_l]
        if hops is not None and cands:
            matched = [e for e in cands if e.hops == hops]
            if matched:
                return matched
            nearest_h = min({e.hops for e in cands},
                            key=lambda h: abs(h - hops))
            return [e for e in cands if e.hops == nearest_h]
        return cands

    #: Entries within this fraction of the best metric are a "near-tie" and
    #: re-rank by measured p95 — the variance-aware slice of selection: two
    #: configs indistinguishable on the mean are distinguishable on tail
    #: latency, which is what the latency-sensitive paths feel.
    NEAR_TIE = 0.05

    @classmethod
    def _rank(cls, entries: list[TuneEntry], objective: str
              ) -> Optional[TuneEntry]:
        """Fastest entry under ``objective``.  For ``e2e``, entries with a
        measured consumer-loop time outrank latency-only entries (a measured
        e2e beats a proxy); with none measured, fall back to bare latency.
        Entries within :data:`NEAR_TIE` of the winner's metric break the
        tie on recorded ``p95_us``; entries without a recorded p95 cannot
        win a near-tie (an unknown tail never beats a measured one), and a
        DB with no dispersion recorded ranks exactly as before."""
        if not entries:
            return None
        metric = None
        if objective == "e2e":
            with_e2e = [e for e in entries if e.e2e_us > 0.0]
            if with_e2e:
                entries = with_e2e
                metric = lambda e: e.e2e_us  # noqa: E731
        if metric is None:
            metric = lambda e: e.us_per_call  # noqa: E731
        best = min(entries, key=metric)
        near = [e for e in entries
                if metric(e) <= metric(best) * (1.0 + cls.NEAR_TIE)]
        with_p95 = [e for e in near if e.p95_us > 0.0]
        if len(near) > 1 and with_p95:
            # Variance-aware: the lowest measured tail wins the near-tie.
            # Entries without recorded dispersion cannot win it — an
            # unknown tail must not beat a measured one on missing data.
            return min(with_p95, key=lambda e: (e.p95_us, metric(e)))
        return best

    def best(self, collective: str, msg_bytes: int, topo: str | None = None,
             hops: int | None = None, objective: str = "latency",
             torus: str | None = None,
             loss: float | None = None,
             consumer: str | None = None) -> Optional[TuneEntry]:
        """Fastest entry at exactly ``msg_bytes`` (None if not measured)."""
        exact = [e for e in self.candidates(collective, topo, hops, torus,
                                            loss, consumer)
                 if e.msg_bytes == msg_bytes]
        return self._rank(exact, objective)

    def nearest(self, collective: str, msg_bytes: int, topo: str | None = None,
                hops: int | None = None, objective: str = "latency",
                torus: str | None = None,
                loss: float | None = None,
                consumer: str | None = None) -> Optional[TuneEntry]:
        """Fastest entry at the measured message size closest (in log space)
        to ``msg_bytes`` — message-size behaviour is scale-free, so log
        distance is the right metric (1 KiB is "nearer" 4 KiB than 64 KiB)."""
        cands = self.candidates(collective, topo, hops, torus, loss, consumer)
        if not cands:
            return None
        target = math.log(max(1, msg_bytes))
        nearest_size = min({e.msg_bytes for e in cands},
                           key=lambda s: abs(math.log(max(1, s)) - target))
        exact = [e for e in cands if e.msg_bytes == nearest_size]
        return self._rank(exact, objective)

    # ------------------------------------------------------------------
    # Persistence
    # ------------------------------------------------------------------
    def save(self, path: os.PathLike | str | None = None) -> Path:
        path = Path(path) if path is not None else default_db_path()
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": DB_VERSION,
                   "entries": [dataclasses.asdict(e) for e in self.entries]}
        # Unique temp name + atomic replace: two processes saving the same
        # DB concurrently never collide on the temp file or tear the target.
        tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
        tmp.replace(path)
        return path

    @classmethod
    def load(cls, path: os.PathLike | str | None = None) -> "TuneDB":
        """Load a DB; a missing, torn, corrupt, or schema-incompatible file
        yields an empty DB (the sweep rebuilds and overwrites) — a damaged
        cache must never take the tuner down."""
        path = Path(path) if path is not None else default_db_path()
        if not path.exists():
            return cls()
        try:
            payload = json.loads(path.read_text())
            if payload.get("version") != DB_VERSION:
                return cls()
            return cls([TuneEntry(**e) for e in payload.get("entries", ())])
        except (OSError, ValueError, TypeError):
            return cls()


def select_config(collective: str, msg_bytes: int, mesh=None,
                  db: TuneDB | None = None,
                  path: os.PathLike | str | None = None,
                  topo: str | None = None,
                  hops: int | None = None,
                  objective: str = "latency",
                  torus: str | None = None,
                  loss: float | None = None,
                  consumer: str | None = None,
                  fallback: CommConfig = OPTIMIZED_CONFIG) -> CommConfig:
    """The autotuner's answer to "how should I communicate?".

    Looks up the fastest measured config for (collective, msg_bytes) on this
    topology; with ``hops`` given, prefers measurements taken at the same
    torus hop distance (multi-hop edges may want a different transport or
    window than direct links — the paper's direct-link vs Ethernet-switch
    distinction); relaxes to other device counts on the SAME platform (a
    config tuned on another platform's cost structure is worse than no
    tuning); falls back to the paper's ``OPTIMIZED_CONFIG`` on a cold cache
    so callers can unconditionally pass ``comm_cfg="auto"``.

    ``objective`` selects the ranking metric: ``"latency"`` (bare collective
    microbenchmark — the default) or ``"e2e"`` (the measured consumer-loop
    wall clock, ``TuneEntry.e2e_us``).  The paper's §5 finding is exactly
    that these disagree when the consumer has hideable compute: the config
    that wins the microbench is not the one that scales the application.
    Entries without an e2e measurement rank by bare latency under either
    objective.

    ``torus`` (a ``TorusSpec.name``, e.g. ``"4x4"``) prefers entries
    measured on that virtual placement: a caller routing over an emulated
    torus must not be answered by an unrouted flat-mesh measurement that
    happens to share a hop count (and relaxes to any entry when that
    placement was never swept).

    ``loss`` prefers entries measured under that injected chunk-loss rate
    (nearest measured rate when no exact match): on a lossy wire the
    GUARANTEED small-segment configs that looked slow on the clean sweep
    are the ones that actually win, and only lossy-wire measurements can
    say so.

    ``consumer`` names the caller's consumer loop ("decode_step",
    "prefill", "row_parallel", ...): entries whose ``e2e_us`` was measured
    inside that loop are preferred, which is how serving's two phases
    resolve *different* configs from the same TuneDB — a latency-bound
    decode step and a throughput-bound prefill disagree about the winner
    even at the same message size.
    """
    if objective not in ("latency", "e2e"):
        raise ValueError(f"objective must be 'latency' or 'e2e', "
                         f"got {objective!r}")
    if db is None:
        db = TuneDB.load(path)
    if topo is None:
        topo = topology_key(mesh) if mesh is not None else topology_key()
    platform = topo.split(":", 1)[0]
    entry = (db.best(collective, msg_bytes, topo, hops, objective, torus,
                     loss, consumer)
             or db.nearest(collective, msg_bytes, topo, hops, objective,
                           torus, loss, consumer))
    if entry is None:
        same_platform = TuneDB([e for e in db.entries
                                if e.topo.split(":", 1)[0] == platform])
        entry = same_platform.nearest(collective, msg_bytes, None, hops,
                                      objective, torus, loss, consumer)
    if entry is None:
        return fallback
    return entry.comm_config
