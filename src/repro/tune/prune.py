"""Model-guided sweep pruning — the paper's calibrated-model search.

The paper does not measure its whole configuration space blindly: the Eq. 1
latency model, calibrated against a handful of measurements, ranks the
candidates and only the plausible ones are benchmarked.  This module closes
that loop for the autotuner: given a :class:`~repro.tune.calibrate.
CalibrationResult` fitted on this substrate, :func:`prune_candidates` drops
every candidate the model predicts to be more than ``ratio``× slower than
the predicted incumbent, cutting full-sweep wall clock while keeping every
config that could plausibly win within measurement noise.
"""
from __future__ import annotations

from typing import Sequence

from repro.core import latmodel
from repro.core.config import CommConfig, CommMode, Scheduling
from repro.tune.calibrate import CalibrationResult, calibrate_from_db

# Default pruning aggressiveness: skip configs the model ranks > 2x off the
# predicted incumbent.  2x leaves ample headroom for the fit's residuals
# (rms_rel_err is typically well under 0.5 on a clean sweep).
DEFAULT_RATIO = 2.0

# Collectives whose streaming implementation splits the message into wire
# chunks, each an independently scheduled command (chunked_permute /
# pipelined_consume; all_to_all only tiles under overlapped scheduling).
# Ring/native reduction collectives move whole segments — no chunk term.
_CHUNKED_STREAMING = frozenset({"sendrecv", "multi_neighbor"})


def predicted_latency(cfg: CommConfig, msg_bytes: int,
                      calibration: CalibrationResult,
                      collective: str | None = None,
                      hops: int = 1, loss: float = 0.0) -> float:
    """Eq. 1 prediction (seconds) for one candidate on the calibrated
    substrate.

    The chunk-aware ``pingping_latency`` charges one scheduled command per
    wire chunk — what ranks a 64 KiB-segment config far off a jumbo-segment
    incumbent at multi-MiB messages (the paper's segmentation/jumbo-frame
    finding).  Collectives that never split the wire (ring/native reduction
    collectives; all_to_all outside overlapped scheduling) are predicted at
    a single command regardless of ``chunk_bytes``.  ``hops`` is the edge's
    torus hop distance: the route term re-serializes buffered messages per
    hop and wormholes streaming chunks, which is what reorders candidates
    between direct links and routed edges.  ``loss`` is the expected
    chunk-loss rate of the wire: GUARANTEED candidates are surcharged by
    :func:`~repro.core.latmodel.expected_retransmit_factor`, which is what
    lets the pruner rank small segments above jumbo frames on lossy links.
    """
    import dataclasses
    hw = calibration.to_hardware_spec()
    chunked = (collective in _CHUNKED_STREAMING
               and cfg.mode == CommMode.STREAMING) or (
        collective == "all_to_all"
        and cfg.mode == CommMode.STREAMING
        and cfg.scheduling == Scheduling.OVERLAPPED)
    if not chunked and cfg.mode == CommMode.STREAMING:
        cfg = dataclasses.replace(cfg, max_chunks=1)
    return latmodel.pingping_latency(msg_bytes, cfg, hw, hops=hops,
                                     loss=loss)


def predicted_e2e(cfg: CommConfig, msg_bytes: int,
                  calibration: CalibrationResult, compute_s: float,
                  collective: str | None = None,
                  hops: int = 1, loss: float = 0.0) -> float:
    """End-to-end consumer-loop prediction (seconds per iteration): the
    overlap-aware Eq. 2 term applied to the consumer, on the calibrated
    substrate.

    ``compute_s`` is the hideable per-iteration compute (the row_parallel
    matmul, the halo interior update).  The overlapped schedule hides the
    calibrated comm latency behind it (``max``), the fused/host schedules
    expose part or all of it — which is what reorders candidates relative
    to :func:`predicted_latency` and lets the sweep prune on the ``e2e``
    objective without measuring every consumer loop.

    Chunking mirrors what the consumer actually executes: the row_parallel
    consumer routes EVERY streaming-mode all_reduce through the chunked
    ``overlapped_matmul_allreduce`` (not just overlapped scheduling), so a
    streaming candidate is always priced per wire chunk — otherwise the
    pruner would rank candidates against a program the e2e sweep never
    runs.
    """
    import dataclasses
    from repro.core.config import Scheduling
    hw = calibration.to_hardware_spec()
    chunked = cfg.mode == CommMode.STREAMING and (
        collective in _CHUNKED_STREAMING
        or collective == "all_reduce"
        or cfg.scheduling == Scheduling.OVERLAPPED)
    if not chunked and cfg.mode == CommMode.STREAMING:
        cfg = dataclasses.replace(cfg, max_chunks=1)
    return latmodel.e2e_consumer_latency(msg_bytes, cfg, compute_s, hw,
                                         hops=hops, loss=loss)


def prune_candidates(cands: Sequence[CommConfig], msg_bytes: int,
                     calibration: CalibrationResult,
                     ratio: float = DEFAULT_RATIO,
                     collective: str | None = None,
                     objective: str = "latency",
                     compute_s: float = 0.0,
                     hops: int = 1,
                     loss: float = 0.0
                     ) -> tuple[list[CommConfig], list[CommConfig]]:
    """Split candidates into (measure, skip) by calibrated model ranking.

    A candidate is skipped when the model predicts it to be more than
    ``ratio``× slower than the best predicted candidate (the incumbent).
    The incumbent itself is always kept, so the pruned sweep can never
    select a config the exhaustive sweep would not also have measured.
    ``objective="e2e"`` ranks by :func:`predicted_e2e` (consumer loop with
    ``compute_s`` of hideable compute) instead of bare Eq. 1 latency.
    ``hops`` prices the candidates at the hop distance the sweep is about
    to measure them at (the per-edge axis of a torus sweep).
    """
    if not cands:
        return [], []
    if objective == "e2e":
        preds = [predicted_e2e(c, msg_bytes, calibration, compute_s,
                               collective, hops=hops, loss=loss)
                 for c in cands]
    else:
        preds = [predicted_latency(c, msg_bytes, calibration, collective,
                                   hops=hops, loss=loss) for c in cands]
    best = min(preds)
    kept, skipped = [], []
    for cfg, pred in zip(cands, preds):
        (kept if pred <= ratio * best else skipped).append(cfg)
    return kept, skipped


def calibration_from_db(db, topo: str | None = None
                        ) -> CalibrationResult | None:
    """Fit the Eq. 1 constants from a TuneDB's sendrecv measurements, or
    ``None`` when the DB holds none for this topology (cold cache — the
    sweep then seeds its own calibration set first)."""
    try:
        result = calibrate_from_db(db, topo)
    except ValueError:
        return None
    return result if result.n_points >= 2 else None
