"""Fit the Eq. 1 latency-model constants from sweep measurements.

The paper calibrates its model (l_k = 30 us XRT dispatch, 12.5 GB/s QSFP
link, global-memory staging cost) by measuring the running system; this module
does the same for whatever substrate the sweep ran on.  The pingping model
(at hop distance h, with wire chunks pipelining across the route — see
:func:`repro.core.latmodel.pingping_latency`)

    buffered : t = 2*l_k + l0 + (h-1)*l_hop + h*wire/bw + 2*msg/bw_mem
    streaming: t = n*l_k + l0 + (h-1)*l_hop + (n+h-1)*(wire/n)/bw

is linear in the unknowns (l_k_host, l_k_fused, l0, 1/bw, 2/bw_mem, l_hop),
so a least-squares fit over the measured (config, size, seconds[, hops])
points recovers them directly.  The per-hop term is only resolvable when the
sweep measured more than one hop distance (the ``--hop-distances`` axis on a
virtual torus); a single-distance sweep keeps the hardware default.
``CalibrationResult.to_hardware_spec`` rebuilds a ``HardwareSpec`` whose
Eq. 1-3 predictions track the measured substrate, and ``model_vs_measured``
reports the residuals per point.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core import latmodel
from repro.core.config import (CommConfig, CommMode, HardwareSpec, Scheduling,
                               V5E)

# One measurement point: (config, message bytes, measured seconds per op)
# with an optional trailing hop distance (defaults to 1 — a direct link).
Measurement = tuple


def _point(m: Measurement) -> tuple[CommConfig, int, float, int]:
    cfg, size, sec = m[0], m[1], m[2]
    hops = int(m[3]) if len(m) > 3 else 1
    return cfg, size, sec, hops


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Fitted Eq. 1 constants for the measured substrate."""
    l_k_host: float       # s per host-scheduled dispatch (paper: ~30 us XRT)
    l_k_fused: float      # s per in-program issue (paper: sub-us PL)
    link_latency: float   # s base latency per message (l0)
    link_bw: float        # B/s effective wire bandwidth
    staging_bw: float     # B/s effective staging (HBM write+read) bandwidth
    n_points: int         # measurements used
    rms_rel_err: float    # fit quality over those points
    # Per-extra-hop latency (the paper's direct-link vs switch delta); fitted
    # only when the measurements span > 1 hop distance, else the default.
    hop_latency: float = V5E.ici_hop_latency

    def to_hardware_spec(self, base: HardwareSpec = V5E,
                         name: str = "calibrated") -> HardwareSpec:
        """A HardwareSpec whose latmodel predictions match the measurements."""
        return dataclasses.replace(
            base, name=name,
            host_dispatch=self.l_k_host, fused_dispatch=self.l_k_fused,
            ici_latency=self.link_latency, ici_bw=self.link_bw,
            hbm_bw=self.staging_bw, ici_hop_latency=self.hop_latency)

    def summary(self) -> str:
        return ("calibrated: "
                f"l_k(host)={self.l_k_host*1e6:.1f}us "
                f"l_k(fused)={self.l_k_fused*1e6:.2f}us "
                f"link_lat={self.link_latency*1e6:.2f}us "
                f"hop_lat={self.hop_latency*1e6:.2f}us "
                f"link_bw={self.link_bw/1e9:.2f}GB/s "
                f"staging_bw={self.staging_bw/1e9:.2f}GB/s "
                f"(n={self.n_points}, rms_rel_err={self.rms_rel_err:.2f})")


def _design_row(cfg: CommConfig, msg_bytes: int, hops: int = 1) -> np.ndarray:
    """Coefficients of [l_k_host, l_k_fused, l0, 1/bw, 2/bw_mem, l_hop].

    The command count is ``latmodel.n_commands``: 2 for buffered (staging
    write + read-back), one per wire chunk for streaming — and the wire
    coefficient carries the route term (store-and-forward re-serialization
    for buffered, chunk wormholing for streaming) — keeping the fit
    consistent with the hop-aware ``pingping_latency`` so the pruning
    model's predictions live on the same surface the constants were fitted
    on."""
    h = max(1, int(hops))
    n_k = latmodel.n_commands(msg_bytes, cfg)
    host = n_k if cfg.scheduling == Scheduling.HOST else 0.0
    # overlapped is device-scheduled like fused: same in-program issue cost
    fused = n_k if cfg.scheduling != Scheduling.HOST else 0.0
    wire = latmodel.wire_bytes(msg_bytes, cfg)
    if cfg.mode == CommMode.BUFFERED:
        wire = h * wire
        staging = float(msg_bytes)
    else:
        wire = (n_k + h - 1) * (wire / n_k)
        staging = 0.0
    return np.array([host, fused, 1.0, wire, staging, float(h - 1)])


def fit_latency_model(measurements: Sequence[Measurement]) -> CalibrationResult:
    """Least-squares fit of the Eq. 1 constants; raises on an empty input."""
    if not measurements:
        raise ValueError("no measurements to calibrate from")
    points = [_point(m) for m in measurements]
    A = np.stack([_design_row(cfg, size, hops)
                  for cfg, size, _, hops in points])
    t = np.array([sec for _, _, sec, _ in points], dtype=np.float64)
    multi_hop = len({h for _, _, _, h in points}) > 1
    hop_offset = np.zeros_like(t)
    if not multi_hop:
        # The hop column is the constant h-1 — collinear with l0, so a
        # single-distance sweep can't resolve it.  Price the hops at the
        # retained default instead (any residual lands in l0), so predicting
        # at hops=h doesn't add the default on top of an l0 that already
        # absorbed the hop cost.
        h0 = max(1, points[0][3])
        hop_offset += (h0 - 1) * CalibrationResult.hop_latency
        A = A[:, :5]
    coef, *_ = np.linalg.lstsq(A, t - hop_offset, rcond=None)
    coef = np.maximum(coef, 0.0)   # latencies/inverse-bandwidths are physical
    pred = A @ coef + hop_offset
    rel = (pred - t) / np.maximum(t, 1e-12)
    # A zero inverse-bandwidth coefficient means the size term was not
    # resolvable from these points (overhead-dominated substrate): report the
    # bandwidth as infinite, which latmodel handles (size/inf == 0).
    return CalibrationResult(
        l_k_host=float(coef[0]), l_k_fused=float(coef[1]),
        link_latency=float(coef[2]),
        link_bw=float(1.0 / coef[3]) if coef[3] > 0 else float("inf"),
        staging_bw=float(2.0 / coef[4]) if coef[4] > 0 else float("inf"),
        n_points=len(points),
        rms_rel_err=float(np.sqrt(np.mean(rel ** 2))),
        hop_latency=(float(coef[5]) if multi_hop
                     else CalibrationResult.hop_latency))


def measurements_from_db(db, topo: str | None = None,
                         collective: str = "sendrecv") -> list[Measurement]:
    """Pingpong-style points from a TuneDB (the Eq. 1 calibration set).
    Each entry's measured hop distance rides along, so a hop-distance sweep
    resolves the per-hop constant."""
    return [(e.comm_config, e.msg_bytes, e.us_per_call * 1e-6, e.hops)
            for e in db.candidates(collective, topo)]


def calibrate_from_db(db, topo: str | None = None,
                      collective: str = "sendrecv") -> CalibrationResult:
    return fit_latency_model(measurements_from_db(db, topo, collective))


def model_vs_measured(result: CalibrationResult, db,
                      topo: str | None = None,
                      collective: str = "sendrecv") -> list[str]:
    """Human-readable modeled-vs-measured report rows."""
    hw = result.to_hardware_spec()
    rows = []
    for cfg, size, sec, hops in (
            _point(m) for m in measurements_from_db(db, topo, collective)):
        modeled = latmodel.pingping_latency(size, cfg, hw, hops=hops)
        rows.append(
            f"{collective} {size:>8d}B h{hops} {cfg.mode.value:9s}/"
            f"{cfg.scheduling.value:5s} measured={sec*1e6:9.1f}us "
            f"modeled={modeled*1e6:9.1f}us ratio={modeled/max(sec,1e-12):5.2f}")
    return rows
