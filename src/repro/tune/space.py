"""Search-space enumeration for the ACCL-X autotuner.

The tunable surface is the full ``CommConfig`` cross product:

    mode x scheduling x transport x window x chunk_bytes x compression
         x algorithm

Most of that product is either invalid (``CommConfig.__post_init__`` rejects
it — e.g. int8 wire compression with native XLA collectives) or redundant
(``window`` is only consulted by the ordered transport; ``algorithm`` is only
consulted by collectives, not point-to-point ops).  This module enumerates the
*valid, non-redundant* candidates so the sweep engine never burns wall clock
measuring a configuration twice.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

from repro.core.config import (CommConfig, CommMode, Compression, Reliability,
                               Scheduling, Transport)

# Default tuning axes.  ``window``/``chunk_bytes`` follow the paper's §3.3
# transport tuning (window scaling, jumbo frames); the rest is the §3.1/§3.2
# mode/scheduling/plugin surface.
DEFAULT_AXES: dict[str, tuple] = {
    "mode": tuple(CommMode),
    "scheduling": tuple(Scheduling),
    "transport": tuple(Transport),
    "window": (1, 4, 8),
    "chunk_bytes": (1 << 16, 1 << 20),
    "compression": tuple(Compression),
    "algorithm": ("native", "ring"),
}

# A trimmed space for --fast smoke sweeps: the paper's four named corner
# configurations plus the ring-algorithm variant and both segment sizes
# (64 KiB vs jumbo 1 MiB — the axis the pruning model separates).
FAST_AXES: dict[str, tuple] = {
    "mode": tuple(CommMode),
    "scheduling": tuple(Scheduling),
    "transport": (Transport.UNORDERED,),
    "window": (4,),
    "chunk_bytes": (1 << 16, 1 << 20),
    "compression": (Compression.NONE,),
    "algorithm": ("native", "ring"),
}

# Which config fields a collective's implementation actually reads.  Fields
# not listed are irrelevant for that collective and get canonicalized to the
# CommConfig default so enumeration does not emit behavioural duplicates.
_RELEVANT_FIELDS: dict[str, frozenset[str]] = {
    # Point-to-point: streaming.chunked/buffered_permute read mode, transport,
    # window, chunk_bytes; scheduling decides dispatch granularity.
    "sendrecv": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes"}),
    "multi_neighbor": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes"}),
    # Collectives: algorithm + compression select the implementation; ring
    # algorithms additionally honor the point-to-point wire fields.
    "all_reduce": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes",
         "compression", "algorithm"}),
    "all_gather": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes",
         "compression", "algorithm"}),
    "reduce_scatter": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes",
         "compression", "algorithm"}),
    # all_to_all: chunked-overlap delivery (streaming + overlapped) reads the
    # wire fields; fused/host execution reads only scheduling + compression.
    "all_to_all": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes",
         "compression"}),
    # hierarchical (cross-pod) all-reduce: composed of RS/AR/AG, same
    # surface as all_reduce.
    "hierarchical_all_reduce": frozenset(
        {"mode", "scheduling", "transport", "window", "chunk_bytes",
         "compression", "algorithm"}),
}

_DEFAULTS = CommConfig()

# Collectives with e2e consumer-loop benchmarks whose *consumers* read
# Scheduling.OVERLAPPED even though the bare collective executes identically
# to fused (row_parallel, decode_step and prefill all route their combine
# through overlapped_matmul_allreduce; the halo fold is double-buffered —
# see sweep.CONSUMERS for the per-collective consumer sets).  Under the
# e2e objective the overlapped variants must stay distinct candidates — the
# whole point of the paper's §5 finding is that the microbench cannot rank
# them but the consumer loop can.  all_to_all (the MoE dispatch/combine
# consumer) needs no entry here: its streaming+overlapped variants are
# already distinct under either objective (chunked_all_to_all), and its
# buffered variants have no wire chunks to tile under any objective.
CONSUMER_COLLECTIVES = frozenset({"all_reduce", "multi_neighbor"})


def _canonicalize(cfg: CommConfig, collective: str | None,
                  objective: str = "latency") -> CommConfig:
    """Collapse fields a collective (or the config itself) never reads."""
    updates: dict = {}
    if collective is not None:
        relevant = _RELEVANT_FIELDS.get(collective)
        if relevant is not None:
            for f in DEFAULT_AXES:
                if f not in relevant:
                    updates[f] = getattr(_DEFAULTS, f)
    merged = dataclasses.replace(cfg, **updates) if updates else cfg
    # The retransmit/timeout/backoff knobs are only consulted by the
    # GUARANTEED protocol; best-effort configs differing only in them are
    # the same program.
    if merged.reliability == Reliability.BEST_EFFORT:
        merged = dataclasses.replace(
            merged, ack_timeout=_DEFAULTS.ack_timeout,
            max_retransmits=_DEFAULTS.max_retransmits,
            backoff_base=_DEFAULTS.backoff_base,
            backoff_cap=_DEFAULTS.backoff_cap)
    # window is only consulted when chunks form an ack chain (ordered
    # transport) or by the GUARANTEED send window; best-effort unordered
    # configs differing only in window are identical.
    if (merged.transport == Transport.UNORDERED
            and merged.reliability == Reliability.BEST_EFFORT
            and merged.window != _DEFAULTS.window):
        merged = dataclasses.replace(merged, window=_DEFAULTS.window)
    # Overlapped scheduling only changes behaviour for the multi-round halo
    # exchange (double-buffered delivery) and the chunk-tiled all_to_all
    # (streaming delivery only); every other collective executes the
    # overlapped config exactly like the fused one, so collapse it and
    # never measure the duplicate.
    if merged.scheduling == Scheduling.OVERLAPPED:
        keep_overlapped = (objective == "e2e"
                           and collective in CONSUMER_COLLECTIVES)
        if collective == "all_to_all" and merged.mode != CommMode.STREAMING:
            # buffered all_to_all has no wire chunks to tile: same program
            merged = dataclasses.replace(merged, scheduling=Scheduling.FUSED)
        elif (collective not in (None, "multi_neighbor", "all_to_all")
              and not keep_overlapped):
            merged = dataclasses.replace(merged, scheduling=Scheduling.FUSED)
        elif (collective == "multi_neighbor"
              and merged.mode == CommMode.BUFFERED
              and merged.window != _DEFAULTS.window):
            # buffered rounds have no wire chunks: the double-buffered path
            # chains whole rounds per buffer and never reads the ack window.
            # STREAMING rounds DO read it (pipelined_consume chains chunk i
            # on chunk i-window), so those variants stay distinct.
            merged = dataclasses.replace(merged, window=_DEFAULTS.window)
    if (collective == "all_to_all"
            and merged.scheduling != Scheduling.OVERLAPPED):
        # without chunked-overlap delivery the wire fields are never read
        merged = dataclasses.replace(
            merged, mode=_DEFAULTS.mode, transport=_DEFAULTS.transport,
            window=_DEFAULTS.window, chunk_bytes=_DEFAULTS.chunk_bytes)
    return merged


def enumerate_configs(collective: str | None = None,
                      axes: dict[str, Sequence] | None = None,
                      fast: bool = False,
                      objective: str = "latency") -> list[CommConfig]:
    """All valid, deduplicated ``CommConfig`` candidates for ``collective``.

    Invalid combinations are pruned by attempting construction — the single
    source of truth for validity is ``CommConfig.__post_init__`` itself, so
    the search space can never drift from the config's rules.

    ``objective="e2e"`` keeps candidates distinct when the collective's
    *consumer loop* distinguishes them even though the bare collective does
    not (overlapped scheduling for :data:`CONSUMER_COLLECTIVES`).
    """
    if axes is None:
        axes = FAST_AXES if fast else DEFAULT_AXES
    names = list(axes)
    seen: set[CommConfig] = set()
    out: list[CommConfig] = []
    for combo in itertools.product(*(axes[n] for n in names)):
        try:
            cfg = CommConfig(**dict(zip(names, combo)))
            # Canonicalization can itself produce an invalid combo (e.g.
            # resetting an irrelevant algorithm='ring' to 'native' while
            # int8 compression stays relevant) — prune those too.
            cfg = _canonicalize(cfg, collective, objective)
        except ValueError:
            continue
        if cfg in seen:
            continue
        seen.add(cfg)
        out.append(cfg)
    return out


def space_size(axes: dict[str, Sequence] | None = None) -> int:
    """Raw (unpruned) cross-product size — for reporting pruning ratios."""
    if axes is None:
        axes = DEFAULT_AXES
    n = 1
    for vals in axes.values():
        n *= len(vals)
    return n


# ----------------------------------------------------------------------
# CommConfig <-> JSON-safe dict (the TuneDB wire format)
# ----------------------------------------------------------------------

_ENUM_FIELDS = {"mode": CommMode, "scheduling": Scheduling,
                "transport": Transport, "compression": Compression,
                "reliability": Reliability}


def config_to_dict(cfg: CommConfig) -> dict:
    d = dataclasses.asdict(cfg)
    for f in _ENUM_FIELDS:
        d[f] = d[f].value if isinstance(d[f], _ENUM_FIELDS[f]) else str(d[f])
    return d


def config_from_dict(d: dict) -> CommConfig:
    kw = dict(d)
    for f, enum_cls in _ENUM_FIELDS.items():
        if f in kw:
            kw[f] = enum_cls(kw[f])
    return CommConfig(**kw)
