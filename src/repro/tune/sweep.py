"""Measured configuration sweeps — the b_eff synthetic benchmark, automated.

For every (collective, message size, candidate ``CommConfig``) triple the
engine builds the real SPMD program on the running mesh, times it with warmup
(wall clock, ``block_until_ready``), and records the result in a
:class:`~repro.tune.db.TuneDB`.  Scheduling is honored the way the runtime
honors it: fused configs time K ops inside ONE compiled program (one host
dispatch amortized over the loop), host-scheduled configs block on every call
— the same methodology as ``benchmarks/b_eff.py``.

CLI::

    PYTHONPATH=src python -m repro.tune.sweep --fast            # smoke sweep
    PYTHONPATH=src python -m repro.tune.sweep --sizes 1024,65536 \
        --collectives all_reduce,sendrecv --out .repro_tune/tunedb.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from functools import partial
from typing import Callable, Sequence

import numpy as np

from repro.core.config import CommConfig, Scheduling
from repro.tune import space as tune_space
from repro.tune.db import TuneDB, TuneEntry, default_db_path, topology_key

# Message sizes (bytes per device) swept by default — the paper's Fig. 4 spans
# 64 B .. 4 MiB; host-CPU meshes get a truncated range to keep compiles sane.
FULL_SIZES = (1 << 10, 1 << 14, 1 << 17, 1 << 20)
FAST_SIZES = (1 << 10, 1 << 14)

SWEEPABLE = ("sendrecv", "all_reduce", "all_gather", "reduce_scatter",
             "multi_neighbor")


# ----------------------------------------------------------------------
# Microbenchmark program builders
# ----------------------------------------------------------------------

def _payload_elems(msg_bytes: int, n: int) -> int:
    """float32 elements per device, padded to a multiple of the mesh size so
    reduce-scatter/all-to-all constraints hold for every collective."""
    elems = max(n, msg_bytes // 4)
    return elems + (-elems) % n


def _multi_neighbor_rounds(comm) -> list:
    """The 4-neighbor halo pattern (ring distance ±1, ±2) — the SWE
    exchange.  Single source for both the benchmark op and the hop distance
    recorded with its measurements."""
    return [comm.ring_perm(1), comm.reverse_ring_perm(1),
            comm.ring_perm(2), comm.reverse_ring_perm(2)]


def _pattern_hops(collective: str, comm) -> int:
    """Worst-case torus hop distance of the pattern a collective exercises
    (recorded per TuneEntry so selection can prefer hop-matched results)."""
    if collective == "multi_neighbor":
        return comm.max_hops(
            [e for r in _multi_neighbor_rounds(comm) for e in r])
    return comm.max_hops(comm.ring_perm())


def _build_op(collective: str, comm, cfg: CommConfig) -> Callable:
    """Per-device body (x -> x-shaped array) exercising one collective op."""
    from jax import numpy as jnp
    from repro.core import collectives

    if collective == "sendrecv":
        def op(x):
            return collectives.sendrecv(x, comm.ring_perm(), comm, cfg)
    elif collective == "all_reduce":
        def op(x):
            return collectives.all_reduce(x, comm, cfg) / comm.size
    elif collective == "all_gather":
        def op(x):
            y = collectives.all_gather(x, comm, cfg, axis=0)
            # keep x's shape but depend on the whole gathered result so the
            # collective cannot be dead-code-eliminated
            return x + 0.0 * jnp.sum(y)
    elif collective == "reduce_scatter":
        def op(x):
            y = collectives.reduce_scatter(x, comm, cfg)
            return x + 0.0 * jnp.sum(y)
    elif collective == "multi_neighbor":
        def op(x):
            rounds = _multi_neighbor_rounds(comm)
            outs = collectives.multi_neighbor_exchange(
                [x] * len(rounds), rounds, comm, cfg)
            return sum(outs) / len(outs)
    else:
        raise ValueError(f"unknown collective {collective!r} "
                         f"(sweepable: {SWEEPABLE})")
    return op


def _time_program(op: Callable, mesh, msg_bytes: int, cfg: CommConfig,
                  warmup: int = 1, reps: int = 3, inner: int = 8) -> float:
    """Seconds per collective op under the config's scheduling discipline."""
    import jax
    from jax import numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat

    axis = mesh.axis_names[0]
    n = mesh.shape[axis]
    elems = _payload_elems(msg_bytes, n)
    x = jnp.zeros((n, elems), jnp.float32)

    single = jax.jit(compat.shard_map(
        lambda xs: op(xs[0])[None], mesh=mesh,
        in_specs=P(axis), out_specs=P(axis), check_vma=False))

    if cfg.scheduling != Scheduling.HOST:
        # fused and overlapped are both device-scheduled: one dispatch
        # amortized over the compiled loop
        def many(xs):
            for _ in range(inner):
                xs = compat.shard_map(
                    lambda v: op(v[0])[None], mesh=mesh,
                    in_specs=P(axis), out_specs=P(axis), check_vma=False)(xs)
            return xs
        fn = jax.jit(many)
        for _ in range(warmup):
            x = jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        for _ in range(reps):
            x = fn(x)
        jax.block_until_ready(x)
        return (time.perf_counter() - t0) / (reps * inner)

    # Host scheduling: one dispatch per op, host blocks between dispatches.
    for _ in range(warmup):
        x = jax.block_until_ready(single(x))
    t0 = time.perf_counter()
    for _ in range(reps * inner):
        x = jax.block_until_ready(single(x))
    return (time.perf_counter() - t0) / (reps * inner)


# ----------------------------------------------------------------------
# Sweep driver
# ----------------------------------------------------------------------

def run_sweep(mesh=None, collectives: Sequence[str] = SWEEPABLE,
              sizes: Sequence[int] | None = None, fast: bool = False,
              db: TuneDB | None = None, max_configs: int | None = None,
              reps: int = 3, inner: int = 8,
              log: Callable[[str], None] | None = None) -> TuneDB:
    """Measure every candidate config and return the populated TuneDB."""
    import jax
    from repro import compat
    from repro.core.communicator import Communicator

    if mesh is None:
        mesh = compat.make_mesh((jax.device_count(),), ("x",))
    if sizes is None:
        sizes = FAST_SIZES if fast else FULL_SIZES
    if db is None:
        db = TuneDB()
    if fast:
        reps, inner = min(reps, 2), min(inner, 4)
    log = log or (lambda s: None)

    axis = mesh.axis_names[0]
    comm = Communicator.from_mesh(mesh, axis)
    topo = topology_key(mesh)

    for coll in collectives:
        cands = tune_space.enumerate_configs(coll, fast=fast)
        if max_configs is not None:
            cands = cands[:max_configs]
        hops = _pattern_hops(coll, comm)
        log(f"[{topo}] {coll}: {len(cands)} configs x {len(sizes)} sizes "
            f"(pattern hops={hops})")
        for msg_bytes in sizes:
            for i, cfg in enumerate(cands):
                try:
                    op = _build_op(coll, comm, cfg)
                    sec = _time_program(op, mesh, msg_bytes, cfg,
                                        reps=reps, inner=inner)
                except Exception as e:  # noqa: BLE001 — skip unrunnable combos
                    log(f"  skip {coll}/{msg_bytes}B cfg{i}: "
                        f"{type(e).__name__}: {e}")
                    continue
                db.add(TuneEntry(
                    topo=topo, collective=coll, msg_bytes=int(msg_bytes),
                    config=tune_space.config_to_dict(cfg),
                    us_per_call=sec * 1e6,
                    gbps=msg_bytes / sec / 1e9,
                    hops=hops))
            best = db.best(coll, msg_bytes, topo)
            if best is not None:
                log(f"  {coll:15s} {msg_bytes:>8d}B best "
                    f"{best.us_per_call:9.1f} us  ({best.gbps:6.3f} GB/s)  "
                    f"{best.config['mode']}/{best.config['scheduling']}"
                    f"/{best.config['algorithm']}")
    return db


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def _ensure_devices(n: int) -> None:
    """Re-exec with N host CPU devices when launched on a single device."""
    if os.environ.get("REPRO_TUNE_NO_REEXEC"):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
        os.environ["REPRO_TUNE_NO_REEXEC"] = "1"
        os.execv(sys.executable,
                 [sys.executable, "-m", "repro.tune.sweep"] + sys.argv[1:])


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune.sweep",
        description="Measured CommConfig sweep -> TuneDB JSON.")
    ap.add_argument("--fast", action="store_true",
                    help="smoke sweep: corner configs, small sizes")
    ap.add_argument("--devices", type=int, default=8,
                    help="host CPU devices to force when single-device")
    ap.add_argument("--collectives", default=",".join(SWEEPABLE),
                    help=f"comma list from {SWEEPABLE}")
    ap.add_argument("--sizes", default=None,
                    help="comma list of message sizes in bytes")
    ap.add_argument("--max-configs", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help=f"TuneDB path (default {default_db_path()})")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit latmodel constants from the sweep and report")
    args = ap.parse_args(argv)

    _ensure_devices(args.devices)
    import jax  # after XLA_FLAGS is settled

    try:
        sizes = ([int(s) for s in args.sizes.split(",")]
                 if args.sizes else None)
    except ValueError:
        ap.error(f"--sizes must be comma-separated integers, got {args.sizes!r}")
    colls = [c.strip() for c in args.collectives.split(",") if c.strip()]
    unknown = [c for c in colls if c not in SWEEPABLE]
    if unknown:
        ap.error(f"unknown collective(s) {unknown}; sweepable: {SWEEPABLE}")

    db = TuneDB.load(args.out)
    db = run_sweep(collectives=colls, sizes=sizes, fast=args.fast, db=db,
                   max_configs=args.max_configs, log=lambda s: print(s, flush=True))
    path = db.save(args.out)
    print(f"wrote {len(db)} entries -> {path}")

    if args.calibrate:
        from repro.tune.calibrate import calibrate_from_db, model_vs_measured
        result = calibrate_from_db(db)
        print(result.summary())
        for row in model_vs_measured(result, db):
            print("  " + row)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
