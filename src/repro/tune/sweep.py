"""Measured configuration sweeps — the b_eff synthetic benchmark, automated.

For every (collective, message size, candidate ``CommConfig``) triple the
engine builds the real SPMD program on the running mesh, times it with warmup
(wall clock, ``block_until_ready``), and records the result in a
:class:`~repro.tune.db.TuneDB`.  Scheduling is honored the way the runtime
honors it: fused configs time K ops inside ONE compiled program (one host
dispatch amortized over the loop), host-scheduled configs block on every call
— the same methodology as ``benchmarks/b_eff.py``.

CLI::

    PYTHONPATH=src python -m repro.tune.sweep --fast            # smoke sweep
    PYTHONPATH=src python -m repro.tune.sweep --sizes 1024,65536 \
        --collectives all_reduce,sendrecv --out .repro_tune/tunedb.json
    # virtual 4x4 torus, per-edge hop-distance axis (TuneEntry.hops)
    PYTHONPATH=src python -m repro.tune.sweep --devices 16 --topology 4x4 \
        --hop-distances 1,2,4 --collectives sendrecv --sizes small
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from contextlib import nullcontext
from functools import partial
from pathlib import Path
from typing import Callable, Sequence

import numpy as np

from repro.core import plans, planstore, reliable
from repro.core.config import (CommConfig, CommMode, Reliability, Scheduling,
                               V5E)
from repro.core.topology import TorusSpec
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.tune import prune as tune_prune
from repro.tune import space as tune_space
from repro.tune.db import TuneDB, TuneEntry, default_db_path, topology_key

# Message sizes (bytes per device) swept by default — the paper's Fig. 4 spans
# 64 B .. 4 MiB; host-CPU meshes get a truncated range to keep compiles sane.
FULL_SIZES = (1 << 10, 1 << 14, 1 << 17, 1 << 20)
FAST_SIZES = (1 << 10, 1 << 14)
# "small" smoke set: one mid + one large size, so the pruning model still
# sees the bandwidth/segmentation-separated regime (a 16 KiB-only sweep
# cannot distinguish segment sizes — every message is a single chunk).
NAMED_SIZES = {"small": (1 << 14, 1 << 20), "full": FULL_SIZES}

SWEEPABLE = ("sendrecv", "all_reduce", "all_gather", "reduce_scatter",
             "multi_neighbor", "all_to_all", "hierarchical_all_reduce")

# Collectives with end-to-end consumer-loop benchmarks (the
# hideable-compute consumers of the paper's §5 argument), one tuple per
# collective.  all_reduce serves three phases with opposite cost
# structures: the training row-parallel matmul+reduce layer, the serving
# decode step (tiny latency-bound per-token combines with almost no
# hideable compute), and prefill (throughput-bound bulk reduces behind a
# large hideable matmul).  Under ``--objective e2e`` each consumer is
# measured separately and recorded as its own TuneEntry (tagged
# ``TuneEntry.consumer``) so ``select_config(consumer=...)`` can answer
# per phase.  The first consumer in each tuple is the primary one — the
# one the pruning model predicts with.
CONSUMERS: dict[str, tuple[str, ...]] = {
    "all_reduce": ("row_parallel", "decode_step", "prefill"),
    "multi_neighbor": ("halo_fold",),
    "all_to_all": ("moe_loop",),
}

# Collectives whose benchmark pattern is parameterized by a torus hop
# distance (the --hop-distances axis): the perm is a translation of the
# whole virtual torus by exactly d hops.
HOP_PATTERNED = ("sendrecv", "multi_neighbor")

OBJECTIVES = ("latency", "e2e")

# row_parallel consumer geometry: the reduced output is (tokens, _ROWPAR_D)
# with tokens*_ROWPAR_D*4 = msg_bytes; the hideable per-device matmul
# contracts over _ROWPAR_FF features.
_ROWPAR_D = 64
_ROWPAR_FF = 128
# moe_loop consumer geometry: (tokens, _MOE_D) dispatch payload with
# tokens*_MOE_D*4 = msg_bytes; each expert's FFN expands to _MOE_FF.
_MOE_D = 32
_MOE_FF = 64
# decode_step consumer geometry: a (batch, _DEC_D) per-token activation with
# batch*_DEC_D*4 = msg_bytes; the per-step matmul contracts over _DEC_D —
# near-zero hideable compute, latency-bound (the serving decode phase).
_DEC_D = 16
# prefill consumer geometry: (tokens, _PRE_FF) activations with
# tokens*_PRE_FF*4 = msg_bytes and a _PRE_FF-wide contraction — a large
# hideable matmul per combine, throughput-bound (the serving prefill phase).
_PRE_FF = 256


def consumer_flops(collective: str, msg_bytes: int,
                   consumer: str | None = None) -> float:
    """Hideable per-iteration compute (FLOPs) of a collective's consumer
    loop — feeds the e2e prediction (compute_s = flops / peak).  With
    ``consumer`` omitted, the collective's primary consumer is assumed."""
    if consumer is None:
        consumer = (CONSUMERS.get(collective) or ("",))[0]
    if collective == "all_reduce":
        if consumer == "decode_step":
            # tiny per-token matmul + the LSE max/sum pair: ~4 flops/elem
            return 4.0 * (msg_bytes / 4.0)
        if consumer == "prefill":
            # bulk matmul: 2 * tokens * ff^2 with tokens*ff = msg_bytes/4
            return 2.0 * _PRE_FF * (msg_bytes / 4.0)
        # matmul: 2 * tokens * ff * d with tokens*d = msg_bytes/4 elements
        return 2.0 * _ROWPAR_FF * (msg_bytes / 4.0)
    if collective == "multi_neighbor":
        # elementwise interior update over the state (~12 flops/element)
        return 12.0 * (msg_bytes / 4.0)
    if collective == "all_to_all":
        # expert FFN: two matmuls (D->FF, FF->D) over tokens*D = msg/4 elems
        return 4.0 * _MOE_FF * (msg_bytes / 4.0)
    return 0.0


# ----------------------------------------------------------------------
# Microbenchmark program builders
# ----------------------------------------------------------------------

def _payload_elems(msg_bytes: int, n: int) -> int:
    """float32 elements per device, padded to a multiple of the mesh size so
    reduce-scatter/all-to-all constraints hold for every collective."""
    elems = max(n, msg_bytes // 4)
    return elems + (-elems) % n


def _mesh_key(mesh) -> tuple:
    """Program-cache key component for the bench mesh's STRUCTURE.

    ``topology_key`` is only platform:n_devices — two factorizations of the
    same device count (an 8-rank axis vs a 4x2 inner/outer mesh) compile
    different programs and must never replay each other's."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape))


def _multi_neighbor_rounds(comm) -> list:
    """The 4-neighbor halo pattern (ring distance ±1, ±2) — the SWE
    exchange.  Single source for both the benchmark op and the hop distance
    recorded with its measurements."""
    return [comm.ring_perm(1), comm.reverse_ring_perm(1),
            comm.ring_perm(2), comm.reverse_ring_perm(2)]


def _pattern_hops(collective: str, comm) -> int:
    """Worst-case torus hop distance of the pattern a collective exercises
    (recorded per TuneEntry so selection can prefer hop-matched results)."""
    if collective == "multi_neighbor":
        return comm.max_hops(
            [e for r in _multi_neighbor_rounds(comm) for e in r])
    if collective == "all_to_all":
        # every rank exchanges with every other rank
        return max((comm.torus_hops(0, j) for j in range(comm.size)),
                   default=0) or 1
    return comm.max_hops(comm.ring_perm())


def _build_op(collective: str, comm, cfg: CommConfig,
              subcomms=None, hop_distance: int | None = None) -> Callable:
    """Per-device body (x -> x-shaped array) exercising one collective op.

    ``subcomms`` is the (inner, outer) communicator pair for the
    hierarchical (cross-pod) all-reduce, which runs over a 2-axis mesh.
    ``hop_distance`` (virtual torus only) replaces the hop-patterned
    collectives' default edge list with a translation perm at exactly that
    many torus hops — the per-edge axis of the hop-distance sweep.
    """
    from jax import numpy as jnp
    from repro.core import collectives

    if hop_distance is not None and collective not in HOP_PATTERNED:
        raise ValueError(f"{collective!r} has no hop-parameterized pattern "
                         f"(hop-patterned: {HOP_PATTERNED})")
    if collective == "sendrecv":
        perm = (comm.hop_perm(hop_distance) if hop_distance is not None
                else comm.ring_perm())
        def op(x):
            return collectives.sendrecv(x, perm, comm, cfg)
    elif collective == "all_reduce":
        def op(x):
            return collectives.all_reduce(x, comm, cfg) / comm.size
    elif collective == "all_gather":
        def op(x):
            y = collectives.all_gather(x, comm, cfg, axis=0)
            # keep x's shape but depend on the whole gathered result so the
            # collective cannot be dead-code-eliminated
            return x + 0.0 * jnp.sum(y)
    elif collective == "reduce_scatter":
        def op(x):
            y = collectives.reduce_scatter(x, comm, cfg)
            return x + 0.0 * jnp.sum(y)
    elif collective == "multi_neighbor":
        if hop_distance is not None:
            mn_rounds = [comm.hop_perm(hop_distance),
                         comm.topo.reverse_hop_perm(hop_distance)]
        else:
            mn_rounds = _multi_neighbor_rounds(comm)
        def op(x):
            outs = collectives.multi_neighbor_exchange(
                [x] * len(mn_rounds), mn_rounds, comm, cfg)
            return sum(outs) / len(outs)
    elif collective == "all_to_all":
        def op(x):
            # (n, elems/n) bucketed payload — the MoE dispatch shape
            y = collectives.all_to_all(x.reshape(comm.size, -1), comm, cfg)
            return x + 0.0 * jnp.sum(y)
    elif collective == "hierarchical_all_reduce":
        inner, outer = subcomms
        def op(x):
            return collectives.hierarchical_all_reduce(
                x, inner, outer, cfg) / (inner.size * outer.size)
    else:
        raise ValueError(f"unknown collective {collective!r} "
                         f"(sweepable: {SWEEPABLE})")
    return op


def _build_consumer_op(collective: str, comm, cfg: CommConfig,
                       msg_bytes: int,
                       hop_distance: int | None = None,
                       consumer: str | None = None
                       ) -> tuple[Callable, tuple]:
    """One iteration of the collective's consumer loop: (op, per_dev_shape).

    ``op`` maps a per-device payload to a same-shaped payload so iterations
    chain; the body is compute the schedule could hide the collective
    behind — the end-to-end time is what the ``e2e`` objective ranks.
    ``hop_distance`` (hop-patterned collectives on a virtual torus) swaps
    the exchange pattern for the same translation perm the bare benchmark
    measures, so a per-hop ``e2e_us`` really routed at that distance.
    ``consumer`` picks one of the collective's loops from
    :data:`CONSUMERS` (default: the primary one) — all_reduce serves
    row_parallel (training TP), decode_step (latency-bound serving), and
    prefill (throughput-bound serving).
    """
    from jax import numpy as jnp
    from repro.core import collectives, streaming

    if consumer is None:
        consumer = (CONSUMERS.get(collective) or ("",))[0]

    if collective == "all_reduce" and consumer == "decode_step":
        # Serving decode step: a tiny (batch, d) per-token activation, the
        # LSE-combine pair (max reduce + sum reduce — exactly the partial-
        # attention combine in models.attention.decode_attention) and a
        # row-parallel output combine with a near-trivial matmul.  Almost
        # no hideable compute: the config's fixed per-op cost dominates,
        # which is what makes decode's winner differ from prefill's.
        b = max(4, msg_bytes // 4 // _DEC_D)
        w = jnp.asarray(
            np.random.RandomState(2).randn(_DEC_D, _DEC_D) * 0.05,
            jnp.float32)

        def op(h):
            m = collectives.all_reduce(h, comm, cfg, op="max")
            if (cfg.mode == CommMode.STREAMING
                    or cfg.scheduling == Scheduling.OVERLAPPED):
                y = streaming.overlapped_matmul_allreduce(h, w, comm, cfg)
            else:
                partial = jnp.dot(h, w, preferred_element_type=jnp.float32)
                y = collectives.all_reduce(partial, comm, cfg)
            return jnp.tanh(h + 1e-3 * (y - 1e-3 * m))

        return op, (b, _DEC_D)

    if collective == "all_reduce" and consumer == "prefill":
        # Serving prefill: bulk (tokens, ff) activations with a wide
        # hideable matmul per combine — throughput-bound; the overlapped
        # schedules can hide most of the wire time behind the contraction.
        tokens = max(8, msg_bytes // 4 // _PRE_FF)
        w = jnp.asarray(
            np.random.RandomState(3).randn(_PRE_FF, _PRE_FF) * 0.05,
            jnp.float32)

        def op(h):
            if (cfg.mode == CommMode.STREAMING
                    or cfg.scheduling == Scheduling.OVERLAPPED):
                y = streaming.overlapped_matmul_allreduce(h, w, comm, cfg)
            else:
                partial = jnp.dot(h, w, preferred_element_type=jnp.float32)
                y = collectives.all_reduce(partial, comm, cfg)
            return jnp.tanh(h + 1e-3 * y)

        return op, (tokens, _PRE_FF)

    if collective == "all_reduce":
        # Row-parallel TP layer: per-device matmul + combine of the partial
        # sum.  Mirrors models.layers.row_parallel: streaming mode or
        # overlapped scheduling routes the chunked, double-buffered
        # overlapped_matmul_allreduce; buffered+fused/host issues one
        # all_reduce after the full matmul.
        tokens = max(8, msg_bytes // 4 // _ROWPAR_D)
        w = jnp.asarray(
            np.random.RandomState(0).randn(_ROWPAR_FF, _ROWPAR_D) * 0.05,
            jnp.float32)

        def op(h):
            if (cfg.mode == CommMode.STREAMING
                    or cfg.scheduling == Scheduling.OVERLAPPED):
                y = streaming.overlapped_matmul_allreduce(h, w, comm, cfg)
            else:
                partial = jnp.dot(h, w, preferred_element_type=jnp.float32)
                y = collectives.all_reduce(partial, comm, cfg)
            # feed the reduced output back into the activation shape so the
            # next iteration depends on this one
            return jnp.tanh(h + 1e-3 * jnp.sum(y, axis=-1, keepdims=True))

        return op, (tokens, _ROWPAR_FF)

    if collective == "multi_neighbor":
        # Halo-fold step: 4-neighbor exchange + fold of the received halos
        # + an interior element update the overlapped schedule can issue
        # while the exchange is in flight.
        if hop_distance is not None:
            rounds = [comm.hop_perm(hop_distance),
                      comm.topo.reverse_hop_perm(hop_distance)]
        else:
            rounds = _multi_neighbor_rounds(comm)
        n = comm.size
        elems = _payload_elems(msg_bytes, n)

        def op(x):
            payloads = [x] * len(rounds)
            interior = x * 0.999 + 0.001 * jnp.tanh(x)     # hideable compute
            if cfg.scheduling == Scheduling.OVERLAPPED:
                halo, _ = collectives.multi_neighbor_exchange(
                    payloads, rounds, comm, cfg,
                    consume=lambda c, r, m: c + m, init=jnp.zeros_like(x))
            else:
                received = collectives.multi_neighbor_exchange(
                    payloads, rounds, comm, cfg)
                halo = sum(received)
            return interior + 1e-3 * jnp.tanh(halo)

        return op, (elems,)

    if collective == "all_to_all":
        # MoE expert loop: dispatch (all_to_all) -> expert FFN -> combine
        # (all_to_all back).  The FFN is the hideable compute: the chunked
        # overlapped dispatch/combine (streaming.chunked_all_to_all) lets
        # the scheduler run expert matmuls on chunk i while chunk i+1 is on
        # the wire — the third consumer of the paper's §5 argument.
        n = comm.size
        tokens = max(n, msg_bytes // 4 // _MOE_D)
        tokens += (-tokens) % n              # all_to_all split constraint
        rng = np.random.RandomState(1)
        w1 = jnp.asarray(rng.randn(_MOE_D, _MOE_FF) * 0.05, jnp.float32)
        w2 = jnp.asarray(rng.randn(_MOE_FF, _MOE_D) * 0.05, jnp.float32)

        def op(x):
            y = collectives.all_to_all(x, comm, cfg)            # dispatch
            h = jnp.tanh(jnp.dot(y, w1,
                                 preferred_element_type=jnp.float32))
            h = jnp.dot(h, w2, preferred_element_type=jnp.float32)
            z = collectives.all_to_all(h.astype(x.dtype), comm, cfg)  # combine
            return jnp.tanh(x + 1e-3 * z)

        return op, (tokens, _MOE_D)

    raise ValueError(f"no consumer-loop benchmark {consumer!r} for "
                     f"{collective!r} (consumers: {CONSUMERS})")


# Per-rep seconds of the most recent _time_program call.  The sweep reads
# this right after each measurement to estimate the candidate's tail
# (TuneEntry.p95_us) without changing the timer's return contract; injected
# test timers never populate it, so the sweep's p95 falls back to 0.0 (the
# "no tail data" sentinel) instead of inheriting a stale run's samples —
# run_sweep clears the list before every timer call.
_LAST_SAMPLES: list[float] = []


def _time_program(op: Callable, mesh, msg_bytes: int, cfg: CommConfig,
                  warmup: int = 1, reps: int = 3, inner: int = 8,
                  per_dev_shape: tuple | None = None,
                  cache_key: tuple | None = None) -> float:
    """Seconds per collective op under the config's scheduling discipline.

    With ``cache_key`` given, the jitted program is fetched from / stored in
    the :mod:`repro.core.plans` program cache: a warm sweep (same process,
    same collective/config/size/topology) replays the compiled program and
    pays zero rebuild/retrace — the plan-cache half of the sweep wall-clock
    win.

    Each rep's per-op seconds are additionally appended to
    :data:`_LAST_SAMPLES` (cleared on entry), the raw material for the
    sweep's per-candidate tail estimate.
    """
    import jax
    from jax import numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat

    # Shard dim 0 jointly over every mesh axis (the hierarchical all-reduce
    # benches on a 2-axis inner×outer mesh; everything else on one axis).
    spec = P(tuple(mesh.axis_names))
    n = mesh.devices.size
    if per_dev_shape is None:
        per_dev_shape = (_payload_elems(msg_bytes, n),)
    # Committed to the output sharding up front: every call (including the
    # first) then presents one input layout, so the program compiles once
    # and an AOT-serialized executable replays for all of them.
    x = jax.device_put(jnp.zeros((n,) + tuple(per_dev_shape), jnp.float32),
                       jax.sharding.NamedSharding(mesh, spec))

    def build_single():
        return jax.jit(compat.shard_map(
            lambda xs: op(xs[0])[None], mesh=mesh,
            in_specs=spec, out_specs=spec, check_vma=False))

    if cfg.scheduling != Scheduling.HOST:
        # fused and overlapped are both device-scheduled: one dispatch
        # amortized over the compiled loop
        def build_many():
            def many(xs):
                for _ in range(inner):
                    xs = compat.shard_map(
                        lambda v: op(v[0])[None], mesh=mesh,
                        in_specs=spec, out_specs=spec, check_vma=False)(xs)
                return xs
            return jax.jit(many)

        if cache_key is not None:
            fn = plans.jitted_program(
                cache_key + ("many", inner, tuple(per_dev_shape)), build_many,
                example_args=(x,))
        else:
            fn = build_many()
        for _ in range(warmup):
            x = jax.block_until_ready(fn(x))
        del _LAST_SAMPLES[:]
        t0 = time.perf_counter()
        for _ in range(reps):
            t1 = time.perf_counter()
            x = jax.block_until_ready(fn(x))
            _LAST_SAMPLES.append((time.perf_counter() - t1) / inner)
        return (time.perf_counter() - t0) / (reps * inner)

    # Host scheduling: one dispatch per op, host blocks between dispatches.
    if cache_key is not None:
        single = plans.jitted_program(
            cache_key + ("single", tuple(per_dev_shape)), build_single,
            example_args=(x,))
    else:
        single = build_single()
    for _ in range(warmup):
        x = jax.block_until_ready(single(x))
    del _LAST_SAMPLES[:]
    t0 = time.perf_counter()
    for _ in range(reps):
        t1 = time.perf_counter()
        for _ in range(inner):
            x = jax.block_until_ready(single(x))
        _LAST_SAMPLES.append((time.perf_counter() - t1) / inner)
    return (time.perf_counter() - t0) / (reps * inner)


# ----------------------------------------------------------------------
# Sweep driver
# ----------------------------------------------------------------------

def _seed_calibration(mesh, comm, db: TuneDB, topo: str,
                      sizes: Sequence[int], reps: int, inner: int,
                      log: Callable[[str], None], timer=None,
                      torus: str = ""):
    """Cold-cache calibration seed: measure the sendrecv corner configs so
    the Eq. 1 fit has points on THIS substrate before pruning starts.  The
    seed measurements are real TuneDB entries (they also serve selection)."""
    log("[prune] cold cache: seeding Eq.1 calibration with a sendrecv "
        "corner sweep")
    timer = timer or _time_program
    hops = _pattern_hops("sendrecv", comm)
    for msg_bytes in sizes:
        for cfg in tune_space.enumerate_configs("sendrecv", fast=True):
            try:
                op = _build_op("sendrecv", comm, cfg)
                sec = timer(
                    op, mesh, msg_bytes, cfg, reps=reps, inner=inner,
                    cache_key=("sweep", topo, torus, 0, _mesh_key(mesh),
                               "sendrecv",
                               tuple(sorted(tune_space.config_to_dict(
                                   cfg).items())), int(msg_bytes)))
            except Exception as e:  # noqa: BLE001
                log(f"  seed skip sendrecv/{msg_bytes}B: "
                    f"{type(e).__name__}: {e}")
                continue
            db.add(TuneEntry(
                topo=topo, collective="sendrecv", msg_bytes=int(msg_bytes),
                config=tune_space.config_to_dict(cfg),
                us_per_call=sec * 1e6, gbps=msg_bytes / sec / 1e9,
                hops=hops, torus=torus))
    return tune_prune.calibration_from_db(db, topo)


def run_sweep(mesh=None, collectives: Sequence[str] = SWEEPABLE,
              sizes: Sequence[int] | None = None, fast: bool = False,
              db: TuneDB | None = None, max_configs: int | None = None,
              reps: int = 3, inner: int = 8,
              log: Callable[[str], None] | None = None,
              prune: bool = False,
              prune_ratio: float = tune_prune.DEFAULT_RATIO,
              calibration=None,
              objective: str = "latency",
              stats: dict | None = None,
              topology: TorusSpec | None = None,
              hop_distances: Sequence[int] | None = None,
              loss_rate: float = 0.0,
              timer: Callable | None = None) -> TuneDB:
    """Measure every candidate config and return the populated TuneDB.

    ``prune=True`` enables the paper-style model-guided search: an Eq. 1
    calibration (fitted from existing sendrecv entries, or from a small
    seed sweep on a cold cache) predicts every candidate's latency and the
    sweep skips configs ranked more than ``prune_ratio``× off the predicted
    incumbent.  ``stats`` (optional dict) receives the bookkeeping:
    candidate/measured/pruned counts and wall clock, including the
    estimated exhaustive wall clock the pruning saved and the plan-cache
    hit/miss deltas.

    ``objective="e2e"`` additionally measures each candidate *end-to-end*
    for the collectives with consumer-loop benchmarks (:data:`CONSUMERS`:
    the row-parallel matmul+reduce layer, the serving decode step and
    prefill loops, the halo-fold step, and the MoE
    dispatch→expert-FFN→combine loop) — one measurement and one tagged
    ``TuneEntry`` per consumer, so ``select_config(consumer=...)`` answers
    per phase from a single sweep — keeps consumer-distinct candidates
    (overlapped scheduling) in the space, and — with ``prune=True`` —
    ranks candidates by the overlap-aware e2e prediction instead of bare
    Eq. 1 latency.

    ``topology`` places the bench communicator on a virtual multi-hop torus
    (:class:`~repro.core.topology.TorusSpec`): multi-hop edges physically
    route through intermediate ranks, so measured latency carries the
    per-hop cost.  ``hop_distances`` adds the per-edge sweep axis — the
    hop-patterned collectives (:data:`HOP_PATTERNED`) are measured once per
    distance with ``TuneEntry.hops`` recording it, which is what lets
    ``select_config(hops=...)`` answer per edge.

    ``loss_rate`` > 0 sweeps a LOSSY wire: every candidate is forced to
    ``Reliability.GUARANTEED`` (best-effort delivery cannot survive chunk
    loss), each measurement runs under a seeded
    :class:`~repro.core.reliable.WireFaults` chunk-drop schedule at that
    rate, and entries record ``TuneEntry.loss`` so selection can prefer
    configs measured on a matching wire — the sweep half of the paper's
    "jumbo frames win clean links, small segments win lossy ones" answer.

    ``timer`` overrides the measurement function (signature of
    :func:`_time_program`) — deterministic model-driven timers make the
    selection pipeline testable end-to-end without wall-clock noise.
    """
    import jax
    from repro import compat
    from repro.core.communicator import Communicator

    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {OBJECTIVES}, "
                         f"got {objective!r}")
    if not 0.0 <= loss_rate < 1.0:
        raise ValueError(f"loss_rate must be in [0, 1), got {loss_rate}")
    # One seeded schedule for the whole sweep: every candidate faces the
    # SAME drop pattern (reliable.inject resets the message counter per
    # measurement), so latency differences are config, not luck.
    wire = (reliable.WireFaults(seed=17, drop=loss_rate)
            if loss_rate > 0.0 else None)
    losskey: tuple = (("loss", loss_rate),) if wire is not None else ()
    if mesh is None:
        mesh = compat.make_mesh((jax.device_count(),), ("x",))
    if sizes is None:
        sizes = FAST_SIZES if fast else FULL_SIZES
    if db is None:
        db = TuneDB()
    if fast:
        reps, inner = min(reps, 2), min(inner, 4)
    log = log or (lambda s: None)
    timer = timer or _time_program
    stats = stats if stats is not None else {}
    stats.update(total=0, measured=0, pruned=0, errors=0, e2e_measured=0,
                 wall_s=0.0)
    # Plan-cache deltas come from the obs.metrics registry (the counters
    # behind plans.cache_stats()), so the warm-sweep report shares one
    # source of truth with every other telemetry consumer.
    reg = obs_metrics.registry()
    # Witness for the elastic runtime's no-resweep guarantee: recovery tests
    # assert this counter stays flat across model-based re-selection.
    reg.counter("sweep.runs").inc()
    cache_ctrs = {k: reg.counter(f"plans.{k}") for k in
                  ("plan_hits", "plan_misses",
                   "program_hits", "program_misses",
                   "disk_hits", "disk_misses")}
    cache_before = {k: int(c.value) for k, c in cache_ctrs.items()}
    t_start = time.perf_counter()

    axis = mesh.axis_names[0]
    comm = Communicator.from_mesh(mesh, axis, topo=topology)
    topo = topology_key(mesh)
    torus = topology.name if topology is not None else ""
    n = mesh.devices.size
    if hop_distances is not None:
        if topology is None:
            raise ValueError("--hop-distances requires --topology "
                             "(hop distances live on a virtual torus)")
        bad = [d for d in hop_distances
               if not 1 <= d <= topology.diameter]
        if bad:
            raise ValueError(f"hop distances {bad} outside this torus's "
                             f"[1, {topology.diameter}]")

    if prune and calibration is None:
        calibration = tune_prune.calibration_from_db(db, topo)
        if calibration is None:
            # Seed wall clock is tracked separately: it is calibration
            # overhead, not sweep time, and must not inflate the
            # estimated-exhaustive comparison.  Seed entries land in the
            # DB, so a sendrecv sweep in the same run keeps the faster of
            # the two measurements per config.
            t_seed = time.perf_counter()
            calibration = _seed_calibration(mesh, comm, db, topo, sizes,
                                            reps, inner, log, timer=timer,
                                            torus=torus)
            stats["seed_s"] = time.perf_counter() - t_seed
        if calibration is None:
            log("[prune] calibration unavailable — sweeping exhaustively")
        else:
            log(f"[prune] {calibration.summary()}")

    for coll in collectives:
        bench_mesh, subcomms = mesh, None
        if coll == "hierarchical_all_reduce":
            if n < 4 or n % 2:
                log(f"[{topo}] {coll}: skipped (needs an even device count "
                    f">= 4, have {n})")
                continue
            # inner (in-pod / ICI) × outer (cross-pod / DCN) factorization
            bench_mesh = compat.make_mesh((n // 2, 2), ("inner", "outer"))
            inner_comm = Communicator.from_mesh(bench_mesh, "inner")
            outer_comm = Communicator.from_mesh(bench_mesh, "outer")
            subcomms = (inner_comm, outer_comm)
        cands = tune_space.enumerate_configs(coll, fast=fast,
                                             objective=objective)
        if wire is not None:
            # Best-effort candidates cannot deliver under chunk loss:
            # promote everything to GUARANTEED and dedup (promotion can
            # collide candidates that differed only in reliability).
            forced, seen_cfg = [], set()
            for c in cands:
                g = dataclasses.replace(c,
                                        reliability=Reliability.GUARANTEED)
                if g not in seen_cfg:
                    seen_cfg.add(g)
                    forced.append(g)
            cands = forced
        if max_configs is not None:
            cands = cands[:max_configs]
        # The per-edge axis: hop-patterned collectives sweep once per
        # requested distance; everything else measures its natural pattern.
        if (hop_distances is not None and coll in HOP_PATTERNED):
            distances: list[int | None] = list(hop_distances)
        else:
            distances = [None]
        consumers = CONSUMERS.get(coll, ()) if objective == "e2e" else ()
        for hop_d in distances:
            hops = hop_d if hop_d is not None else _pattern_hops(coll, comm)
            log(f"[{topo}{'/' + torus if torus else ''}] {coll}: "
                f"{len(cands)} configs x {len(sizes)} sizes "
                f"(pattern hops={hops}"
                + (f", e2e consumers={','.join(consumers)}"
                   if consumers else "") + ")")
            for msg_bytes in sizes:
                stats["total"] += len(cands)
                to_measure = cands
                if prune and calibration is not None:
                    # The primary consumer's compute feeds the prediction;
                    # pruning is shared across the consumer set (a config
                    # hopeless for the primary loop is measured for none).
                    compute_s = (consumer_flops(coll, msg_bytes)
                                 / V5E.peak_flops if consumers else 0.0)
                    to_measure, skipped = tune_prune.prune_candidates(
                        cands, msg_bytes, calibration, prune_ratio,
                        collective=coll,
                        objective="e2e" if consumers else "latency",
                        compute_s=compute_s, hops=hops, loss=loss_rate)
                    stats["pruned"] += len(skipped)
                    reg.counter("sweep.pruned").inc(len(skipped))
                    if skipped:
                        log(f"  prune {coll}/{msg_bytes}B: measuring "
                            f"{len(to_measure)}/{len(cands)} (model skipped "
                            f"{len(skipped)})")
                cfg_key = lambda c: tuple(sorted(
                    tune_space.config_to_dict(c).items()))
                for i, cfg in enumerate(to_measure):
                    try:
                        op = _build_op(coll, comm, cfg, subcomms=subcomms,
                                       hop_distance=hop_d)
                        del _LAST_SAMPLES[:]
                        with obs_trace.span("sweep.candidate", cat="sweep",
                                            collective=coll,
                                            msg_bytes=int(msg_bytes),
                                            hops=hops, cfg=i) as sp:
                            with (reliable.inject(wire) if wire is not None
                                  else nullcontext()):
                                sec = timer(
                                    op, bench_mesh, msg_bytes, cfg,
                                    reps=reps, inner=inner,
                                    cache_key=("sweep", topo, torus,
                                               hop_d or 0,
                                               _mesh_key(bench_mesh),
                                               coll, cfg_key(cfg),
                                               int(msg_bytes)) + losskey)
                            sp.set(us_per_call=sec * 1e6)
                        # Per-rep samples feed both the aggregate series and
                        # this candidate's tail estimate; timers that report
                        # only a mean contribute that single point.
                        samples = [s * 1e6 for s in _LAST_SAMPLES]
                        hist = reg.histogram("sweep.us", collective=coll)
                        for v in (samples or [sec * 1e6]):
                            hist.observe(v)
                        p95_us = obs_metrics.percentile_of(samples, 95.0)
                    except Exception as e:  # noqa: BLE001 — skip unrunnable combos
                        stats["errors"] += 1
                        log(f"  skip {coll}/{msg_bytes}B cfg{i}: "
                            f"{type(e).__name__}: {e}")
                        continue
                    # One e2e measurement per consumer loop: the same bare
                    # candidate yields one TuneEntry per consumer (tagged),
                    # so selection can answer per phase from one sweep.
                    consumer_e2e: dict[str, float] = {}
                    for consumer in consumers:
                        try:
                            cop, shape = _build_consumer_op(
                                coll, comm, cfg, msg_bytes,
                                hop_distance=hop_d, consumer=consumer)
                            with (reliable.inject(wire) if wire is not None
                                  else nullcontext()):
                                e2e_sec = timer(
                                    cop, bench_mesh, msg_bytes, cfg,
                                    reps=reps, inner=inner,
                                    per_dev_shape=shape,
                                    cache_key=("sweep_e2e", topo, torus,
                                               hop_d or 0,
                                               _mesh_key(bench_mesh), coll,
                                               consumer, cfg_key(cfg),
                                               int(msg_bytes)) + losskey)
                            consumer_e2e[consumer] = e2e_sec * 1e6
                            stats["e2e_measured"] += 1
                            reg.histogram("sweep.e2e_us",
                                          collective=coll).observe(
                                              e2e_sec * 1e6)
                        except Exception as e:  # noqa: BLE001
                            stats["errors"] += 1
                            log(f"  skip e2e {coll}/{consumer}/"
                                f"{msg_bytes}B cfg{i}: "
                                f"{type(e).__name__}: {e}")
                    stats["measured"] += 1
                    for consumer, e2e_us in (consumer_e2e.items()
                                             or ((None, 0.0),)):
                        db.add(TuneEntry(
                            topo=topo, collective=coll,
                            msg_bytes=int(msg_bytes),
                            config=tune_space.config_to_dict(cfg),
                            us_per_call=sec * 1e6,
                            gbps=msg_bytes / sec / 1e9,
                            hops=hops, e2e_us=e2e_us, torus=torus,
                            p95_us=p95_us, loss=loss_rate,
                            consumer=consumer or ""))
                best = db.best(coll, msg_bytes, topo, hops=hops)
                if best is not None:
                    log(f"  {coll:15s} {msg_bytes:>8d}B h{hops} best "
                        f"{best.us_per_call:9.1f} us  ({best.gbps:6.3f} GB/s)  "
                        f"{best.config['mode']}/{best.config['scheduling']}"
                        f"/{best.config['algorithm']}")
                for consumer in consumers:
                    be = db.best(coll, msg_bytes, topo, hops=hops,
                                 objective="e2e", consumer=consumer)
                    if be is not None and be.e2e_us > 0.0:
                        log(f"  {coll:15s} {msg_bytes:>8d}B h{hops} best e2e "
                            f"{be.e2e_us:9.1f} us/iter "
                            f"({consumer}) "
                            f"{be.config['mode']}/{be.config['scheduling']}")
    stats["wall_s"] = time.perf_counter() - t_start
    for k, c in cache_ctrs.items():
        stats[k] = int(c.value) - cache_before[k]
    stats["latency_hist"] = reg.find("sweep.us{")
    # The visible pruning win: scale the measured wall clock (minus any
    # calibration-seed overhead) back up to the exhaustive candidate count
    # (per-config cost assumed comparable).
    if stats["measured"]:
        sweep_s = stats["wall_s"] - stats.get("seed_s", 0.0)
        stats["est_exhaustive_s"] = sweep_s * stats["total"] / stats["measured"]
    return db


def sweep_summary(stats: dict) -> str:
    """One-line wall-clock summary (exhaustive vs calibration-pruned), plus
    the plan-cache hit/miss counts behind the warm-sweep win."""
    line = (f"sweep wall clock {stats.get('wall_s', 0.0):.1f}s: measured "
            f"{stats.get('measured', 0)}/{stats.get('total', 0)} candidate "
            f"configs")
    if stats.get("e2e_measured"):
        line += f" ({stats['e2e_measured']} consumer-loop e2e)"
    if stats.get("pruned"):
        line += (f" — {stats['pruned']} pruned by the calibrated model "
                 f"(exhaustive est. ~{stats.get('est_exhaustive_s', 0.0):.1f}s)")
    line += (f" — plan cache: {stats.get('program_hits', 0)} program hits / "
             f"{stats.get('program_misses', 0)} misses, "
             f"{stats.get('plan_hits', 0)} plan hits / "
             f"{stats.get('plan_misses', 0)} misses")
    if stats.get("disk_hits", 0) or stats.get("disk_misses", 0):
        line += (f" — plan store: {stats.get('disk_hits', 0)} disk hits / "
                 f"{stats.get('disk_misses', 0)} disk misses")
    hists = stats.get("latency_hist") or {}
    for name, h in sorted(hists.items()):
        if h.get("count"):
            line += (f"\n  {name}: p50 {h['p50']:.1f} us, "
                     f"p95 {h['p95']:.1f} us over {h['count']} samples")
    return line


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def _ensure_devices(n: int) -> None:
    """Re-exec with N host CPU devices when launched on a single device."""
    if os.environ.get("REPRO_TUNE_NO_REEXEC"):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
        os.environ["REPRO_TUNE_NO_REEXEC"] = "1"
        os.execv(sys.executable,
                 [sys.executable, "-m", "repro.tune.sweep"] + sys.argv[1:])


def _dump_stats_json(stats: dict) -> None:
    """Machine-readable stats channel: when REPRO_SWEEP_STATS_JSON names a
    path, the (first) sweep's stats dict is written there — how the
    cross-process warm check (and CI) reads a child sweep's wall clock and
    disk hit counts without parsing log lines."""
    path = os.environ.get("REPRO_SWEEP_STATS_JSON")
    if not path:
        return
    payload = {k: v for k, v in stats.items() if k != "latency_hist"}
    Path(path).write_text(json.dumps(payload))


def _cross_process_warm_check(child_argv: Sequence[str],
                              cold_s: float) -> int:
    """The second half of ``--warm-check`` when a plan store is active:
    re-run this exact sweep in a FRESH python process against the populated
    plan dir.  The child must replay plans from disk (``plans.disk_hits``
    > 0) and report a sweep wall clock >= 30% below this process's cold
    run — proving the *disk* store and the persistent compilation cache,
    not the in-process cache, are what make a restart start warm."""
    import subprocess
    import tempfile

    argv = [a for a in child_argv if a != "--warm-check"]
    fd, stats_path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    env = dict(os.environ)
    env.pop("REPRO_TUNE_NO_REEXEC", None)
    env["REPRO_SWEEP_STATS_JSON"] = stats_path
    env[planstore.ENV_VAR] = str(planstore.plan_dir())
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.tune.sweep", *argv],
            capture_output=True, text=True, env=env)
        if proc.returncode != 0:
            print("CROSS-PROCESS WARM-CHECK FAILED: child sweep exited "
                  f"{proc.returncode}\n{proc.stdout[-2000:]}"
                  f"\n{proc.stderr[-2000:]}", file=sys.stderr)
            return 5
        try:
            child = json.loads(Path(stats_path).read_text())
        except (OSError, ValueError):
            print("CROSS-PROCESS WARM-CHECK FAILED: child stats JSON "
                  "missing/unreadable", file=sys.stderr)
            return 5
    finally:
        try:
            os.unlink(stats_path)
        except OSError:
            pass
    warm_s = child.get("wall_s", float("inf"))
    disk_hits = child.get("disk_hits", 0)
    print(f"plan-store cross-process check: cold {cold_s:.1f}s -> "
          f"fresh-process warm {warm_s:.1f}s "
          f"({1.0 - warm_s / max(cold_s, 1e-9):.0%} lower), "
          f"{disk_hits} disk hits / {child.get('disk_misses', 0)} misses")
    if disk_hits <= 0:
        print("CROSS-PROCESS WARM-CHECK FAILED: the fresh process replayed "
              "zero plans from the disk store", file=sys.stderr)
        return 5
    if warm_s > 0.7 * cold_s:
        print("CROSS-PROCESS WARM-CHECK FAILED: fresh-process wall clock "
              "is not >= 30% lower than the cold run (disk store / "
              "compilation cache ineffective)", file=sys.stderr)
        return 5
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    raw_argv = list(argv) if argv is not None else sys.argv[1:]
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune.sweep",
        description="Measured CommConfig sweep -> TuneDB JSON.")
    ap.add_argument("--fast", action="store_true",
                    help="smoke sweep: corner configs, small sizes")
    ap.add_argument("--devices", type=int, default=8,
                    help="host CPU devices to force when single-device")
    ap.add_argument("--collectives", default=",".join(SWEEPABLE),
                    help=f"comma list from {SWEEPABLE}")
    ap.add_argument("--sizes", default=None,
                    help="comma list of message sizes in bytes, or a named "
                    f"set from {tuple(NAMED_SIZES)}")
    ap.add_argument("--max-configs", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help=f"TuneDB path (default {default_db_path()})")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit latmodel constants from the sweep and report")
    ap.add_argument("--prune", action="store_true",
                    help="model-guided pruning: skip configs the calibrated "
                    "Eq.1 model ranks more than --prune-ratio off the "
                    "predicted incumbent")
    ap.add_argument("--prune-ratio", type=float,
                    default=tune_prune.DEFAULT_RATIO)
    ap.add_argument("--assert-pruned", action="store_true",
                    help="exit non-zero unless the sweep measured strictly "
                    "fewer configs than the exhaustive candidate space "
                    "(CI guard for the pruning path)")
    ap.add_argument("--objective", choices=OBJECTIVES, default="latency",
                    help="ranking metric recorded by the sweep: bare "
                    "collective latency, or 'e2e' — additionally measure "
                    "each candidate inside its consumer loop (row_parallel "
                    "matmul+reduce, halo-fold step, MoE dispatch/combine) "
                    "and record TuneEntry.e2e_us for "
                    "select_config(objective='e2e')")
    ap.add_argument("--topology", default=None,
                    help="virtual torus placement, e.g. '4x4' or "
                    "'2x4:snake' (rows x cols must equal the device "
                    "count); multi-hop edges are physically routed "
                    "through intermediate ranks")
    ap.add_argument("--hop-distances", default=None,
                    help="comma list of torus hop distances to sweep the "
                    "hop-patterned collectives at (requires --topology); "
                    "each distance is recorded as TuneEntry.hops so "
                    "select_config(hops=...) answers per edge")
    ap.add_argument("--loss-rate", type=float, default=0.0,
                    help="sweep a lossy wire: seeded chunk-drop rate in "
                    "[0, 1) injected under every measurement; candidates "
                    "are forced to reliability=guaranteed and entries "
                    "record TuneEntry.loss so select_config(loss=...) can "
                    "prefer lossy-wire measurements")
    ap.add_argument("--plan-dir", default=None,
                    help="disk-backed CommPlan/program store directory "
                    "(also via REPRO_PLAN_DIR): plan schedules persist as "
                    "versioned JSON and traced programs through JAX's "
                    "persistent compilation cache, so a FRESH process "
                    "rerunning this sweep starts warm")
    ap.add_argument("--warm-check", action="store_true",
                    help="run the sweep twice in this process (cold, then "
                    "warm against the populated plan cache) and exit "
                    "non-zero unless the warm sweep's wall clock is at "
                    "least 30%% lower (plan-cache effectiveness guard); "
                    "with a plan dir active, additionally rerun the sweep "
                    "in a FRESH subprocess and require plans.disk_hits > 0 "
                    "plus the same 30%% wall-clock bar cross-process")
    args = ap.parse_args(argv)

    _ensure_devices(args.devices)
    import jax  # after XLA_FLAGS is settled

    if args.plan_dir:
        # Through the env so the re-exec above and the cross-process
        # warm-check child both inherit the same store.
        os.environ[planstore.ENV_VAR] = args.plan_dir
    store = planstore.active()
    if store is not None:
        print(f"plan store: {store.root} "
              f"({store.entry_count()} entries on disk)")

    if args.sizes in NAMED_SIZES:
        sizes = NAMED_SIZES[args.sizes]
    else:
        try:
            sizes = ([int(s) for s in args.sizes.split(",")]
                     if args.sizes else None)
        except ValueError:
            ap.error(f"--sizes must be comma-separated integers or one of "
                     f"{tuple(NAMED_SIZES)}, got {args.sizes!r}")
    colls = [c.strip() for c in args.collectives.split(",") if c.strip()]
    unknown = [c for c in colls if c not in SWEEPABLE]
    if unknown:
        ap.error(f"unknown collective(s) {unknown}; sweepable: {SWEEPABLE}")
    topology = None
    if args.topology:
        try:
            topology = TorusSpec.parse(args.topology)
        except ValueError as e:
            ap.error(str(e))
        if topology.n_ranks != jax.device_count():
            ap.error(f"--topology {args.topology} places {topology.n_ranks} "
                     f"ranks but {jax.device_count()} devices are up "
                     f"(use --devices {topology.n_ranks})")
    hop_distances = None
    if args.hop_distances:
        if topology is None:
            ap.error("--hop-distances requires --topology")
        try:
            hop_distances = [int(d) for d in args.hop_distances.split(",")]
        except ValueError:
            ap.error(f"--hop-distances must be comma-separated integers, "
                     f"got {args.hop_distances!r}")

    db = TuneDB.load(args.out)
    stats: dict = {}
    kwargs = dict(collectives=colls, sizes=sizes, fast=args.fast,
                  max_configs=args.max_configs,
                  log=lambda s: print(s, flush=True),
                  prune=args.prune, prune_ratio=args.prune_ratio,
                  objective=args.objective,
                  topology=topology, hop_distances=hop_distances,
                  loss_rate=args.loss_rate)
    db = run_sweep(db=db, stats=stats, **kwargs)
    path = db.save(args.out)
    print(f"wrote {len(db)} entries -> {path}")
    print(sweep_summary(stats))
    _dump_stats_json(stats)

    if args.warm_check:
        warm_stats: dict = {}
        db = run_sweep(db=db, stats=warm_stats, **kwargs)
        db.save(args.out)
        print("warm " + sweep_summary(warm_stats))
        # Cold cost includes any calibration seeding: its compiles are part
        # of what the first run pays and may themselves warm the program
        # cache (a sendrecv sweep with --prune measures the seeded configs).
        # A warm run skipping work via cached programs/calibration is
        # exactly the claimed win; the hits guard below (not the wall
        # clock) is what catches a silently broken cache.
        cold_s = stats.get("wall_s", 0.0)
        warm_s = warm_stats.get("wall_s", 0.0)
        print(f"plan-cache warm check: cold {cold_s:.1f}s -> warm "
              f"{warm_s:.1f}s ({1.0 - warm_s / max(cold_s, 1e-9):.0%} lower)")
        if warm_stats.get("program_hits", 0) <= 0:
            print("WARM-CHECK FAILED: the warm sweep replayed zero cached "
                  "programs (plan cache broken?)", file=sys.stderr)
            return 4
        if warm_s > 0.7 * cold_s:
            print("WARM-CHECK FAILED: warm sweep wall clock is not >= 30% "
                  "lower than cold (plan cache ineffective)",
                  file=sys.stderr)
            return 4
        if planstore.active() is not None:
            rc = _cross_process_warm_check(raw_argv, cold_s)
            if rc:
                return rc

    if args.calibrate:
        from repro.tune.calibrate import calibrate_from_db, model_vs_measured
        result = calibrate_from_db(db)
        print(result.summary())
        for row in model_vs_measured(result, db):
            print("  " + row)
    if args.assert_pruned and stats.get("pruned", 0) <= 0:
        print("ASSERT-PRUNED FAILED: the calibrated model pruned zero "
              "candidates (the sweep measured the exhaustive space)",
              file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
