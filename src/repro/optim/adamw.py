"""AdamW with ZeRO-1 optimizer-state sharding over the data axis.

Two modes (manual SPMD — runs inside shard_map):

- **plain**: full fp32 moments per device; gradients synced with an ACCL-X
  all-reduce (hierarchical across pods when the mesh has a pod axis).
- **zero1**: gradients are flattened into one vector, reduce-scattered over
  the ``data`` axis (each data rank owns 1/dp of every model shard's
  optimizer state — this is where the paper's ring reduce-scatter and its
  int8 wire compression plug in), Adam runs on the owned slice, and the
  updated slice is all-gathered back.  Cross-pod: the scattered slice is
  all-reduced over ``pod`` between the RS and the update.

FSDP-sharded leaves (grad already reduced over ``data`` by the all-gather
transpose) keep per-rank moments and update in place — ZeRO-3 naturally.

Moments can be stored bf16 (``moment_dtype``) — the optimizer-state analogue
of the compression plugin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import collectives
from repro.core.communicator import Communicator
from repro.core.config import CommConfig
from repro.models.common import Runtime


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    zero1: bool = True
    moment_dtype: Any = jnp.float32
    # Separate wire config for the gradient reduce-scatter/all-gather (e.g.
    # ring + int8 compression) without touching the forward TP collectives.
    grad_comm: Optional[CommConfig] = None


def schedule(step, oc: OptConfig):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, oc.warmup_steps))
    t = jnp.clip((step - oc.warmup_steps)
                 / max(1, oc.total_steps - oc.warmup_steps), 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * cos


# ----------------------------------------------------------------------
# Flat packing helpers (ZeRO-1)
# ----------------------------------------------------------------------

def _flatten(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def _unflatten(flat, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def init_state(params, oc: OptConfig, rt: Runtime, fsdp_plan=None):
    """Optimizer state pytree.

    zero1: flat slices of size ceil(P/dp) per data rank for non-FSDP leaves;
    per-leaf moments for FSDP leaves (already data-sharded in storage).
    """
    dp = rt.mesh.data_sizes[-1]
    step = jnp.zeros((), jnp.int32)
    if not oc.zero1 or dp == 1:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, oc.moment_dtype), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, oc.moment_dtype), params)
        return {"m": m, "v": v, "step": step}
    reg, fs = partition_params(params, fsdp_plan)
    n = sum(l.size for l in jax.tree.leaves(reg))
    pad = (-n) % dp
    k = (n + pad) // dp
    # Leading (1, 1) dims: the slice differs across BOTH the model and data
    # axes, so its global representation is (tp, dp, k) with spec
    # P('model', 'data', None); locally it is (1, 1, k).
    return {
        "m_slice": jnp.zeros((1, 1, k), oc.moment_dtype),
        "v_slice": jnp.zeros((1, 1, k), oc.moment_dtype),
        "m_fsdp": jax.tree.map(lambda p: jnp.zeros(p.shape, oc.moment_dtype), fs),
        "v_fsdp": jax.tree.map(lambda p: jnp.zeros(p.shape, oc.moment_dtype), fs),
        "step": step,
    }


def partition_params(params, fsdp_plan):
    """Split the tree into (regular, fsdp) by the fsdp plan codes."""
    if fsdp_plan is None:
        return params, jax.tree.map(lambda p: None, params)
    reg = jax.tree.map(lambda p, c: None if c >= 0 else p, params, fsdp_plan)
    fs = jax.tree.map(lambda p, c: p if c >= 0 else None, params, fsdp_plan)
    return reg, fs


def _merge(reg, fs):
    return jax.tree.map(lambda a, b: a if b is None else b, reg, fs,
                        is_leaf=lambda x: x is None)


def _adam_update(g, m, v, p32, lr, oc: OptConfig, step):
    b1, b2 = oc.b1, oc.b2
    m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
    v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
    t = step.astype(jnp.float32) + 1.0
    mhat = m32 / (1 - b1 ** t)
    vhat = v32 / (1 - b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p32
    return p32 - lr * upd, m32.astype(m.dtype), v32.astype(v.dtype)


def apply_updates(params, grads, state, oc: OptConfig, rt: Runtime,
                  fsdp_plan=None, ms_mask=None):
    """One optimizer step.  All cross-device traffic goes through ACCL-X.

    ``grads`` must already be model-axis-correct (see train_step); this
    routine handles the data/pod-axis reduction per mode.
    """
    dp = rt.mesh.data_sizes[-1]
    data_axis = rt.mesh.data_axes[-1]
    pod_axes = rt.mesh.data_axes[:-1]
    step = state["step"]
    lr = schedule(step, oc)

    def pod_reduce(x):
        if not pod_axes:
            return x
        comm = Communicator(pod_axes, rt.mesh.data_sizes[:-1])
        return collectives.all_reduce(x, comm, rt.comm)

    if "m_slice" not in state:
        dp_total = rt.mesh.dp
        if dp_total > 1:
            grads = jax.tree.map(
                lambda g: collectives.all_reduce(
                    g.astype(jnp.float32), rt.dp_comm(), rt.comm) / dp_total,
                grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = sharded_global_norm(grads, ms_mask, rt)
        scale = clip_scale(gnorm, oc)
        new_p, new_m, new_v = {}, {}, {}
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        outs = []
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            p2, m2, v2 = _adam_update(g * scale, m, v, p.astype(jnp.float32),
                                      lr, oc, step)
            outs.append((p2.astype(p.dtype), m2, v2))
        params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        m = jax.tree.unflatten(tdef, [o[1] for o in outs])
        v = jax.tree.unflatten(tdef, [o[2] for o in outs])
        return params, {"m": m, "v": v, "step": step + 1}, \
            {"lr": lr, "grad_norm": gnorm}

    # ---- zero1 ----
    reg_p, fs_p = partition_params(params, fsdp_plan)
    reg_g, fs_g = partition_params(grads, fsdp_plan)

    # FSDP leaves: grad already summed over data (all_gather transpose);
    # sum across pods, then normalize to the global mean. Shard updates in
    # place (each data rank owns disjoint rows — ZeRO-3 naturally).
    fs_g = jax.tree.map(
        lambda g: None if g is None
        else pod_reduce(g.astype(jnp.float32)) / rt.mesh.dp,
        fs_g, is_leaf=lambda x: x is None)

    # Regular leaves: flat ring reduce-scatter (mean) over data.
    gcfg = oc.grad_comm or rt.comm
    flat_g = _flatten(reg_g)
    n = flat_g.shape[0]
    pad = (-n) % dp
    if pad:
        flat_g = jnp.pad(flat_g, (0, pad))
    if dp > 1:
        g_slice = collectives.reduce_scatter(flat_g, rt_comm_data(rt), gcfg)
        g_slice = g_slice / rt.mesh.dp
    else:
        g_slice = flat_g / rt.mesh.dp
    g_slice = pod_reduce(g_slice)

    # Global grad norm.  Weight per element: 1 for model-sharded leaves
    # (disjoint shards -> sum over the model axis), 1/tp for model-replicated
    # leaves (identical grads on every model rank -> count once after the
    # model-axis psum).  Slices are summed over data; pods hold identical
    # slices after pod_reduce.
    tp = rt.mesh.tp
    reg_ms, fs_ms = (partition_params(ms_mask, fsdp_plan)
                     if ms_mask is not None else (None, None))
    w_flat = _flat_weights(reg_g, reg_ms, tp)
    if pad:
        w_flat = jnp.pad(w_flat, (0, pad))
    if dp > 1:
        k0 = w_flat.shape[0] // dp
        w_slice = jax.lax.dynamic_slice_in_dim(
            w_flat, jax.lax.axis_index(data_axis) * k0, k0, 0)
    else:
        w_slice = w_flat
    sq = jnp.sum(w_slice * g_slice * g_slice)
    fs_leaves_g = jax.tree.leaves(fs_g)
    fs_leaves_m = jax.tree.leaves(fs_ms) if fs_ms is not None else [1] * len(fs_leaves_g)
    for g, m in zip(fs_leaves_g, fs_leaves_m):
        sq = sq + (1.0 if m else 1.0 / tp) * jnp.sum(g * g)
    if dp > 1:
        sq = collectives.all_reduce(sq, rt_comm_data(rt), rt.comm)
    if tp > 1:
        sq = collectives.all_reduce(
            sq, Communicator((rt.mesh.axis_model,), (tp,)), rt.comm)
    gnorm = jnp.sqrt(sq)
    scale = clip_scale(gnorm, oc)

    # Adam on the owned flat slice.
    flat_p = _flatten(reg_p)
    if pad:
        flat_p = jnp.pad(flat_p, (0, pad))
    k = flat_p.shape[0] // dp
    if dp > 1:
        idx = jax.lax.axis_index(data_axis) * k
        p_slice = jax.lax.dynamic_slice_in_dim(flat_p, idx, k, 0)
    else:
        p_slice = flat_p
    p2, m2, v2 = _adam_update(g_slice * scale, state["m_slice"][0, 0],
                              state["v_slice"][0, 0], p_slice, lr, oc, step)
    delta = p2 - p_slice
    if dp > 1:
        delta_full = collectives.all_gather(
            delta, rt_comm_data(rt), oc.grad_comm or rt.comm, axis=0,
            tiled=True)
    else:
        delta_full = delta
    new_flat = flat_p + delta_full
    new_reg = _unflatten(new_flat[:n], reg_p)

    # FSDP leaves in place.
    new_fs, new_m_fs, new_v_fs = {}, {}, {}
    fs_leaves, fs_def = jax.tree.flatten(fs_p, is_leaf=lambda x: x is None)
    g_leaves = jax.tree.leaves(fs_g, is_leaf=lambda x: x is None)
    m_leaves = jax.tree.leaves(state["m_fsdp"], is_leaf=lambda x: x is None)
    v_leaves = jax.tree.leaves(state["v_fsdp"], is_leaf=lambda x: x is None)
    outs = []
    for pl, gl, ml, vl in zip(fs_leaves, g_leaves, m_leaves, v_leaves):
        if pl is None:
            outs.append((None, None, None))
            continue
        p2, m2l, v2l = _adam_update(gl * scale, ml, vl,
                                    pl.astype(jnp.float32), lr, oc, step)
        outs.append((p2.astype(pl.dtype), m2l, v2l))
    new_fs = jax.tree.unflatten(fs_def, [o[0] for o in outs])
    new_m_fs = jax.tree.unflatten(fs_def, [o[1] for o in outs])
    new_v_fs = jax.tree.unflatten(fs_def, [o[2] for o in outs])

    params = _merge(new_reg, new_fs)
    new_state = {"m_slice": m2[None, None],
                 "v_slice": v2[None, None],
                 "m_fsdp": new_m_fs, "v_fsdp": new_v_fs, "step": step + 1}
    return params, new_state, {"lr": lr, "grad_norm": gnorm}


def rt_comm_data(rt: Runtime) -> Communicator:
    return Communicator((rt.mesh.data_axes[-1],), (rt.mesh.data_sizes[-1],))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _flat_weights(grad_tree, ms_tree, tp: int):
    """Per-element norm weights for the flattened grad vector."""
    g_leaves = jax.tree.leaves(grad_tree)
    if ms_tree is None:
        m_leaves = [1] * len(g_leaves)
    else:
        m_leaves = jax.tree.leaves(ms_tree)
    parts = [jnp.full((g.size,), 1.0 if m else 1.0 / tp, jnp.float32)
             for g, m in zip(g_leaves, m_leaves)]
    return jnp.concatenate(parts)


def sharded_global_norm(grads, ms_mask, rt: Runtime):
    """Global norm with model-axis awareness (plain mode)."""
    tp = rt.mesh.tp
    if ms_mask is None or tp == 1:
        return global_norm(grads)
    sq_sharded = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                     for g, m in zip(jax.tree.leaves(grads),
                                     jax.tree.leaves(ms_mask)) if m)
    sq_repl = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                  for g, m in zip(jax.tree.leaves(grads),
                                  jax.tree.leaves(ms_mask)) if not m)
    sq_sharded = collectives.all_reduce(
        jnp.asarray(sq_sharded, jnp.float32),
        Communicator((rt.mesh.axis_model,), (tp,)), rt.comm)
    return jnp.sqrt(sq_sharded + sq_repl)


def clip_scale(gnorm, oc: OptConfig):
    if oc.clip_norm is None:
        return jnp.ones(())
    return jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))


def state_specs(param_spec_tree, oc: OptConfig, rt: Runtime, fsdp_plan=None):
    """PartitionSpec tree matching init_state's output."""
    from jax.sharding import PartitionSpec as P
    dp = rt.mesh.data_sizes[-1]
    if not oc.zero1 or dp == 1:
        return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}
    if fsdp_plan is None:
        fs_spec = jax.tree.map(lambda s: None, param_spec_tree)
    else:
        fs_spec = jax.tree.map(lambda s, c: s if c >= 0 else None,
                               param_spec_tree, fsdp_plan)
    return {
        "m_slice": P(rt.mesh.axis_model, rt.mesh.data_axes[-1], None),
        "v_slice": P(rt.mesh.axis_model, rt.mesh.data_axes[-1], None),
        "m_fsdp": fs_spec,
        "v_fsdp": fs_spec,
        "step": P(),
    }
