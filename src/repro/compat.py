"""Version-compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern spelling ``jax.shard_map`` (with
the ``check_vma`` keyword).  On jax 0.4.x the function lives at
``jax.experimental.shard_map.shard_map`` and the keyword is ``check_rep``.
This module resolves whichever is available and translates the keyword, so
every caller does::

    from repro.compat import shard_map

and never touches the jax version split directly.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

# Modern jax defaults to the partitionable threefry, making RNG output
# independent of the mesh/sharding it is computed under.  jax 0.4.x defaults
# to False, which breaks cross-mesh parity (params initialized on a (2,4)
# mesh differ from a (1,1) mesh).  Force the modern behavior.
if not getattr(jax.config, "jax_threefry_partitionable", True):
    jax.config.update("jax_threefry_partitionable", True)

_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)
if _NATIVE_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _LEGACY_SHARD_MAP
else:
    _LEGACY_SHARD_MAP = None


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, check_rep: bool | None = None,
              **kwargs: Any):
    """``jax.shard_map`` that works on both jax 0.4.x and >= 0.5.

    ``check_vma`` (new name) and ``check_rep`` (0.4.x name) are accepted
    interchangeably; whichever the installed jax expects is forwarded.
    """
    check = True
    if check_rep is not None:
        check = check_rep
    if check_vma is not None:
        check = check_vma
    if _NATIVE_SHARD_MAP is not None:
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check,
                                 **kwargs)
    return _LEGACY_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check, **kwargs)


def tpu_compiler_params(**kwargs: Any):
    """Pallas-TPU compiler params across the CompilerParams rename.

    jax >= 0.5 spells it ``pltpu.CompilerParams``; 0.4.x uses
    ``pltpu.TPUCompilerParams``.  Fields (e.g. ``dimension_semantics``) are
    identical.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with a fallback for very old jax versions."""
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        return mk(tuple(axis_shapes), tuple(axis_names))
    import numpy as np
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[: int(np.prod(axis_shapes))])
    return Mesh(devs.reshape(tuple(axis_shapes)), tuple(axis_names))
