"""Synthetic unstructured triangular mesh over a bight-shaped domain.

The paper simulates the tidal flow of the bight of Abaco (1696-element mesh,
scaled up to ~312k elements for weak scaling).  We generate a comparable
family of meshes: jittered-grid points inside a bight polygon (a bay with an
open-sea edge on one side), Delaunay-triangulated; boundary edges are
classified *land* (coastline) or *sea* (open boundary), as in the paper's
Figure 5.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy.spatial import Delaunay


@dataclasses.dataclass
class Mesh:
    nodes: np.ndarray        # (N, 2) float64
    elements: np.ndarray     # (E, 3) int32 node ids, CCW
    neighbors: np.ndarray    # (E, 3) int32: adjacent element id, or
                             #   -1 = land boundary, -2 = sea boundary
    area: np.ndarray         # (E,)
    normals: np.ndarray      # (E, 3, 2) outward normal * edge length
    centroids: np.ndarray    # (E, 2)

    @property
    def n_elements(self) -> int:
        return len(self.elements)


def _bight_mask(pts: np.ndarray) -> np.ndarray:
    """A bay shape on [0,1]²: water = inside the bight; the x=1 edge is the
    open sea."""
    x, y = pts[:, 0], pts[:, 1]
    # coastline: a cosine-shaped bay carved from the west
    coast = 0.25 * (1 - np.cos(2 * np.pi * y)) * 0.5
    return x > coast


def generate_bight_mesh(target_elements: int = 1696, seed: int = 0) -> Mesh:
    """Jittered-grid Delaunay mesh with ≈ target_elements triangles."""
    # elements ≈ 2 * points for Delaunay in 2D; solve for grid size
    n_pts = max(16, int(target_elements / 2))
    aspect = 1.0
    nx = int(np.sqrt(n_pts * aspect))
    ny = max(2, n_pts // max(nx, 1))
    rng = np.random.RandomState(seed)
    gx, gy = np.meshgrid(np.linspace(0, 1, nx), np.linspace(0, 1, ny))
    pts = np.stack([gx.ravel(), gy.ravel()], 1)
    jitter = 0.35 / max(nx, ny)
    interior = ((pts[:, 0] > 0) & (pts[:, 0] < 1)
                & (pts[:, 1] > 0) & (pts[:, 1] < 1))
    pts[interior] += rng.uniform(-jitter, jitter, pts[interior].shape)
    pts = pts[_bight_mask(pts)]

    tri = Delaunay(pts)
    elements = tri.simplices.astype(np.int32)
    # drop slivers hugging the concave coastline
    cent = pts[elements].mean(1)
    keep = _bight_mask(cent)
    # quality filter: tiny slivers force dt -> 0 (CFL); drop anything far
    # below the median area
    a = _areas(pts, elements)
    keep &= a > 0.05 * np.median(a[a > 1e-12])
    elements = elements[keep]

    neighbors = _build_neighbors(pts, elements)
    area = _areas(pts, elements)
    normals = _edge_normals(pts, elements)
    return Mesh(nodes=pts, elements=elements, neighbors=neighbors,
                area=area, normals=normals, centroids=pts[elements].mean(1))


def _areas(nodes, elements):
    p = nodes[elements]
    return 0.5 * np.abs(
        (p[:, 1, 0] - p[:, 0, 0]) * (p[:, 2, 1] - p[:, 0, 1])
        - (p[:, 2, 0] - p[:, 0, 0]) * (p[:, 1, 1] - p[:, 0, 1]))


def _edge_normals(nodes, elements):
    """Outward normal scaled by edge length; edge j connects vertex j and
    j+1 (mod 3)."""
    p = nodes[elements]          # (E,3,2)
    out = np.zeros((len(elements), 3, 2))
    cent = p.mean(1)
    for j in range(3):
        a, b = p[:, j], p[:, (j + 1) % 3]
        t = b - a
        n = np.stack([t[:, 1], -t[:, 0]], 1)   # rotate -90°
        mid = 0.5 * (a + b)
        flip = np.einsum("ij,ij->i", n, mid - cent) < 0
        n[flip] *= -1
        out[:, j] = n
    return out


def _build_neighbors(nodes, elements):
    """(E,3) adjacency; -1 land, -2 sea (open boundary near x≈max)."""
    edge_map: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for e, tri_nodes in enumerate(elements):
        for j in range(3):
            key = tuple(sorted((int(tri_nodes[j]), int(tri_nodes[(j + 1) % 3]))))
            edge_map.setdefault(key, []).append((e, j))
    neigh = np.full((len(elements), 3), -1, np.int32)
    xmax = nodes[:, 0].max()
    for key, users in edge_map.items():
        if len(users) == 2:
            (e1, j1), (e2, j2) = users
            neigh[e1, j1] = e2
            neigh[e2, j2] = e1
        else:
            (e, j), = users
            n1, n2 = key
            # open-sea boundary: both endpoints on the eastern edge
            if nodes[n1, 0] > xmax - 1e-6 and nodes[n2, 0] > xmax - 1e-6:
                neigh[e, j] = -2
            else:
                neigh[e, j] = -1
    return neigh
