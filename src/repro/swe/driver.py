"""Multi-device shallow-water simulation driver.

Three execution modes, mirroring the paper's §3.1/§5 scheduling comparison:

- **fused** ("PL scheduling"): the whole time step — halo exchange + element
  update — is ONE compiled program; with ``lax.scan`` over steps, an entire
  simulation segment launches with a single host dispatch.
- **overlapped** (§5 scaling configuration): fused, plus the step is split
  into interior/boundary element passes around a double-buffered halo
  exchange, so interior compute carries no dependency on the in-flight
  permutes (``make_sim_runner`` serves this mode too — the split lives in
  ``dg_solver.make_step_fn``).
- **host** ("MPI+PCIe baseline"): each phase is a separate dispatch — the
  exchange is staged through host-visible buffers between two compiled
  programs, paying 2·l_k per step exactly like the paper's baseline where the
  communication kernel is invoked by the host every simulation step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import CommConfig, Scheduling
from repro.core import latmodel
from repro.obs import trace as obs_trace
from repro.swe import dg_solver
from repro.swe.dg_solver import SWEConfig, make_step_fn
from repro.swe.mesh_gen import Mesh as SweMesh, generate_bight_mesh
from repro.swe.partition import PartitionedMesh, partition_mesh


@dataclasses.dataclass
class Simulation:
    mesh: SweMesh
    pm: PartitionedMesh
    device_mesh: Mesh
    comm_cfg: CommConfig
    swe: SWEConfig
    state: jnp.ndarray        # (P, E_max, 3) sharded over 'data'
    t: float = 0.0
    # Virtual torus the partitions are placed on (multi-hop exchange edges
    # route through intermediate partitions) and the per-round hop-aware
    # config selection; None = flat mesh / uniform config.
    topology: object = None            # TorusSpec | None
    round_cfgs: Optional[list] = None  # per exchange round, serial paths only


def _select_round_configs(rounds, comm, halo_bytes: int, tune_db_path=None,
                          objective: str = "latency"):
    """Per-edge hop-aware selection: one autotuned config per exchange round.

    Each round's edges share one ppermute (and, on a torus, comparable hop
    distances), so the round is the per-edge selection granularity: the
    round's worst-case hop distance is looked up in the TuneDB (preferring
    measurements taken on the same virtual placement) and the hop-matched
    winner returned.  This replaces the single worst-case-hop config of the
    uniform path — a 1-hop round no longer pays the transport tuned for the
    3-hop round (the paper's per-edge result).
    """
    from repro.tune import select_config, topology_key
    from repro.tune.db import TuneDB
    topo = topology_key(n_devices=comm.size)
    torus = comm.topo.name if comm.topo is not None else ""
    db = TuneDB.load(tune_db_path)   # one read for all rounds
    cfgs = []
    for perm in rounds:
        hops = max(1, comm.max_hops(perm))
        cfgs.append(select_config("multi_neighbor", halo_bytes, topo=topo,
                                  db=db, hops=hops,
                                  objective=objective, torus=torus))
    return cfgs


def flatten_state(sim: "Simulation", state) -> np.ndarray:
    """Partitioned ``(P, E_max, 3)`` state -> global element order
    ``(E, 3)``.

    The RCB partition is a pure function of (mesh, n_parts), so the same
    mesh flattens identically from ANY partition count — which is what makes
    the global state the elastic runtime's portable checkpoint: a snapshot
    taken on 8 partitions restores bitwise onto 7 survivors
    (``build_simulation(..., initial_state=flatten_state(...))``), and final
    states digest-compare across fault/no-fault runs.
    """
    from repro.swe.partition import _rcb
    s = np.asarray(state)
    part = _rcb(sim.mesh.centroids, sim.pm.n_parts)
    counts = np.zeros(sim.pm.n_parts, int)
    vals = np.zeros((sim.mesh.n_elements, 3), s.dtype)
    for e in range(sim.mesh.n_elements):
        p = part[e]
        vals[e] = s[p, counts[p]]
        counts[p] += 1
    return vals


def state_digest(sim: "Simulation", state) -> str:
    """sha256 of the global-order state — the result-stream fingerprint the
    kill-and-resume smoke compares against its no-fault reference."""
    import hashlib
    return hashlib.sha256(
        np.ascontiguousarray(flatten_state(sim, state)).tobytes()).hexdigest()


def build_simulation(n_elements: int, device_mesh: Mesh,
                     comm_cfg: CommConfig | str, swe: SWEConfig = SWEConfig(),
                     seed: int = 0, tune_db_path=None,
                     objective: str = "latency",
                     topology=None,
                     initial_state: Optional[np.ndarray] = None) -> Simulation:
    """Build the partitioned simulation.

    ``comm_cfg="auto"`` asks the autotuner for the fastest measured config
    for this partitioning's halo exchange (multi-neighbor pattern at the
    largest per-round message size), falling back to ``OPTIMIZED_CONFIG``
    when no sweep has been run on this topology.  ``objective="e2e"`` ranks
    by the measured halo-fold consumer loop instead of the bare exchange —
    the step has interior compute the overlapped schedule can hide, exactly
    the case where the microbench winner is not the end-to-end winner (§5).

    ``topology`` (a :class:`~repro.core.topology.TorusSpec`) places the
    partitions on a virtual multi-hop torus.  With ``comm_cfg="auto"`` the
    selection then happens **per edge**: every exchange round is tuned at
    its own hop distance (``Simulation.round_cfgs``) instead of one config
    at the pattern's worst-case hop.  The representative ``comm_cfg`` (step
    structure / scheduling) is the worst-hop round's winner; per-round wire
    configs apply on the serially scheduled paths, and their scheduling is
    unified with the representative so the step structure stays coherent.

    ``initial_state`` (global ``(E, 3)``, e.g. from :func:`flatten_state`)
    seeds the partitions with a mid-run snapshot instead of the t=0 hump —
    the elastic-recovery path restoring onto a different partition count.
    """
    mesh = generate_bight_mesh(n_elements, seed=seed)
    n_parts = device_mesh.shape["data"]
    if initial_state is None:
        initial_state = dg_solver.initial_state(mesh)
    pm = partition_mesh(mesh, n_parts, np.asarray(initial_state))
    round_cfgs = None
    if not isinstance(comm_cfg, CommConfig):
        from repro.core.collectives import resolve_config
        from repro.core.communicator import Communicator
        halo_bytes = int(pm.s_max) * 3 * 4   # (h, hu, hv) f32 per halo element
        # Worst-case torus hop distance of this partitioning's exchange
        # pattern — multi-hop edges prefer hop-matched measurements.
        comm = Communicator(("data",), (n_parts,), topo=topology)
        edges = [e for r in pm.rounds for e in r]
        hops = comm.max_hops(edges) if edges else None
        comm_cfg = resolve_config(comm_cfg, "multi_neighbor", halo_bytes,
                                  mesh=device_mesh, db_path=tune_db_path,
                                  hops=hops, objective=objective,
                                  torus=topology.name if topology else "")
        # Per-edge selection is a torus feature: the flat mesh keeps PR 4's
        # single worst-case-hop config (no silent behavior change), and the
        # double-buffered overlapped engine pipelines all rounds under one
        # config — don't select what can't be applied.
        if (pm.rounds and topology is not None
                and comm_cfg.scheduling != Scheduling.OVERLAPPED):
            per_round = _select_round_configs(pm.rounds, comm, halo_bytes,
                                              tune_db_path, objective)
            # One scheduling discipline per step: unify each round's wire
            # config with the representative's scheduling.
            per_round = [dataclasses.replace(c, scheduling=comm_cfg.scheduling)
                         for c in per_round]
            if any(c != comm_cfg for c in per_round):
                round_cfgs = per_round
    sharding = NamedSharding(device_mesh, P("data"))
    state = jax.device_put(jnp.asarray(pm.state0, jnp.float32), sharding)
    return Simulation(mesh=mesh, pm=pm, device_mesh=device_mesh,
                      comm_cfg=comm_cfg, swe=swe, state=state,
                      topology=topology, round_cfgs=round_cfgs)


def _static_args(sim: Simulation):
    pm = sim.pm
    sharding = NamedSharding(sim.device_mesh, P("data"))
    put = lambda a, dt=jnp.float32: jax.device_put(jnp.asarray(a, dt), sharding)
    return dict(
        area=put(pm.area),
        normals=put(pm.normals),
        neigh_idx=put(pm.neigh_idx, jnp.int32),
        edge_type=put(pm.edge_type, jnp.int32),
        valid=put(pm.valid),
        send_idx=put(pm.send_idx, jnp.int32),
        send_mask=put(pm.send_mask),
        recv_slot=put(pm.recv_slot, jnp.int32),
        boundary_idx=put(pm.boundary_idx, jnp.int32),
    )


def make_sim_runner(sim: Simulation, n_inner: int = 10):
    """Fused/overlapped runner: `run(state, t)` advances n_inner steps in one
    dispatch (the interior/boundary split of overlapped scheduling lives
    inside the step function)."""
    pm = sim.pm
    step = make_step_fn(pm, sim.comm_cfg, "data", sim.swe,
                        topology=sim.topology, round_cfgs=sim.round_cfgs)
    args = _static_args(sim)
    in_specs = (P("data"),) + (P("data"),) * len(args) + (P(),)
    arg_list = list(args.values())

    def body(state, area, normals, neigh_idx, edge_type, valid,
             send_idx, send_mask, recv_slot, boundary_idx, t0):
        def inner(carry, i):
            s, t = carry
            s = step(s[0], t, area[0], normals[0], neigh_idx[0], edge_type[0],
                     valid[0], send_idx[0], send_mask[0], recv_slot[0],
                     boundary_idx[0])[None]
            return (s, t + sim.swe.dt), None
        (state, t), _ = jax.lax.scan(inner, (state, t0), jnp.arange(n_inner))
        return state

    sm = compat.shard_map(body, mesh=sim.device_mesh,
                       in_specs=in_specs, out_specs=P("data"),
                       check_vma=False)
    fn = jax.jit(sm)

    def run(state, t):
        # Host wall-clock span: one fused dispatch of n_inner steps.  The
        # dispatch is async, so the span covers launch, not completion —
        # callers that need completion time block outside.
        with obs_trace.span("swe.segment", cat="driver", steps=n_inner,
                            scheduling=sim.comm_cfg.scheduling.value):
            return fn(state, *arg_list, jnp.asarray(t, jnp.float32))

    return run


def make_host_scheduled_runner(sim: Simulation):
    """Paper-baseline: communication staged through a host-visible buffer
    between two separately dispatched programs (2 dispatches / step)."""
    pm = sim.pm
    swe = sim.swe
    step_full = make_step_fn(pm, sim.comm_cfg, "data", sim.swe,
                             topology=sim.topology, round_cfgs=sim.round_cfgs)
    args = _static_args(sim)
    arg_list = list(args.values())

    # phase 1: gather the send payloads (what the paper's communication
    # kernel writes to global memory for the host)
    def gather(state, send_idx, send_mask):
        payloads = state[:, send_idx[0]] * send_mask[0][None, ..., None]
        return payloads   # (1, R, S, 3) on this device

    gather_sm = jax.jit(compat.shard_map(
        gather, mesh=sim.device_mesh,
        in_specs=(P("data"), P("data"), P("data")), out_specs=P("data"),
        check_vma=False))

    # phase 2: full step (exchange + update) as its own dispatch
    def phase2(state, area, normals, neigh_idx, edge_type, valid,
               send_idx, send_mask, recv_slot, boundary_idx, t0):
        s = step_full(state[0], t0, area[0], normals[0], neigh_idx[0],
                      edge_type[0], valid[0], send_idx[0], send_mask[0],
                      recv_slot[0], boundary_idx[0])[None]
        return s

    in_specs = (P("data"),) + (P("data"),) * len(arg_list) + (P(),)
    step_sm = jax.jit(compat.shard_map(
        phase2, mesh=sim.device_mesh, in_specs=in_specs, out_specs=P("data"),
        check_vma=False))

    class Runner:
        dispatches = 0

        def run(self, state, t, n_steps: int):
            for i in range(n_steps):
                with obs_trace.span("swe.host_step", cat="driver", step=i,
                                    dispatches=2):
                    payload = gather_sm(state, args["send_idx"],
                                        args["send_mask"])
                    jax.block_until_ready(payload)  # host round-trip (l_k)
                    state = step_sm(state, *arg_list,
                                    jnp.asarray(t, jnp.float32))
                    jax.block_until_ready(state)
                self.dispatches += 2
                t += swe.dt
            return state, t

    return Runner()


def build_workload(sim: Simulation, freq: float = 256e6) -> latmodel.SWEWorkload:
    """Eq. 2/3 workload descriptor from the partition statistics."""
    pm = sim.pm
    # critical partition: largest sent/received element count
    per_part_send = pm.n_send
    crit = int(np.argmax(per_part_send + pm.n_neighbors * 1000))
    msg_bytes = int(pm.s_max * 3 * 4)
    return latmodel.SWEWorkload(
        e_total=sim.mesh.n_elements,
        e_core=int(pm.n_core[crit]),
        e_send=int(pm.n_send[crit]),
        e_recv=int(pm.n_send[crit]),
        d_ext=0,
        l_pipe=100,
        n_max=pm.n_max,
        flop_per_element=dg_solver.FLOP_PER_ELEMENT,
        freq=freq,
        msg_bytes=msg_bytes)
