"""Mesh partitioning + halo communication schedule.

Recursive coordinate bisection over element centroids (balanced partitions),
then for every ordered neighbor pair (p -> q) the list of p's elements whose
state q needs (the *halo*, paper Fig. 6).  The exchange schedule is the
edge-colored round structure of ``collectives.edge_color_rounds`` — the
number of rounds a partition participates in is N_max of Eq. 3.

All per-partition arrays are padded to uniform shapes so the simulation is a
single SPMD program over the ``data`` mesh axis.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.collectives import edge_color_rounds
from repro.swe.mesh_gen import Mesh


@dataclasses.dataclass
class PartitionedMesh:
    n_parts: int
    e_max: int               # padded elements per partition
    h_max: int               # padded halo slots per partition
    s_max: int               # padded send count per round
    n_rounds: int
    rounds: list             # list of perm lists [(src,dst), ...]
    # Per-partition padded arrays (leading dim = n_parts):
    state0: np.ndarray       # (P, E_max, 3) initial state
    area: np.ndarray         # (P, E_max)
    normals: np.ndarray      # (P, E_max, 3, 2)
    neigh_idx: np.ndarray    # (P, E_max, 3) index into [local | halo] ext array
    edge_type: np.ndarray    # (P, E_max, 3) 0=interior 1=land 2=sea 3=remote
    valid: np.ndarray        # (P, E_max) 1 for real elements
    send_idx: np.ndarray     # (P, R, S_max) local element ids to send (or 0)
    send_mask: np.ndarray    # (P, R, S_max)
    recv_slot: np.ndarray    # (P, R, S_max) halo slot for arriving data (or -1)
    n_core: np.ndarray       # (P,) elements with no remote edge
    n_send: np.ndarray       # (P,) distinct elements sent
    n_neighbors: np.ndarray  # (P,)
    # Interior/boundary element split for the overlapped schedule: boundary
    # elements have >=1 remote edge and consume the halo; interior elements
    # update without it.  Padded entries repeat the partition's first boundary
    # element so a scatter over boundary_idx writes duplicate-identical rows.
    boundary_idx: np.ndarray  # (P, B_max) local ids of boundary elements
    n_boundary: np.ndarray    # (P,) real boundary element count

    @property
    def n_max(self) -> int:
        return int(self.n_neighbors.max())


def _rcb(centroids: np.ndarray, n_parts: int) -> np.ndarray:
    """Recursive coordinate bisection -> part id per element."""
    part = np.zeros(len(centroids), np.int32)

    def split(idx, parts_left, base):
        if parts_left == 1:
            part[idx] = base
            return
        half = parts_left // 2
        c = centroids[idx]
        axis = int(np.argmax(c.max(0) - c.min(0)))
        order = np.argsort(c[:, axis], kind="stable")
        cut = int(round(len(idx) * half / parts_left))
        split(idx[order[:cut]], half, base)
        split(idx[order[cut:]], parts_left - half, base + half)

    split(np.arange(len(centroids)), n_parts, 0)
    return part


def partition_mesh(mesh: Mesh, n_parts: int, initial_state: np.ndarray
                   ) -> PartitionedMesh:
    part = _rcb(mesh.centroids, n_parts)
    E = mesh.n_elements
    local_ids = [np.where(part == p)[0] for p in range(n_parts)]
    g2l = np.full(E, -1, np.int64)
    for p, ids in enumerate(local_ids):
        g2l[ids] = np.arange(len(ids))

    # halo requirements: for edge (e in p) adjacent to (n in q != p),
    # p must RECEIVE n from q  => q sends n to p.
    send: dict[tuple[int, int], list[int]] = {}
    for e in range(E):
        p = part[e]
        for j in range(3):
            n = mesh.neighbors[e, j]
            if n >= 0 and part[n] != p:
                send.setdefault((int(part[n]), int(p)), []).append(int(n))
    send = {k: sorted(set(v)) for k, v in send.items()}

    edges = sorted(send)
    rounds = edge_color_rounds(edges)
    n_rounds = len(rounds)
    s_max = max((len(v) for v in send.values()), default=1)

    # halo layout per partition: slots grouped by (source q, element order)
    halo_slot: dict[int, dict[tuple[int, int], int]] = {p: {} for p in range(n_parts)}
    h_count = np.zeros(n_parts, np.int64)
    for (q, p), elems in send.items():
        for g in elems:
            halo_slot[p][(q, g)] = int(h_count[p])
            h_count[p] += 1
    h_max = max(1, int(h_count.max()))
    e_max = max(len(ids) for ids in local_ids)

    P = n_parts
    state0 = np.zeros((P, e_max, 3))
    area = np.ones((P, e_max))
    normals = np.zeros((P, e_max, 3, 2))
    neigh_idx = np.zeros((P, e_max, 3), np.int32)
    edge_type = np.ones((P, e_max, 3), np.int32)  # pad edges behave as land
    valid = np.zeros((P, e_max), np.float32)
    send_idx = np.zeros((P, n_rounds, s_max), np.int32)
    send_mask = np.zeros((P, n_rounds, s_max), np.float32)
    recv_slot = np.full((P, n_rounds, s_max), 0, np.int32)
    recv_mask = np.zeros((P, n_rounds, s_max), np.float32)
    n_core = np.zeros(P, np.int64)
    n_send_arr = np.zeros(P, np.int64)
    n_neighbors = np.zeros(P, np.int64)
    boundary_lists: list[np.ndarray] = []

    for p in range(P):
        ids = local_ids[p]
        k = len(ids)
        state0[p, :k] = initial_state[ids]
        area[p, :k] = mesh.area[ids]
        normals[p, :k] = mesh.normals[ids]
        valid[p, :k] = 1.0
        has_remote = np.zeros(k, bool)
        for li, g in enumerate(ids):
            for j in range(3):
                n = mesh.neighbors[g, j]
                if n == -1:
                    edge_type[p, li, j] = 1
                elif n == -2:
                    edge_type[p, li, j] = 2
                elif part[n] == p:
                    edge_type[p, li, j] = 0
                    neigh_idx[p, li, j] = g2l[n]
                else:
                    edge_type[p, li, j] = 3
                    has_remote[li] = True
                    neigh_idx[p, li, j] = e_max + halo_slot[p][(int(part[n]), int(n))]
        n_core[p] = int((~has_remote).sum())
        boundary_lists.append(np.where(has_remote)[0].astype(np.int32))
        nb = set()
        sent = set()
        for (src, dst), elems in send.items():
            if src == p or dst == p:
                nb.add(dst if src == p else src)
            if src == p:
                sent.update(elems)
        n_neighbors[p] = len(nb)
        n_send_arr[p] = len(sent)

    for r, perm in enumerate(rounds):
        for (src, dst) in perm:
            elems = send[(src, dst)]
            for i, g in enumerate(elems):
                send_idx[src, r, i] = g2l[g]
                send_mask[src, r, i] = 1.0
                recv_slot[dst, r, i] = halo_slot[dst][(src, g)]
                recv_mask[dst, r, i] = 1.0
    # store recv mask in the sign: recv_slot=-1 means ignore
    recv_slot = np.where(recv_mask > 0, recv_slot, -1)

    b_max = max(1, max((len(b) for b in boundary_lists), default=1))
    boundary_idx = np.zeros((P, b_max), np.int32)
    n_boundary = np.zeros(P, np.int64)
    for p, blist in enumerate(boundary_lists):
        n_boundary[p] = len(blist)
        if len(blist):
            boundary_idx[p, :len(blist)] = blist
            boundary_idx[p, len(blist):] = blist[0]
        # no boundary elements (single partition): all-zero padding; the
        # duplicate writes carry identical values so the scatter is exact

    return PartitionedMesh(
        n_parts=P, e_max=e_max, h_max=h_max, s_max=s_max, n_rounds=n_rounds,
        rounds=rounds, state0=state0, area=area, normals=normals,
        neigh_idx=neigh_idx, edge_type=edge_type, valid=valid,
        send_idx=send_idx, send_mask=send_mask, recv_slot=recv_slot,
        n_core=n_core, n_send=n_send_arr, n_neighbors=n_neighbors,
        boundary_idx=boundary_idx, n_boundary=n_boundary)
