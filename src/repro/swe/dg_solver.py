"""Piecewise-constant discontinuous-Galerkin (cell-centered FV) shallow-water
solver with ACCL-X halo exchange.

Per time step (paper Fig. 7/8):
  1. fire the halo exchange for the boundary elements (streaming: chunked
     collective-permutes with no barrier — XLA overlaps them with step 2;
     buffered: whole-message permute behind an optimization barrier);
  2. compute fluxes on all LOCAL edges (interior/land/sea) — the "core
     element" work that hides the communication latency;
  3. consume the received halo for the remote edges and update.

Under ``Scheduling.OVERLAPPED`` the step is additionally split into an
interior/boundary element partition: interior elements (no remote edge) are
fluxed and updated with NO data dependency on the exchange, while the
double-buffered exchange (``streaming.double_buffered_exchange``) folds each
round's message into the halo as it lands; only the boundary elements are then
recomputed against the real halo and scattered over the interior result.  The
arithmetic per element is identical, so all schedules are bitwise-equal —
only the dependency structure (and therefore the achievable compute/comm
overlap) differs.

Rusanov (local Lax-Friedrichs) flux; reflective land boundaries; open-sea
boundary with optional tidal forcing (the bight-of-Abaco scenario).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import collectives, streaming
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, Scheduling
from repro.obs import trace as obs_trace
from repro.swe.partition import PartitionedMesh

G = 9.81
# FLOP count per element per step (3 edges × Rusanov ≈ 75 flops + update),
# used for the Eq. 2 throughput accounting like the paper's FLOP_sum.
FLOP_PER_ELEMENT = 260.0


def physical_flux(u, n):
    """u: (..., 3) = (h, hu, hv); n: (..., 2) scaled outward normal."""
    h = jnp.maximum(u[..., 0], 1e-8)
    hu, hv = u[..., 1], u[..., 2]
    un = (hu * n[..., 0] + hv * n[..., 1]) / h      # normal velocity * |n|
    f0 = h * un
    f1 = hu * un + 0.5 * G * h * h * n[..., 0]
    f2 = hv * un + 0.5 * G * h * h * n[..., 1]
    return jnp.stack([f0, f1, f2], axis=-1)


def rusanov(u_l, u_r, n):
    """Rusanov numerical flux through an edge with scaled normal n."""
    nlen = jnp.maximum(jnp.linalg.norm(n, axis=-1, keepdims=True), 1e-12)
    nhat = n / nlen
    h_l = jnp.maximum(u_l[..., 0], 1e-8)
    h_r = jnp.maximum(u_r[..., 0], 1e-8)
    un_l = (u_l[..., 1] * nhat[..., 0] + u_l[..., 2] * nhat[..., 1]) / h_l
    un_r = (u_r[..., 1] * nhat[..., 0] + u_r[..., 2] * nhat[..., 1]) / h_r
    lam = jnp.maximum(jnp.abs(un_l) + jnp.sqrt(G * h_l),
                      jnp.abs(un_r) + jnp.sqrt(G * h_r))[..., None]
    return 0.5 * (physical_flux(u_l, n) + physical_flux(u_r, n)
                  - lam * nlen * (u_r - u_l))


def reflect(u, n):
    """Reflective (land) ghost state: mirror the normal momentum."""
    nlen = jnp.maximum(jnp.linalg.norm(n, axis=-1, keepdims=True), 1e-12)
    nhat = n / nlen
    qn = u[..., 1] * nhat[..., 0] + u[..., 2] * nhat[..., 1]
    return jnp.stack([u[..., 0],
                      u[..., 1] - 2 * qn * nhat[..., 0],
                      u[..., 2] - 2 * qn * nhat[..., 1]], axis=-1)


@dataclasses.dataclass(frozen=True)
class SWEConfig:
    dt: float = 1e-4
    tidal_amplitude: float = 0.0
    tidal_omega: float = 0.5
    h_sea: float = 1.0


def make_step_fn(pm: PartitionedMesh, comm_cfg: CommConfig, axis: str = "data",
                 swe: SWEConfig = SWEConfig(), topology=None,
                 round_cfgs=None):
    """Returns step(state, halo_arrays..., boundary_idx) for use inside
    shard_map.

    All arrays are this device's partition slice (leading P dim removed).
    ``comm_cfg.scheduling == OVERLAPPED`` selects the interior/boundary-split
    step (interior compute carries no dependency on the exchange); all other
    schedules use the exchange-then-update step.  Both are bitwise-equal.

    ``topology`` places the partitions on a virtual multi-hop torus
    (:class:`~repro.core.topology.TorusSpec`): exchange edges spanning more
    than one hop are physically routed through intermediate partitions
    (value-identical).  ``round_cfgs`` is the driver's per-edge hop-aware
    selection — one config per exchange round (rounds group edges of
    comparable hop distance); serial scheduling only, and ``comm_cfg``
    remains the step-structure config.
    """
    comm = Communicator((axis,), (pm.n_parts,), topo=topology)
    rounds = pm.rounds
    exchange_cfg = (list(round_cfgs) if round_cfgs is not None
                    and comm_cfg.scheduling != Scheduling.OVERLAPPED
                    else comm_cfg)

    def payloads_for(state, send_idx, send_mask):
        return [state[send_idx[r]] * send_mask[r][:, None]
                for r in range(pm.n_rounds)]

    def fold_round(halo, recv_slot_r, recv):
        """Scatter-add one round's message (or any row-aligned slice of it)
        into its halo slots."""
        ok = recv_slot_r >= 0
        return halo.at[jnp.where(ok, recv_slot_r, pm.h_max - 1)].add(
            jnp.where(ok[:, None], recv, 0.0))

    def exchange(state, send_idx, send_mask, recv_slot):
        """Halo exchange -> (H_max, 3) halo buffer."""
        halo = jnp.zeros((pm.h_max, 3), state.dtype)
        if not rounds:
            return halo
        received = collectives.multi_neighbor_exchange(
            payloads_for(state, send_idx, send_mask), rounds, comm,
            exchange_cfg)
        for r, recv in enumerate(received):
            halo = fold_round(halo, recv_slot[r], recv)
        return halo

    def exchange_overlapped(state, send_idx, send_mask, recv_slot):
        """Double-buffered exchange with chunk-level halo consume: each
        recv_slot-aligned wire chunk is scatter-added into the halo AS IT
        LANDS, so a single large neighbor message overlaps its own assembly
        instead of fencing the fold on the whole round (buffered-mode rounds,
        which have no wire chunks, still fold per round)."""
        halo = jnp.zeros((pm.h_max, 3), state.dtype)
        if not rounds:
            return halo
        # Chunk geometry is shared by every round (payloads are all
        # (S_max, 3)): align to 3 flat elements so a wire chunk always
        # carries whole (h, hu, hv) halo rows.
        probe = jnp.zeros((pm.s_max, 3), state.dtype)
        _, chunk_elems = streaming.aligned_chunks(probe, comm_cfg, align=3)
        rows_per_chunk = chunk_elems // 3

        def fold_chunk(h, r, i, chunk):
            r0 = i * rows_per_chunk
            slots = lax.slice_in_dim(recv_slot[r], r0,
                                     min(r0 + rows_per_chunk, pm.s_max))
            rows = chunk.reshape(-1, 3)[: slots.shape[0]]
            return fold_round(h, slots, rows)

        halo, _ = collectives.multi_neighbor_exchange(
            payloads_for(state, send_idx, send_mask), rounds, comm, comm_cfg,
            consume=lambda h, r, recv: fold_round(h, recv_slot[r], recv),
            init=halo, chunk_consume=fold_chunk, chunk_align=3)
        return halo

    def edge_fluxes(u_own, u_n, n, edge_type, t):
        """Rusanov flux per edge; shape-generic over the leading element dim.

        ``u_own``: (..., 3) element states; ``u_n``: (..., 3edges, 3) neighbor
        states; ``n``: (..., 3edges, 2) scaled normals.
        """
        u = jnp.broadcast_to(u_own[..., None, :], u_n.shape)
        # ghost states per edge type
        u_land = reflect(u, n)
        h_sea = swe.h_sea + swe.tidal_amplitude * jnp.sin(swe.tidal_omega * t)
        u_sea = jnp.stack([jnp.broadcast_to(h_sea, u[..., 0].shape),
                           u[..., 1], u[..., 2]], axis=-1)
        u_r = jnp.where(edge_type[..., None] == 1, u_land,
                        jnp.where(edge_type[..., None] == 2, u_sea, u_n))
        return rusanov(u, u_r, n)                      # (..., 3edges, 3)

    def fluxes(state, halo, normals, neigh_idx, edge_type, t):
        ext = jnp.concatenate([state, halo], axis=0)   # (E_max+H_max, 3)
        return edge_fluxes(state, ext[neigh_idx], normals, edge_type, t)

    def apply_update(state_rows, f, area_rows, valid_rows):
        div = jnp.sum(f, axis=-2)                      # (..., 3)
        new = state_rows - swe.dt / area_rows[..., None] * div
        new = new * valid_rows[..., None]
        # keep water depth positive
        return new.at[..., 0].set(
            jnp.maximum(new[..., 0], 1e-6) * valid_rows)

    def step_serial(state, t, area, normals, neigh_idx, edge_type, valid,
                    send_idx, send_mask, recv_slot, boundary_idx):
        # 1. fire exchange (streaming: overlaps with local flux compute)
        with obs_trace.span("swe.exchange", cat="phase",
                            rounds=pm.n_rounds):
            halo = exchange(state, send_idx, send_mask, recv_slot)
        # 2+3. fluxes (local edges depend only on state; remote edges read
        # the halo — XLA schedules the permutes against the local part)
        with obs_trace.span("swe.update", cat="phase"):
            f = fluxes(state, halo, normals, neigh_idx, edge_type, t)
            return apply_update(state, f, area, valid)

    def step_overlapped(state, t, area, normals, neigh_idx, edge_type, valid,
                        send_idx, send_mask, recv_slot, boundary_idx):
        # Interior pass: every element updated against an EMPTY halo — no
        # data dependency on the exchange, so the scheduler runs this while
        # the chunk permutes are in flight.  Boundary rows come out wrong
        # here and are overwritten below.
        zero_halo = jnp.zeros((pm.h_max, 3), state.dtype)
        with obs_trace.span("swe.interior", cat="phase"):
            f_int = fluxes(state, zero_halo, normals, neigh_idx, edge_type, t)
            new = apply_update(state, f_int, area, valid)
        # Double-buffered exchange folds rounds into the halo as they land.
        with obs_trace.span("swe.exchange", cat="phase",
                            rounds=pm.n_rounds):
            halo = exchange_overlapped(state, send_idx, send_mask, recv_slot)
        # Boundary pass: recompute ONLY the elements with a remote edge
        # against the real halo, then scatter them over the interior result.
        # Padded boundary_idx entries duplicate a real row with identical
        # values, so the scatter stays deterministic.
        with obs_trace.span("swe.boundary", cat="phase"):
            ext = jnp.concatenate([state, halo], axis=0)
            b = boundary_idx
            f_b = edge_fluxes(state[b], ext[neigh_idx[b]], normals[b],
                              edge_type[b], t)
            new_b = apply_update(state[b], f_b, area[b], valid[b])
            return new.at[b].set(new_b)

    if comm_cfg.scheduling == Scheduling.OVERLAPPED:
        return step_overlapped
    return step_serial


def initial_state(mesh, hump: bool = True) -> np.ndarray:
    """Still water + Gaussian hump in the bight (for conservation tests and
    the quickstart scenario)."""
    E = mesh.n_elements
    state = np.zeros((E, 3))
    state[:, 0] = 1.0
    if hump:
        c = mesh.centroids
        state[:, 0] += 0.3 * np.exp(-60.0 * ((c[:, 0] - 0.55) ** 2
                                             + (c[:, 1] - 0.5) ** 2))
    return state


def total_mass(state, area, valid) -> jnp.ndarray:
    return jnp.sum(state[..., 0] * area * valid)
