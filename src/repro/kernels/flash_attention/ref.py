"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None):
    """q: (BH, S, d), k/v: (BH, T, d) -> (BH, S, d). fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    S, T = q.shape[1], k.shape[1]
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
