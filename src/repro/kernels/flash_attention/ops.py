"""jit'd public wrapper: model-layout (B,S,H,hd) GQA attention -> kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd) -> (B,S,H,hd_v).

    GQA: q heads are grouped onto kv heads (H % KV == 0).  On non-TPU
    backends the kernel runs in interpret mode (tests) — production model
    code selects this path only when rt.use_pallas is set.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    interp = (not _on_tpu()) if interpret is None else interpret

    # exact GQA lowering: repeat kv per q-head group, flatten heads to batch
    q2 = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    k2 = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1
                    ).reshape(B * H, T, hd)
    v2 = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1
                    ).reshape(B * H, T, v.shape[-1])
    out = flash_attention_pallas(q2, k2, v2, causal=causal, window=window,
                                 softcap=softcap, interpret=interp)
    return out.reshape(B, H, S, -1).transpose(0, 2, 1, 3)


def flash_attention_reference(q, k, v, *, causal=True, window=None,
                              softcap=None):
    """Same layout as flash_attention, via the oracle (for tests)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    q2 = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    k2 = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, T, hd)
    v2 = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1
                    ).reshape(B * H, T, v.shape[-1])
    out = attention_ref(q2, k2, v2, causal=causal, window=window,
                        softcap=softcap)
    return out.reshape(B, H, S, -1).transpose(0, 2, 1, 3)
