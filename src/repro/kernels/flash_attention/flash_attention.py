"""Pallas TPU flash attention (tiled online-softmax).

Grid: (batch·kv_heads·rep, q_blocks, kv_blocks) with the kv dimension
innermost ("arbitrary" — sequential), carrying the running (m, l, acc) in
VMEM scratch.  Block shapes are MXU-aligned (q=128 × kv=128 × head_dim) and
the working set (q tile + kv tile + acc) stays well under the 128 MiB v5e
VMEM budget.  Causal and sliding-window masks are applied from global tile
coordinates; with `trim_causal=True` fully-masked kv tiles are skipped via
``pl.when`` (the compute-roofline optimization of EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, kv_len: int, softcap: Optional[float]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (block_q, d)
    k = k_ref[0].astype(jnp.float32)            # (block_k, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < kv_len
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False):
    """q: (BH, S, d), k/v: (BH, T, d) — flat (batch·head) leading dim.

    Returns (BH, S, d).  GQA head-sharing is handled by the ops wrapper.
    """
    bh, s_len, d = q.shape
    t_len = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    s_pad = (-s_len) % block_q
    t_pad = (-t_len) % block_k
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=t_len, softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, q.shape[1], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # accumulator
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s_len]
