"""Pallas TPU kernels for the compression plugin's int8 wire format.

Per-block symmetric quantization (block = quant rows of 128 lanes): the
gradient all-reduce's quantize/dequantize hot loop.  VPU-bound elementwise
work with an in-block max reduction; tile = (block_rows, 128) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 8      # one quant block = 8 x 128 = 1024 elements


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]
                  ).astype(x_ref.dtype)


def quantize_pallas(x, interpret: bool = False):
    """x: any shape -> (q int8 (nblocks, BLOCK_ROWS, LANES), scales (nblocks,1))."""
    flat = x.reshape(-1)
    blk = BLOCK_ROWS * LANES
    pad = (-flat.shape[0]) % blk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    nb = flat.shape[0] // blk
    tiles = flat.reshape(nb, BLOCK_ROWS, LANES)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK_ROWS, LANES), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(tiles)
    return q, s


def dequantize_pallas(q, s, shape, dtype, interpret: bool = False):
    nb = q.shape[0]
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK_ROWS, LANES), dtype),
        interpret=interpret,
    )(q, s)
    n = 1
    for d in shape:
        n *= d
    return x.reshape(-1)[:n].reshape(shape)
