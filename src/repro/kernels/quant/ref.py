"""Pure-jnp oracle: per-(8x128)-block symmetric int8 quantization."""
import jax.numpy as jnp

from repro.kernels.quant.quant import BLOCK_ROWS, LANES


def quantize_ref(x):
    flat = x.reshape(-1)
    blk = BLOCK_ROWS * LANES
    pad = (-flat.shape[0]) % blk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    tiles = flat.reshape(-1, BLOCK_ROWS, LANES).astype(jnp.float32)
    amax = jnp.max(jnp.abs(tiles), axis=(1, 2), keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(tiles / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0, 0][:, None]


def dequantize_ref(q, s, shape, dtype):
    x = q.astype(jnp.float32) * s[:, :, None]
    n = 1
    for d in shape:
        n *= d
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)
