"""jit wrappers for the quantization kernels."""
import functools

import jax

from repro.kernels.quant.quant import quantize_pallas, dequantize_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return quantize_pallas(x, interpret=interp)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def dequantize(q, s, shape, dtype, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return dequantize_pallas(q, s, shape, dtype, interpret=interp)
