"""Pure-jnp oracle for the SSD scan kernel (flat BH layout)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, a, b, c, chunk: int):
    """x: (BH,S,P); dt: (BH,S); a: (BH,); b/c: (BH,S,N) -> y (BH,S,P)."""
    bh, s, p = x.shape
    n = b.shape[-1]
    nc = s // chunk

    def one(xb, dtb, ab, bb, cb):
        def to_chunks(z):
            return z.reshape(nc, chunk, *z.shape[1:])
        xs = (to_chunks(xb.astype(jnp.float32)),
              to_chunks(dtb.astype(jnp.float32)),
              to_chunks(bb.astype(jnp.float32)),
              to_chunks(cb.astype(jnp.float32)))

        def step(h, inp):
            xc, dtc, bc, cc = inp
            da = dtc * ab
            cum = jnp.cumsum(da)
            diff = cum[:, None] - cum[None, :]
            mask = jnp.tril(jnp.ones((chunk, chunk), bool))
            lmat = jnp.exp(jnp.where(mask, diff, -jnp.inf))
            w = (cc @ bc.T) * lmat
            y = w @ (xc * dtc[:, None])
            y = y + (cc * jnp.exp(cum)[:, None]) @ h
            decay_end = jnp.exp(cum[-1] - cum)
            s_c = (bc * (decay_end * dtc)[:, None]).T @ xc
            h = h * jnp.exp(cum[-1]) + s_c
            return h, y

        h0 = jnp.zeros((n, p), jnp.float32)
        _, ys = lax.scan(step, h0, xs)
        return ys.reshape(s, p)

    import jax
    return jax.vmap(one)(x, dt, a, b, c).astype(x.dtype)
