"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU-native adaptation of the SSD duality: the intra-chunk term is an
attention-like masked matmul (MXU), the inter-chunk recurrence carries a
(state × head_dim) tile in VMEM scratch across the sequential chunk grid
dimension — the same carry pattern as flash attention's (m, l, acc), and the
on-chip analogue of the paper's chunk-state "halo" hand-off.

Grid: (batch·heads, chunks) with chunks sequential ("arbitrary").
Block shapes: chunk length L (=128, MXU-aligned) × head_dim P × state N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, hstate, *,
            chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        hstate[...] = jnp.zeros_like(hstate)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, 1)
    a = a_ref[0, 0]                           # scalar A (negative)
    bmat = b_ref[0].astype(jnp.float32)       # (L, N)
    cmat = c_ref[0].astype(jnp.float32)       # (L, N)

    da = dt[:, 0] * a                          # (L,)
    cum = jnp.cumsum(da)                       # (L,)

    # Intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i·B_j) dt_j x_j
    diff = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = cb * lmat                              # (L, L)
    dx = x * dt                                # (L, P)
    y = jax.lax.dot_general(w, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Inter-chunk: y_i += C_i exp(cum_i) h_prev     h_prev: (N, P)
    y = y + jax.lax.dot_general(cmat * jnp.exp(cum)[:, None], hstate[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # Chunk state update: h = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j)
    #                          dt_j B_j x_j^T
    decay_end = jnp.exp(cum[-1] - cum)         # (L,)
    s_c = jax.lax.dot_general(bmat * (decay_end * dt[:, 0])[:, None], x,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    hstate[...] = hstate[...] * jnp.exp(cum[-1]) + s_c

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0] = hstate[...]


def ssd_scan_pallas(x, dt, a, b, c, chunk: int, interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); a: (BH,); b/c: (BH, S, N) -> (BH, S, P).

    The ops wrapper maps model layout (B, S, H, P) onto the flat BH dim and
    broadcasts the shared B/C groups.
    """
    bh, s_len, p_dim = x.shape
    n_dim = b.shape[-1]
    assert s_len % chunk == 0, (s_len, chunk)
    nc = s_len // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n_dim), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n_dim, p_dim), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_len, p_dim), x.dtype),
            jax.ShapeDtypeStruct((bh, n_dim, p_dim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_dim, p_dim), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt[..., None], a[:, None], b, c)
