"""jit'd public wrapper mapping the model layout onto the SSD kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, a, b, c, chunk: int, interpret=None):
    """Model layout: x (B,S,H,P); dt (B,S,H); a (H,); b/c (B,S,G,N), G=1.

    Returns (y (B,S,H,P) fp32, h_final (B,H,N,P) fp32) — matching
    repro.models.ssm.ssd_chunked_ref.  The final state is recomputed from
    the last chunk boundary cheaply via the reference recurrence (the kernel
    streams y; serving prefill uses the state).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    interp = (not _on_tpu()) if interpret is None else interpret
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    af = jnp.broadcast_to(a[None], (B, H)).reshape(B * H)
    bf = jnp.broadcast_to(b[:, :, 0:1, :], (B, S, H, N)
                          ).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cf = jnp.broadcast_to(c[:, :, 0:1, :], (B, S, H, N)
                          ).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y, h_final = ssd_scan_pallas(xf, dtf, af, bf, cf, chunk, interpret=interp)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3).astype(jnp.float32)
    h_final = h_final.reshape(B, H, N, P)
    return y, h_final
