"""Pallas TPU kernel for the shallow-water element update (the paper's
compute pipeline).

The neighbor gather stays in XLA (dynamic indexing); the kernel is the
arithmetic hot loop: 3 Rusanov edge fluxes + the element update, VPU-bound,
tiled (TILE_E elements × 8 sublanes-aligned) in VMEM.  This is the
algorithm-hardware codesign analogue of the paper's HLS element kernel: one
element per clock on the FPGA ⇒ one (8, 128)-vector lane bundle per VPU op
here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_E = 512
G = 9.81


def _flux_kernel(u_ref, un_ref, nx_ref, ny_ref, et_ref, area_ref, valid_ref,
                 hsea_ref, out_ref, *, dt: float):
    """One tile of elements; edge axis unrolled (3 edges).

    u: (T, 3vars); un: (T, 3edges, 3vars); n: (T, 3edges); et: (T, 3edges);
    out: updated state (T, 3vars).
    """
    u = u_ref[...].astype(jnp.float32)            # (T,3)
    div = jnp.zeros_like(u)
    hsea = hsea_ref[0, 0]
    for j in range(3):
        nx = nx_ref[:, j].astype(jnp.float32)
        ny = ny_ref[:, j].astype(jnp.float32)
        et = et_ref[:, j]
        u_n = un_ref[:, j, :].astype(jnp.float32)

        nlen = jnp.maximum(jnp.sqrt(nx * nx + ny * ny), 1e-12)
        nhx, nhy = nx / nlen, ny / nlen

        h_l = jnp.maximum(u[:, 0], 1e-8)
        qn_l = u[:, 1] * nhx + u[:, 2] * nhy
        # ghost states
        u_land0 = u[:, 0]
        u_land1 = u[:, 1] - 2 * qn_l * nhx
        u_land2 = u[:, 2] - 2 * qn_l * nhy
        u_r0 = jnp.where(et == 1, u_land0,
                         jnp.where(et == 2, hsea, u_n[:, 0]))
        u_r1 = jnp.where(et == 1, u_land1,
                         jnp.where(et == 2, u[:, 1], u_n[:, 1]))
        u_r2 = jnp.where(et == 1, u_land2,
                         jnp.where(et == 2, u[:, 2], u_n[:, 2]))

        h_r = jnp.maximum(u_r0, 1e-8)
        un_l = qn_l / h_l
        un_r = (u_r1 * nhx + u_r2 * nhy) / h_r
        lam = jnp.maximum(jnp.abs(un_l) + jnp.sqrt(G * h_l),
                          jnp.abs(un_r) + jnp.sqrt(G * h_r))

        def phys(h, hu, hv):
            un_s = (hu * nx + hv * ny) / jnp.maximum(h, 1e-8)
            f0 = h * un_s
            f1 = hu * un_s + 0.5 * G * h * h * nx
            f2 = hv * un_s + 0.5 * G * h * h * ny
            return f0, f1, f2

        fl = phys(h_l, u[:, 1], u[:, 2])
        fr = phys(h_r, u_r1, u_r2)
        f0 = 0.5 * (fl[0] + fr[0] - lam * nlen * (u_r0 - u[:, 0]))
        f1 = 0.5 * (fl[1] + fr[1] - lam * nlen * (u_r1 - u[:, 1]))
        f2 = 0.5 * (fl[2] + fr[2] - lam * nlen * (u_r2 - u[:, 2]))
        div = div + jnp.stack([f0, f1, f2], axis=-1)

    area = area_ref[...].astype(jnp.float32)[:, None]
    valid = valid_ref[...].astype(jnp.float32)[:, None]
    new = (u - dt / jnp.maximum(area, 1e-12) * div) * valid
    new = new.at[:, 0].set(jnp.maximum(new[:, 0], 1e-6) * valid[:, 0])
    out_ref[...] = new.astype(out_ref.dtype)


def swe_step_pallas(u, u_n, nx, ny, edge_type, area, valid, h_sea, *,
                    dt: float, interpret: bool = False):
    """u: (E,3); u_n: (E,3,3); nx/ny/edge_type: (E,3); area/valid: (E,)."""
    E = u.shape[0]
    pad = (-E) % TILE_E
    if pad:
        padf = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        u, u_n, nx, ny, area, valid = map(padf, (u, u_n, nx, ny, area, valid))
        edge_type = jnp.pad(edge_type, ((0, pad), (0, 0)),
                            constant_values=1)
    ne = u.shape[0] // TILE_E
    kernel = functools.partial(_flux_kernel, dt=dt)
    out = pl.pallas_call(
        kernel,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((TILE_E, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE_E, 3, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_E, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE_E, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE_E, 3), lambda i: (i, 0)),
            pl.BlockSpec((TILE_E,), lambda i: (i,)),
            pl.BlockSpec((TILE_E,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_E, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u.shape[0], 3), u.dtype),
        interpret=interpret,
    )(u, u_n, nx, ny, edge_type, area, valid,
      jnp.asarray(h_sea, jnp.float32)[None, None])
    return out[:E]
