"""jit wrapper for the SWE element-update kernel."""
import functools

import jax

from repro.kernels.swe_step.swe_step import swe_step_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("dt", "interpret"))
def swe_step(u, u_n, nx, ny, edge_type, area, valid, h_sea, *, dt,
             interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return swe_step_pallas(u, u_n, nx, ny, edge_type, area, valid, h_sea,
                           dt=dt, interpret=interp)
