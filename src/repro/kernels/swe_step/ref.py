"""Pure-jnp oracle for the SWE element-update kernel — delegates to the
production solver math (single source of truth for the physics)."""
import jax.numpy as jnp

from repro.swe.dg_solver import reflect, rusanov


def swe_step_ref(u, u_n, nx, ny, edge_type, area, valid, h_sea, *, dt: float):
    n = jnp.stack([nx, ny], axis=-1)                        # (E,3,2)
    ub = jnp.broadcast_to(u[:, None, :], u_n.shape)
    u_land = reflect(ub, n)
    u_sea = jnp.stack([jnp.broadcast_to(h_sea, ub[..., 0].shape),
                       ub[..., 1], ub[..., 2]], axis=-1)
    u_r = jnp.where(edge_type[..., None] == 1, u_land,
                    jnp.where(edge_type[..., None] == 2, u_sea, u_n))
    f = rusanov(ub, u_r, n)
    div = jnp.sum(f, axis=1)
    new = (u - dt / jnp.maximum(area[:, None], 1e-12) * div) * valid[:, None]
    new = new.at[:, 0].set(jnp.maximum(new[:, 0], 1e-6) * valid)
    return new
