"""Sharded checkpointing with async save, emergency save, and
reshard-on-restore (elastic scaling).

Format: one ``.npz`` per checkpoint step holding every leaf (flattened key
paths) + a JSON manifest (step, pytree structure fingerprint, mesh shape).
On a real multi-host deployment each host writes its own shard file; on this
single-process container the full arrays are written — the *restore* path is
the part that matters for elasticity: ``restore(..., target_sharding=...)``
re-shards to ANY new mesh via ``jax.device_put``, which is exactly the
recovery path after losing a node and re-meshing.

Fault-tolerance features:
- ``AsyncCheckpointer.save`` snapshots device arrays to host then writes on a
  background thread (training continues immediately).
- ``emergency_save`` is synchronous and minimal — called from the preemption
  signal handler (see repro.runtime.preemption).
- saves are atomic (tmp file + rename); ``latest_step`` scans the directory.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


def _to_numpy_storable(a) -> np.ndarray:
    """npz cannot store ml_dtypes (bfloat16/fp8); widen to float32."""
    arr = np.asarray(a)
    if arr.dtype.kind not in "fiub?" or str(arr.dtype) == "bfloat16":
        return arr.astype(np.float32)
    return arr


class Checkpointer:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.npz"

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        names, leaves, _ = _flatten_with_names(tree)
        host = [_to_numpy_storable(jax.device_get(l)) for l in leaves]
        tmp = self._path(step).with_suffix(".tmp.npz")
        np.savez(tmp, **{n: a for n, a in zip(names, host)})
        os.replace(tmp, self._path(step))
        manifest = {"step": step, "names": names,
                    "time": time.time(), **(extra or {})}
        mtmp = self.dir / f"manifest_{step:08d}.tmp"
        mtmp.write_text(json.dumps(manifest))
        os.replace(mtmp, self.dir / f"manifest_{step:08d}.json")

    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.dir.glob("ckpt_*.npz"))
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, target_sharding: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally reshard.

        ``target_sharding``: pytree of jax.sharding.Sharding (or None) — the
        elastic-recovery path: a checkpoint from a 256-chip mesh restores
        onto a 192-chip mesh by simply passing the new shardings.
        """
        data = np.load(self._path(step))
        names, leaves, treedef = _flatten_with_names(like)
        out = []
        for n, leaf in zip(names, leaves):
            arr = data[n]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if target_sharding is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else a,
                tree, target_sharding,
                is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)))
        return tree


class AsyncCheckpointer(Checkpointer):
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory):
        super().__init__(directory)
        self._thread: Optional[threading.Thread] = None
        self.pending = 0
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        names, leaves, _ = _flatten_with_names(tree)
        host = [_to_numpy_storable(jax.device_get(l)) for l in leaves]  # sync
        with self._lock:
            self.pending += 1

        def _write():
            try:
                tmp = self._path(step).with_suffix(".tmp.npz")
                np.savez(tmp, **{n: a for n, a in zip(names, host)})
                os.replace(tmp, self._path(step))
                manifest = {"step": step, "names": names,
                            "time": time.time(), **(extra or {})}
                mtmp = self.dir / f"manifest_{step:08d}.tmp"
                mtmp.write_text(json.dumps(manifest))
                os.replace(mtmp, self.dir / f"manifest_{step:08d}.json")
            finally:
                with self._lock:
                    self.pending -= 1

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()


def emergency_save(directory, step: int, tree: Any):
    """Synchronous minimal-latency save for preemption handlers."""
    ck = Checkpointer(directory)
    ck.save(step, tree, extra={"emergency": True})
    return ck._path(step)
