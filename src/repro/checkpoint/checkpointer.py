"""Sharded checkpointing with async save, emergency save, and
reshard-on-restore (elastic scaling).

Format: one ``.npz`` per checkpoint step holding every leaf (flattened key
paths) + a JSON manifest (step, pytree structure fingerprint, mesh shape) +
a terminal ``COMMIT`` marker.  On a real multi-host deployment each host
writes its own shard file; on this single-process container the full arrays
are written — the *restore* path is the part that matters for elasticity:
``restore(..., target_sharding=...)`` re-shards to ANY new mesh via
``jax.device_put``, which is exactly the recovery path after losing a node
and re-meshing.

Fault-tolerance features:
- ``AsyncCheckpointer.save`` snapshots device arrays to host then writes on a
  background thread (training continues immediately).
- ``emergency_save`` is synchronous and minimal — called from the preemption
  signal handler (see repro.runtime.fault_tolerance); it can carry the
  optimizer state alongside the params so a same-mesh resume is
  bitwise-continuous (Adam moments included).
- every file write is atomic (unique tmp + rename), and a checkpoint only
  *exists* once its ``COMMIT`` marker lands: the marker is written last, so
  a crash mid-checkpoint leaves a torn step that ``latest_step`` skips
  (counting it in the ``ckpt.skipped_partial`` obs counter) and restore
  falls back to the newest committed step — a mid-write crash can never
  wedge restart.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.obs import metrics as obs_metrics


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


def _to_numpy_storable(a) -> np.ndarray:
    """npz cannot store ml_dtypes (bfloat16/fp8); widen to float32."""
    arr = np.asarray(a)
    if arr.dtype.kind not in "fiub?" or str(arr.dtype) == "bfloat16":
        return arr.astype(np.float32)
    return arr


class Checkpointer:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        # Torn steps already counted by THIS instance — latest_step may scan
        # repeatedly; each partial checkpoint bumps the counter once.
        self._counted_partial: set[int] = set()

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.npz"

    def _commit_path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:08d}.COMMIT"

    def _write_payload(self, step: int, names, host, extra: Optional[dict]):
        """The one write body (sync and async saves share it): npz, then
        manifest, then the COMMIT marker — each atomically, in that order,
        so the marker's existence implies the whole step is durable."""
        tmp = self._path(step).with_suffix(f".{os.getpid()}.tmp.npz")
        np.savez(tmp, **{n: a for n, a in zip(names, host)})
        os.replace(tmp, self._path(step))
        manifest = {"step": step, "names": names,
                    "time": time.time(), **(extra or {})}
        mtmp = self.dir / f"manifest_{step:08d}.{os.getpid()}.tmp"
        mtmp.write_text(json.dumps(manifest))
        os.replace(mtmp, self.dir / f"manifest_{step:08d}.json")
        ctmp = self._commit_path(step).with_suffix(f".{os.getpid()}.ctmp")
        ctmp.write_text(json.dumps({"step": step, "time": time.time()}))
        os.replace(ctmp, self._commit_path(step))

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        names, leaves, _ = _flatten_with_names(tree)
        host = [_to_numpy_storable(jax.device_get(l)) for l in leaves]
        self._write_payload(step, names, host, extra)

    def latest_step(self) -> Optional[int]:
        """Newest *committed* step.  A ``ckpt_*.npz`` without its ``COMMIT``
        marker is a torn write (crash between the array file and the
        marker): it is skipped — counted once per instance in
        ``ckpt.skipped_partial`` — and the scan falls back to the next
        newest committed step, or None when nothing committed survives."""
        steps = set()
        for p in self.dir.glob("ckpt_*.npz"):
            try:
                steps.add(int(p.stem.split("_")[1]))
            except ValueError:
                continue   # a leaked tmp file, not a checkpoint
        for step in sorted(steps, reverse=True):
            if self._commit_path(step).exists():
                return step
            if step not in self._counted_partial:
                self._counted_partial.add(step)
                obs_metrics.registry().counter("ckpt.skipped_partial").inc()
        return None

    def restore(self, step: int, like: Any, target_sharding: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally reshard.

        ``target_sharding``: pytree of jax.sharding.Sharding (or None) — the
        elastic-recovery path: a checkpoint from a 256-chip mesh restores
        onto a 192-chip mesh by simply passing the new shardings.  Extra npz
        names (e.g. a drained optimizer state riding an emergency save) are
        ignored — only the names present in ``like`` are read.
        """
        data = np.load(self._path(step))
        names, leaves, treedef = _flatten_with_names(like)
        out = []
        for n, leaf in zip(names, leaves):
            arr = data[n]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if target_sharding is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else a,
                tree, target_sharding,
                is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)))
        return tree


class AsyncCheckpointer(Checkpointer):
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory):
        super().__init__(directory)
        self._thread: Optional[threading.Thread] = None
        self.pending = 0
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        names, leaves, _ = _flatten_with_names(tree)
        host = [_to_numpy_storable(jax.device_get(l)) for l in leaves]  # sync
        with self._lock:
            self.pending += 1

        def _write():
            try:
                self._write_payload(step, names, host, extra)
            finally:
                with self._lock:
                    self.pending -= 1

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()


def emergency_save(directory, step: int, tree: Any,
                   opt_state: Any = None):
    """Synchronous minimal-latency save for preemption handlers.

    With ``opt_state`` given, the optimizer state is saved alongside under
    ``<directory>/opt`` — a same-mesh resume then continues with the exact
    Adam moments, making the drained loss stream bitwise-identical to the
    uninterrupted run (restores that only want params are unaffected: extra
    state lives in its own subdirectory).
    """
    ck = Checkpointer(directory)
    ck.save(step, tree, extra={"emergency": True})
    if opt_state is not None:
        Checkpointer(Path(directory) / "opt").save(
            step, opt_state, extra={"emergency": True})
    return ck._path(step)
