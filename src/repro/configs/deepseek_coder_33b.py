"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256; llama-arch. [arXiv:2401.14196; hf]

56 heads do not divide tp=16: attention uses zero-padded head sharding
(56 -> 64 effective heads; identity math, ~14 % extra attention FLOPs —
recorded in the roofline's MODEL/HLO ratio).
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=19200, vocab_size=32256, head_dim=128,
        rope_theta=100_000.0, shard_attn="auto", padded_heads=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-smoke", family="dense",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=112, vocab_size=256, head_dim=8, shard_attn="auto",
        padded_heads=8, remat=False,
    )


registry.register("deepseek-coder-33b", full, smoke)
