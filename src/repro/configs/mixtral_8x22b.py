"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768; 8 experts top-2, SWA. [arXiv:2401.04088; hf]

EP layout on tp=16: each expert split into 2 ff-shards across device pairs
(EP8 × TP2 flattened over the model axis).
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        n_experts=8, n_experts_per_tok=2, moe_d_ff=16384,
        sliding_window=4096, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        n_experts=4, n_experts_per_tok=2, moe_d_ff=128,
        sliding_window=32, remat=False,
    )


registry.register("mixtral-8x22b", full, smoke)
