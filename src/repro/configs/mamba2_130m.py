"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 1536 -> 24 SSD heads of 64; heads are not divisible by tp=16 so the
SSM compute is replicated across the model axis (tiny model; recorded as
waste in the roofline MODEL/HLO ratio — embeddings/logits still shard).
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        conv_width=4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        conv_width=4, remat=False,
    )


registry.register("mamba2-130m", full, smoke)
