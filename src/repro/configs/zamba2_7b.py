"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]

Simplifications vs. the released model (noted in DESIGN.md): a single shared
attention+MLP block (the release alternates two) without per-invocation LoRA;
the shared block input is concat(hidden, embedding) projected back to d_model.
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        conv_width=4, hybrid_attn_every=6,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        conv_width=4, hybrid_attn_every=2,
        remat=False,
    )


registry.register("zamba2-7b", full, smoke)
