"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206; multimodal frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2308.11596; hf]
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        is_encoder_decoder=True, n_encoder_layers=24,
        frontend="audio", frontend_dim=160, mlp_type="gelu",
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        is_encoder_decoder=True, n_encoder_layers=2,
        frontend="audio", frontend_dim=16, mlp_type="gelu", remat=False,
    )


registry.register("seamless-m4t-large-v2", full, smoke)
