"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (MLA) moe_d_ff=2048
vocab=129280; 1 shared + 256 routed experts top-8, 3 leading dense layers
(dense d_ff=18432). MTP head omitted (noted in DESIGN.md).
[arXiv:2412.19437; hf]
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        n_experts=256, n_experts_per_tok=8, n_shared_experts=1,
        moe_d_ff=2048, n_dense_layers=3,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab_size=256,
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1,
        moe_d_ff=32, n_dense_layers=1,
        use_mla=True, q_lora_rank=48, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, remat=False,
    )


registry.register("deepseek-v3-671b", full, smoke)
