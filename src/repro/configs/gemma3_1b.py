"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5:1 local:global attention, 512-token sliding window.
[hf:google/gemma-3-1b-pt; unverified]

With 4 heads on tp=16 the attention computes replicated (shard_attn=
"replicate") in the baseline — the deliberately paper-representative cell:
dispatch/latency overheads dominate a tiny model, and the perf log flips this
to padded head sharding.
"""
from repro.configs import registry
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
        d_ff=6912, vocab_size=262144, head_dim=256,
        local_global_ratio=5, sliding_window=512,
        rope_theta=1_000_000.0, shard_attn="replicate",
        qk_norm=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16,
        local_global_ratio=2, sliding_window=16, qk_norm=True, remat=False,
    )


registry.register("gemma3-1b", full, smoke)
