"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Each assigned architecture has its exact public configuration plus a reduced
SMOKE variant of the same family (small widths/depths, tiny vocab) used by the
CPU tests; the full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.models.common import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}

_MODULES = [
    "zamba2_7b", "qwen3_8b", "command_r_plus_104b", "gemma3_1b",
    "deepseek_coder_33b", "mixtral_8x22b", "deepseek_v3_671b",
    "phi3_vision_4_2b", "mamba2_130m", "seamless_m4t_large_v2",
]
_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str) -> ModelConfig:
    _load_all()
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    _load_all()
    return _SMOKE[name]()


def list_archs():
    _load_all()
    return sorted(_REGISTRY)
