"""Model configuration and mesh/runtime context shared by all architectures.

The model substrate is **manual SPMD**: every model function executes inside a
``shard_map`` over the production mesh, and every cross-device transfer is an
explicit ACCL-X collective (``repro.core``).  This makes the paper's
communication technique a first-class, configurable feature of the framework —
TP combines, DP gradient reductions, MoE dispatch and sequence-parallel decode
all route through the same ``CommConfig``.

Sharding layout (Megatron-style):
  - batch over ``("pod", "data")``  (DP)
  - weights over ``"model"``        (TP; column→row parallel with one combine)
  - decode KV cache over ``"model"`` along the *sequence* axis (SP decode with
    log-sum-exp combination) — uniform for every kv-head count
  - MoE experts over ``"model"`` (EP; flattened expert×ff-shard slices when
    n_experts < tp)
Activations are replicated across ``"model"`` between blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.core.config import CommConfig
from repro.core.communicator import Communicator


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mlp_type: str = "swiglu"        # swiglu | gelu
    attention_bias: bool = False
    # Attention pattern
    causal: bool = True
    sliding_window: Optional[int] = None     # SWA width (mixtral, gemma local)
    local_global_ratio: int = 0              # gemma3: 5 local then 1 global
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    n_dense_layers: int = 0                  # leading dense layers (dsv3: 3)
    capacity_factor: float = 1.25
    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    ssm_groups: int = 1
    # Hybrid (zamba2): one shared attention block applied every k ssm layers
    hybrid_attn_every: int = 0
    # Encoder-decoder (seamless)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # Multimodal frontend stubs
    frontend: Optional[str] = None           # vision | audio
    num_patches: int = 0                     # vision tokens per image
    frontend_dim: int = 0                    # raw frame/patch embedding width
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # Attention TP strategy when n_heads % tp != 0:
    #   "auto"      — pad q heads to `padded_heads` zero-weight heads
    #                 (identity math; small FLOP overhead, e.g. 56→64)
    #   "replicate" — compute attention replicated on every tp rank (tiny
    #                 models; 16x attention FLOP waste, a hillclimb lever)
    shard_attn: str = "auto"
    # Explicit padded head count (config-level so the GQA grouping is
    # identical at every tp, including tp=1). Must be a multiple of
    # n_kv_heads and of every tp used in production.
    padded_heads: Optional[int] = None
    # Which sub-modules are tensor-parallel (auto-disabled when the dimension
    # does not divide by tp; the fallback is replicated compute — recorded as
    # FLOP waste in the roofline's MODEL_FLOPS/HLO_FLOPS ratio).
    remat: bool = True
    # "full" recomputes everything in backward; "dots" saves matmul outputs
    # and recomputes only elementwise ops (selective checkpointing — trades
    # HBM for the recompute FLOPs; the §Perf lever for compute-bound cells).
    remat_policy: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        gn = self.ssm_groups * self.ssm_state
        nh = self.ssm_heads
        return (2 * d * di + 2 * d * gn + d * nh + self.conv_width * di
                + di + di * d + 3 * nh + d)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.use_mla:
            attn = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            attn += d * (self.kv_lora_rank + self.qk_rope_dim)
            attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * (n_q + 2 * n_kv) + n_q * d
        def mlp_params(ff):  # noqa: E306
            return d * ff * (3 if self.mlp_type == "swiglu" else 2)
        if self.family in ("ssm",):
            ssm = self._ssm_params()
            return emb + self.n_layers * ssm
        if self.family == "hybrid":
            n_shared = self.n_layers // max(1, self.hybrid_attn_every)
            shared_block = attn + mlp_params(self.d_ff) + 2 * d * d  # concat proj
            return emb + self.n_layers * self._ssm_params() + shared_block
        core = 0
        n_moe_layers = 0
        if self.n_experts:
            n_moe_layers = self.n_layers - self.n_dense_layers
            ff = self.moe_d_ff or self.d_ff
            core += n_moe_layers * (
                self.n_experts * mlp_params(ff)
                + self.n_shared_experts * mlp_params(ff)
                + d * self.n_experts)
            core += self.n_dense_layers * mlp_params(self.d_ff)
        else:
            core += self.n_layers * mlp_params(self.d_ff)
        core += self.n_layers * attn
        n_enc = self.n_encoder_layers if self.is_encoder_decoder else 0
        core += n_enc * (attn + mlp_params(self.d_ff))   # encoder stack
        core += n_enc * attn                              # cross attention
        return emb + core

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        def mlp_params(f):
            return d * f * (3 if self.mlp_type == "swiglu" else 2)
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = n_moe_layers * (
            self.n_experts - self.n_experts_per_tok) * mlp_params(ff)
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Static view of the mesh from inside shard_map."""
    axis_model: str = "model"
    data_axes: Tuple[str, ...] = ("data",)     # ("pod","data") when multi-pod
    model_size: int = 1
    data_sizes: Tuple[int, ...] = (1,)

    @property
    def tp(self) -> int:
        return self.model_size

    @property
    def dp(self) -> int:
        out = 1
        for s in self.data_sizes:
            out *= s
        return out

    @classmethod
    def from_mesh(cls, mesh, axis_model: str = "model") -> "MeshContext":
        data_axes = tuple(a for a in mesh.axis_names if a != axis_model)
        return cls(axis_model=axis_model, data_axes=data_axes,
                   model_size=mesh.shape[axis_model],
                   data_sizes=tuple(mesh.shape[a] for a in data_axes))


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Everything a model function needs besides params and inputs."""
    cfg: ModelConfig
    mesh: MeshContext
    comm: CommConfig
    use_pallas: bool = False     # select Pallas kernels (TPU) vs jnp reference
    # long-sequence attention strategy: auto | dense | tiled | trimmed
    # ("trimmed" statically skips fully-masked causal/SWA tiles — perf lever)
    attn_tiling: str = "auto"
    # FSDP gather plan from sharding.build_fsdp_plan (None = params fully
    # materialized per their TP spec; no in-scan gathers).
    fsdp_plan: Any = None
    # Decode KV-timeline shard axes. ("model",) default; long-context decode
    # with batch < dp spans the data axes too: ("data", "model") splits a
    # 512K cache 256 ways.
    seq_axes: tuple = ("model",)
    # Megatron-SP: store the residual stream sequence-sharded over the model
    # axis between blocks (LN runs on shards; all-gather before QKV/MLP-in,
    # reduce-scatter after the row-parallel matmul). Memory-term lever:
    # activation residuals shrink tp-fold; comm volume is unchanged
    # (AG+RS == the all-reduce it replaces). Dense/vlm families.
    seq_parallel: bool = False

    def sp_comm(self) -> Communicator:
        sizes = []
        for a in self.seq_axes:
            if a == self.mesh.axis_model:
                sizes.append(self.mesh.model_size)
            else:
                sizes.append(self.mesh.data_sizes[self.mesh.data_axes.index(a)])
        return Communicator(tuple(self.seq_axes), tuple(sizes))

    @property
    def sp_size(self) -> int:
        out = 1
        for s in self.sp_comm().axis_sizes:
            out *= s
        return out

    def tp_comm(self) -> Communicator:
        return Communicator((self.mesh.axis_model,), (self.mesh.model_size,))

    def dp_comm(self) -> Communicator:
        return Communicator(self.mesh.data_axes, self.mesh.data_sizes)
