"""Core layers (manual-SPMD: these run inside shard_map).

Tensor-parallel convention: activations enter replicated across the ``model``
axis; column-parallel matmuls produce sharded features; row-parallel matmuls
produce partial sums that are combined with an ACCL-X all-reduce.  The combine
can run **buffered** (single psum after the full matmul) or **streaming**
(chunk-pipelined ``overlapped_matmul_allreduce``) per the CommConfig — the
paper's §3.1 modes applied to TP.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives, streaming
from repro.core.config import CommMode, Scheduling
from repro.models.common import Runtime


# ----------------------------------------------------------------------
# Initialization helpers (host-side, full arrays; sharded by the launcher)
# ----------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------------
# Normalization
# ----------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * lax.rsqrt(var + eps)
    return (h * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Tensor-parallel matmuls
# ----------------------------------------------------------------------

def tp_grad_sum(x: jnp.ndarray, rt: Runtime, enable: bool = True) -> jnp.ndarray:
    """Megatron's *f* operator: identity forward, all-reduce backward.

    Placed where a replicated activation enters a model-sharded branch —
    each TP rank back-propagates only its shard's partial cotangent, so the
    backward pass must sum them.  Routed through ACCL-X like every other
    collective.
    """
    if not enable or rt.mesh.tp == 1:
        return x

    @jax.custom_vjp
    def f(y):
        return y

    def fwd(y):
        return y, None

    def bwd(_, ct):
        return (collectives.all_reduce(ct, rt.tp_comm(), rt.comm),)

    f.defvjp(fwd, bwd)
    return f(x)


def scale_grad(x: jnp.ndarray, s: float) -> jnp.ndarray:
    """Identity forward; scales the cotangent by ``s`` in backward.

    Used for losses computed replicated-identically on every TP rank (MoE
    aux): the rank-partial grad convention sums contributions over the model
    axis at sync time, so an identical-on-all-ranks path must pre-scale its
    cotangent by 1/tp to stay exact.
    """
    @jax.custom_vjp
    def f(y):
        return y

    def fwd(y):
        return y, None

    def bwd(_, ct):
        return (ct * s,)

    f.defvjp(fwd, bwd)
    return f(x)


def sp_shard_seq(x: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Slice this rank's seq shard (SP stack entry).

    Custom transpose: the cotangents of the shards are disjoint in time, so
    the backward pass reassembles the full-seq cotangent with an all-gather
    (without this, upstream layers — embeddings — would see only this
    rank's token positions)."""
    if rt.mesh.tp == 1:
        return x

    L = x.shape[1] // rt.mesh.tp

    @jax.custom_vjp
    def f(v):
        shard = lax.axis_index(rt.mesh.axis_model)
        return lax.dynamic_slice_in_dim(v, shard * L, L, axis=1)

    def fwd(v):
        shard = lax.axis_index(rt.mesh.axis_model)
        return lax.dynamic_slice_in_dim(v, shard * L, L, axis=1), None

    def bwd(_, ct):
        return (collectives.all_gather(ct, rt.tp_comm(), rt.comm, axis=1),)

    f.defvjp(fwd, bwd)
    return f(x)


def sp_all_gather(x_s: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Megatron-SP g operator: gather the seq-sharded activation to full.

    Forward all-gather over the seq dim; its AD transpose (psum_scatter)
    sums the rank-partial cotangents — so no separate f operator is needed
    on SP branches.  Use ONLY where the gathered value is consumed by
    rank-local sharded branches; for replicated consumers use
    sp_unshard_seq (identity-slice transpose).
    """
    if rt.mesh.tp == 1:
        return x_s
    return collectives.all_gather(x_s, rt.tp_comm(), rt.comm, axis=1)


def sp_unshard_seq(x_s: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Stack-exit gather: output consumed REPLICATED (final norm / CE), whose
    cotangent is already identical on every rank — the transpose takes this
    rank's slice without summing (a sum would count it tp times)."""
    if rt.mesh.tp == 1:
        return x_s

    L = x_s.shape[1]

    @jax.custom_vjp
    def f(v):
        return collectives.all_gather(v, rt.tp_comm(), rt.comm, axis=1)

    def fwd(v):
        return collectives.all_gather(v, rt.tp_comm(), rt.comm, axis=1), None

    def bwd(_, ct):
        shard = lax.axis_index(rt.mesh.axis_model)
        return (lax.dynamic_slice_in_dim(ct, shard * L, L, axis=1),)

    f.defvjp(fwd, bwd)
    return f(x_s)


def sp_reduce_scatter(partial: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Row-parallel combine in SP form: psum_scatter over the seq dim
    (replaces the all-reduce; same wire volume, sharded result)."""
    if rt.mesh.tp == 1:
        return partial

    @jax.custom_vjp
    def f(v):
        return _sp_rs_fwd(v)

    def _sp_rs_fwd(v):
        # wire in the activation dtype (bf16): half the bytes of an f32
        # combine; the f32 matmul accumulation already happened upstream.
        vt = jnp.moveaxis(v.astype(rt.cfg.dtype), 1, 0)
        out = collectives.reduce_scatter(vt, rt.tp_comm(), rt.comm)
        return jnp.moveaxis(out, 0, 1)

    def fwd(v):
        return _sp_rs_fwd(v), None

    def bwd(_, ct):
        # transpose of (sum over ranks + scatter) with replicated-partials
        # semantics: all-gather the cotangent back to full seq
        g = collectives.all_gather(ct, rt.tp_comm(), rt.comm, axis=1)
        return (g,)

    f.defvjp(fwd, bwd)
    return f(partial)


def col_parallel(x: jnp.ndarray, w_shard: jnp.ndarray) -> jnp.ndarray:
    """Replicated x @ column-sharded w -> feature-sharded output (no comm)."""
    return jnp.dot(x, w_shard, preferred_element_type=jnp.float32).astype(x.dtype)


def row_parallel(x_shard: jnp.ndarray, w_shard: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Feature-sharded x @ row-sharded w -> replicated output (one combine).

    Streaming mode — and any config with ``Scheduling.OVERLAPPED`` — routes
    the combine through ``streaming.overlapped_matmul_allreduce``: the
    per-layer TP reduce is chunked and double-buffered against the matmul,
    reusing the runtime's TP communicator so hop-aware tuning sees the real
    topology.  Buffered+fused issues one psum after the full matmul (paper
    §3.1/§5 applied to TP).  All paths are bitwise-identical.
    """
    if rt.mesh.tp == 1:
        return jnp.dot(x_shard, w_shard, preferred_element_type=jnp.float32
                       ).astype(x_shard.dtype)
    if (rt.comm.mode == CommMode.STREAMING
            or rt.comm.scheduling == Scheduling.OVERLAPPED):
        lead = x_shard.shape[:-1]
        h2 = x_shard.reshape(-1, x_shard.shape[-1])
        out = streaming.overlapped_matmul_allreduce(
            h2, w_shard, rt.tp_comm(), rt.comm)
        return out.reshape(*lead, w_shard.shape[-1]).astype(x_shard.dtype)
    partial = jnp.dot(x_shard, w_shard, preferred_element_type=jnp.float32)
    out = collectives.all_reduce(partial, rt.tp_comm(), rt.comm)
    return out.astype(x_shard.dtype)


# ----------------------------------------------------------------------
# MLP (SwiGLU / GELU), column->row parallel
# ----------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x: jnp.ndarray, rt: Runtime, mlp_type: str,
        sharded: bool | None = None, sp: bool = False) -> jnp.ndarray:
    """``sp=True``: x arrives seq-sharded; all-gather in, psum-scatter out
    (Megatron-SP). Otherwise x is replicated and the f operator applies."""
    if sharded is None:
        sharded = bool(rt.cfg.d_ff) and rt.cfg.d_ff % rt.mesh.tp == 0
    if sp and sharded and rt.mesh.tp > 1:
        x = sp_all_gather(x, rt)
    else:
        x = tp_grad_sum(x, rt, sharded)
    up = col_parallel(x, params["w_up"])
    if mlp_type == "swiglu":
        gate = col_parallel(x, params["w_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    if sp and sharded and rt.mesh.tp > 1:
        partial = jnp.dot(h, params["w_down"],
                          preferred_element_type=jnp.float32)
        return sp_reduce_scatter(partial, rt).astype(x.dtype)
    return row_parallel(h, params["w_down"], rt)


# ----------------------------------------------------------------------
# Vocab-sharded embedding / logits / cross-entropy
# ----------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype):
    emb = (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)
    return {"table": emb}


def embed(params, token_ids: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """Vocab-sharded lookup: local gather + all-reduce of masked rows."""
    table = params["table"]            # (vocab/tp, d) local shard
    tp = rt.mesh.tp
    if tp == 1 or table.shape[0] >= rt.cfg.vocab_size:
        # vocab replicated (not divisible by tp): plain lookup
        return jnp.take(table, token_ids, axis=0)
    shard = lax.axis_index(rt.mesh.axis_model)
    vshard = table.shape[0]
    local = token_ids - shard * vshard
    valid = (local >= 0) & (local < vshard)
    rows = jnp.take(table, jnp.clip(local, 0, vshard - 1), axis=0)
    rows = jnp.where(valid[..., None], rows, jnp.zeros_like(rows))
    return collectives.all_reduce(rows, rt.tp_comm(), rt.comm).astype(table.dtype)


def logits_shard(params, x: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """x (…, d) -> vocab-sharded logits (…, vocab/tp); no combine (CE and
    sampling handle the sharded vocab with two small reductions)."""
    table = params["table"]
    # f operator only when the vocab is genuinely sharded (table is a shard).
    x = tp_grad_sum(x, rt, rt.mesh.tp > 1
                    and table.shape[0] < rt.cfg.vocab_size)
    return jnp.dot(x, table.T.astype(x.dtype), preferred_element_type=jnp.float32)


def cross_entropy_vocab_sharded(logits: jnp.ndarray, labels: jnp.ndarray,
                                rt: Runtime, mask: Optional[jnp.ndarray] = None
                                ) -> jnp.ndarray:
    """Stable CE over vocab-sharded logits: pmax + psum over the model axis."""
    tp = rt.mesh.tp
    if logits.shape[-1] >= rt.cfg.vocab_size:
        tp = 1   # vocab replicated on every model rank: no CE collectives
    z = logits.astype(jnp.float32)
    # Math-neutral stability shift; stop_gradient BEFORE pmax (no JVP rule).
    zmax = lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    if tp > 1:
        zmax = collectives.all_reduce(zmax, rt.tp_comm(), rt.comm, op="max")
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    if tp > 1:
        denom = collectives.all_reduce(denom, rt.tp_comm(), rt.comm)
    vshard = logits.shape[-1]
    # NOTE (replicated-VJP invariant): consumers of a psum output must be
    # replicated computations.  We therefore psum the *raw* picked logit and
    # form the loss identically on every rank — attaching -log(denom) only on
    # the label-owning rank would starve the other ranks' softmax-denominator
    # gradient.
    if tp > 1:
        shard = lax.axis_index(rt.mesh.axis_model)
        local = labels - shard * vshard
        valid = (local >= 0) & (local < vshard)
        picked_z = jnp.take_along_axis(
            z, jnp.clip(local, 0, vshard - 1)[..., None], axis=-1)[..., 0]
        picked_z = jnp.where(valid, picked_z, 0.0)
        picked_z = collectives.all_reduce(picked_z, rt.tp_comm(), rt.comm)
    else:
        picked_z = jnp.take_along_axis(z, labels[..., None], axis=-1)[..., 0]
    nll = -(picked_z - zmax[..., 0] - jnp.log(denom[..., 0]))
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def greedy_sample_vocab_sharded(logits: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """argmax over vocab-sharded logits (decode path)."""
    tp = rt.mesh.tp
    vshard = logits.shape[-1]
    local_max = jnp.max(logits, axis=-1)
    local_arg = jnp.argmax(logits, axis=-1)
    if tp == 1 or vshard >= rt.cfg.vocab_size:
        return local_arg
    shard = lax.axis_index(rt.mesh.axis_model)
    global_arg = local_arg + shard * vshard
    gmax = collectives.all_reduce(local_max, rt.tp_comm(), rt.comm, op="max")
    cand = jnp.where(local_max >= gmax, global_arg, jnp.iinfo(jnp.int32).max)
    return collectives.all_reduce(cand, rt.tp_comm(), rt.comm, op="min")
