"""Model assembly for all assigned architecture families.

Families:
  dense   — [attn, mlp] stack, scanned (qwen3, command-r+, deepseek-coder,
            phi-3-vision backbone); sliding-window (mixtral) and 5:1
            local:global (gemma3) attention patterns supported.
  moe     — attn + MoE block (mixtral, deepseek-v3 with MLA + dense head
            layers + shared expert).
  ssm     — Mamba2 stack (mamba2-130m).
  hybrid  — Zamba2: groups of Mamba2 layers with one weight-shared attention
            block applied between groups (input = concat(hidden, embedding)
            re-projected).
  audio   — encoder-decoder (seamless): bidirectional encoder over frame
            embeddings (frontend stub), causal decoder with cross-attention.
  vlm     — patch-embedding stub prepended to token embeddings, dense stack.

Layers are stacked and scanned (`lax.scan`) so compile time is O(1) in depth;
`cfg.remat` wraps each block in jax.checkpoint for training.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers, mla, moe, sharding, ssm
from repro.models.common import ModelConfig, Runtime


# ----------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_dense_layer(key, cfg: ModelConfig, tp: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": (mla.init_mla(k1, cfg, cfg.dtype) if cfg.use_mla
                 else attention.init_attention(k1, cfg, cfg.dtype, tp)),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.dtype),
    }


def _init_moe_layer(key, cfg: ModelConfig, tp: int):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "moe": moe.init_moe(k2, cfg, cfg.dtype, tp),
    }
    if cfg.use_mla:
        p["attn"] = mla.init_mla(k1, cfg, cfg.dtype)
    else:
        p["attn"] = attention.init_attention(k1, cfg, cfg.dtype, tp)
    return p


def _init_ssm_layer(key, cfg: ModelConfig):
    return {"ln": jnp.zeros((cfg.d_model,), cfg.dtype),
            "ssm": ssm.init_ssm(key, cfg, cfg.dtype)}


def _init_cross_layer(key, cfg: ModelConfig, tp: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": attention.init_attention(k1, cfg, cfg.dtype, tp),
        "ln_x": jnp.zeros((cfg.d_model,), cfg.dtype),
        "xattn": attention.init_attention(k2, cfg, cfg.dtype, tp),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.dtype),
    }


def init_model(key, cfg: ModelConfig, tp: int = 1):
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": layers.init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    lk = jax.random.split(keys[1], max(cfg.n_layers, 1))

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio
            blk = r + 1
            n_blocks = cfg.n_layers // blk
            trailing = cfg.n_layers - n_blocks * blk
            params["blocks"] = _stack([
                {"local": _stack([_init_dense_layer(jax.random.fold_in(lk[i], j),
                                                    cfg, tp) for j in range(r)]),
                 "global": _init_dense_layer(jax.random.fold_in(lk[i], r), cfg, tp)}
                for i in range(n_blocks)])
            if trailing:
                params["trailing"] = _stack([
                    _init_dense_layer(lk[n_blocks * blk + j], cfg, tp)
                    for j in range(trailing)])
        else:
            params["layers"] = _stack([_init_dense_layer(lk[i], cfg, tp)
                                       for i in range(cfg.n_layers)])
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - cfg.n_dense_layers
        params["layers"] = _stack([_init_moe_layer(lk[i], cfg, tp)
                                   for i in range(n_moe)])
        if cfg.n_dense_layers:
            dense_cfg = cfg
            params["dense_layers"] = _stack([
                _init_moe_dense_layer(lk[n_moe + i], cfg, tp)
                for i in range(cfg.n_dense_layers)])
    elif cfg.family == "ssm":
        params["layers"] = _stack([_init_ssm_layer(lk[i], cfg)
                                   for i in range(cfg.n_layers)])
    elif cfg.family == "hybrid":
        k_groups = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // k_groups
        trailing = cfg.n_layers - n_groups * k_groups
        params["groups"] = _stack([
            {"ssm": _stack([_init_ssm_layer(jax.random.fold_in(lk[i], j), cfg)
                            for j in range(k_groups)])}
            for i in range(n_groups)])
        if trailing:
            params["trailing"] = _stack([
                _init_ssm_layer(lk[n_groups * k_groups + j], cfg)
                for j in range(trailing)])
        # One weight-shared attention block (applied after every group).
        kx = jax.random.split(keys[2], 3)
        params["shared_attn"] = {
            "proj_in": layers.dense_init(kx[0], 2 * cfg.d_model, cfg.d_model,
                                         cfg.dtype),
            "block": _init_dense_layer(kx[1], cfg, tp),
        }
    elif cfg.family == "audio":
        ek = jax.random.split(keys[3], cfg.n_encoder_layers)
        params["encoder"] = _stack([_init_dense_layer(ek[i], cfg, tp)
                                    for i in range(cfg.n_encoder_layers)])
        params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        params["layers"] = _stack([_init_cross_layer(lk[i], cfg, tp)
                                   for i in range(cfg.n_layers)])
        params["frontend"] = layers.dense_init(keys[4], cfg.frontend_dim,
                                               cfg.d_model, cfg.dtype)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        params["frontend"] = layers.dense_init(keys[4], cfg.frontend_dim,
                                               cfg.d_model, cfg.dtype)
    return params


def _init_moe_dense_layer(key, cfg: ModelConfig, tp: int):
    """Dense (non-MoE) leading layers of deepseek-v3."""
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.dtype),
    }
    if cfg.use_mla:
        p["attn"] = mla.init_mla(k1, cfg, cfg.dtype)
    else:
        p["attn"] = attention.init_attention(k1, cfg, cfg.dtype, tp)
    return p


# ----------------------------------------------------------------------
# Forward blocks
# ----------------------------------------------------------------------

def _attn_block(p, x, positions, rt: Runtime, window=None, causal=None):
    h = layers.rms_norm(x, p["ln1"], rt.cfg.norm_eps)
    if rt.cfg.use_mla:
        h = mla.mla_attention(p["attn"], h, positions, rt)
    else:
        h = attention.attention(p["attn"], h, positions, rt, window=window,
                                causal=causal)
    x = x + h
    return x


def _dense_block(p, x, positions, rt: Runtime, window=None, causal=None):
    x = _attn_block(p, x, positions, rt, window, causal)
    h = layers.rms_norm(x, p["ln2"], rt.cfg.norm_eps)
    x = x + layers.mlp(p["mlp"], h, rt, rt.cfg.mlp_type)
    return x


def _dense_block_sp(p, x_s, positions, rt: Runtime, window=None, causal=None):
    """Megatron-SP dense block: x_s is (B, S/tp, D) seq-sharded.

    LN runs on the shard; attention/MLP all-gather in and psum-scatter out —
    same wire volume as the all-reduce they replace, but the residual carried
    through the layer scan is tp× smaller (the memory-roofline lever of
    EXPERIMENTS.md §Perf)."""
    cfg = rt.cfg
    h = layers.rms_norm(x_s, p["ln1"], cfg.norm_eps)
    a = attention.attention(p["attn"], h, positions, rt, window=window,
                            causal=causal, sp=True)
    x_s = x_s + a
    h = layers.rms_norm(x_s, p["ln2"], cfg.norm_eps)
    x_s = x_s + layers.mlp(p["mlp"], h, rt, cfg.mlp_type, sp=True)
    return x_s


def _moe_layer_fwd(p, x, positions, rt: Runtime, window=None):
    x = _attn_block(p, x, positions, rt, window)
    h = layers.rms_norm(x, p["ln2"], rt.cfg.norm_eps)
    y, aux = moe.moe_block(p["moe"], h, rt)
    return x + y, aux


def _cross_block(p, x, positions, enc_out, enc_pos, rt: Runtime):
    x = _attn_block(p, x, positions, rt)
    h = layers.rms_norm(x, p["ln_x"], rt.cfg.norm_eps)
    dims = attention.attn_dims(rt.cfg, rt.mesh.tp)
    hd = dims.head_dim
    Bsz, T = enc_out.shape[0], enc_out.shape[1]
    # f operator: enc_out enters a model-sharded branch (kv projections) —
    # without it the whole encoder would receive rank-partial cotangents.
    enc_out = layers.tp_grad_sum(enc_out, rt, dims.kv_sharded)
    k = layers.col_parallel(enc_out, p["xattn"]["wk"]).reshape(Bsz, T, -1, hd)
    v = layers.col_parallel(enc_out, p["xattn"]["wv"]).reshape(Bsz, T, -1, hd)
    h = attention.attention(p["xattn"], h, positions, rt, causal=False,
                            kv_override=(k, v, enc_pos))
    x = x + h
    h = layers.rms_norm(x, p["ln2"], rt.cfg.norm_eps)
    return x + layers.mlp(p["mlp"], h, rt, rt.cfg.mlp_type)


def _shared_attn_fwd(p, x, x_embed, positions, rt: Runtime):
    """Zamba2 shared block: concat(hidden, embedding) -> proj -> attn+mlp."""
    h = jnp.concatenate([x, x_embed], axis=-1)
    h = jnp.dot(h, p["proj_in"], preferred_element_type=jnp.float32
                ).astype(x.dtype)
    return _dense_block(p["block"], h, positions, rt)


def _maybe_remat(fn, rt: Runtime, train: bool):
    if rt.cfg.remat and train:
        if rt.cfg.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(fn)
    return fn


# ----------------------------------------------------------------------
# Full forward (training / prefill logits)
# ----------------------------------------------------------------------

class ForwardOut(NamedTuple):
    logits: jnp.ndarray     # vocab-sharded (B, S, V/tp)
    aux_loss: jnp.ndarray   # MoE load-balance loss (0 for non-MoE)


def forward(params, batch: dict, rt: Runtime, train: bool = True) -> ForwardOut:
    cfg = rt.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens, rt)
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "vlm" and "patches" in batch:
        pe = jnp.dot(batch["patches"].astype(x.dtype), params["frontend"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :].repeat(B, 0)

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_ratio:
            x = _local_global_stack(params, x, positions, rt, train)
        else:
            use_sp = (getattr(rt, "seq_parallel", False) and rt.mesh.tp > 1
                      and x.shape[1] % rt.mesh.tp == 0
                      and attention.attn_dims(cfg, rt.mesh.tp).q_sharded)
            block = _dense_block_sp if use_sp else _dense_block
            blk = _maybe_remat(
                functools.partial(block, positions=positions, rt=rt,
                                  window=cfg.sliding_window), rt, train)

            plan = sharding.subplan(rt.fsdp_plan, "layers")
            if use_sp:
                # shard the residual over seq for the whole stack
                x = layers.sp_shard_seq(x, rt)
            x, _ = lax.scan(
                lambda h, p: (blk(sharding.apply_fsdp(p, plan, rt), h), None),
                x, params["layers"])
            if use_sp:
                x = layers.sp_unshard_seq(x, rt)
    elif cfg.family == "moe":
        if "dense_layers" in params:
            dplan = sharding.subplan(rt.fsdp_plan, "dense_layers")

            def dense_body(h, p):
                p = sharding.apply_fsdp(p, dplan, rt)
                h = _attn_block(p, h, positions, rt)
                hh = layers.rms_norm(h, p["ln2"], rt.cfg.norm_eps)
                return h + layers.mlp(p["mlp"], hh, rt, rt.cfg.mlp_type), None
            x, _ = lax.scan(dense_body, x, params["dense_layers"])

        mplan = sharding.subplan(rt.fsdp_plan, "layers")

        def moe_body(carry, p):
            h, aux = carry
            p = sharding.apply_fsdp(p, mplan, rt)
            fn = _maybe_remat(functools.partial(
                _moe_layer_fwd, positions=positions, rt=rt,
                window=cfg.sliding_window), rt, train)
            h, a = fn(p, h)
            return (h, aux + a), None
        (x, aux_total), _ = lax.scan(moe_body, (x, aux_total), params["layers"])
    elif cfg.family == "ssm":
        splan = sharding.subplan(rt.fsdp_plan, "layers")

        def ssm_body(h, p):
            p = sharding.apply_fsdp(p, splan, rt)
            fn = _maybe_remat(lambda pp, hh: hh + ssm.ssm_forward(
                pp["ssm"], layers.rms_norm(hh, pp["ln"], cfg.norm_eps), rt),
                rt, train)
            return fn(p, h), None
        x, _ = lax.scan(ssm_body, x, params["layers"])
    elif cfg.family == "hybrid":
        x_embed = x

        gplan = sharding.subplan(rt.fsdp_plan, "groups")

        def group_body(h, p):
            p = sharding.apply_fsdp(p, gplan, rt)

            def inner(pp, hh):
                for j in range(cfg.hybrid_attn_every):
                    pj = jax.tree.map(lambda a: a[j], pp["ssm"])
                    hh = hh + ssm.ssm_forward(
                        pj["ssm"], layers.rms_norm(hh, pj["ln"], cfg.norm_eps), rt)
                hh = hh + _shared_attn_fwd(params["shared_attn"], hh, x_embed,
                                           positions, rt)
                return hh
            return _maybe_remat(inner, rt, train)(p, h), None
        x, _ = lax.scan(group_body, x, params["groups"])
        if "trailing" in params:
            tplan = sharding.subplan(rt.fsdp_plan, "trailing")

            def tr_body(h, p):
                p = sharding.apply_fsdp(p, tplan, rt)
                return h + ssm.ssm_forward(
                    p["ssm"], layers.rms_norm(h, p["ln"], cfg.norm_eps), rt), None
            x, _ = lax.scan(tr_body, x, params["trailing"])
    elif cfg.family == "audio":
        enc = jnp.dot(batch["frames"].astype(x.dtype), params["frontend"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
        T = enc.shape[1]
        enc_pos = jnp.arange(T)[None, :].repeat(B, 0)

        eplan = sharding.subplan(rt.fsdp_plan, "encoder")

        def enc_body(h, p):
            p = sharding.apply_fsdp(p, eplan, rt)
            fn = _maybe_remat(functools.partial(
                _dense_block, positions=enc_pos, rt=rt, causal=False), rt, train)
            return fn(p, h), None
        enc, _ = lax.scan(enc_body, enc, params["encoder"])
        enc = layers.rms_norm(enc, params["enc_norm"], cfg.norm_eps)

        xplan = sharding.subplan(rt.fsdp_plan, "layers")

        def dec_body(h, p):
            p = sharding.apply_fsdp(p, xplan, rt)
            fn = _maybe_remat(functools.partial(
                _cross_block, positions=positions, enc_out=enc,
                enc_pos=enc_pos, rt=rt), rt, train)
            return fn(p, h), None
        x, _ = lax.scan(dec_body, x, params["layers"])
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.logits_shard(params["embed"], x, rt)
    return ForwardOut(logits=logits, aux_loss=aux_total)


def _local_global_stack(params, x, positions, rt: Runtime, train: bool):
    """gemma3 pattern: scan over (r local + 1 global) super-blocks."""
    cfg = rt.cfg
    r = cfg.local_global_ratio
    bplan = sharding.subplan(rt.fsdp_plan, "blocks")
    tplan = sharding.subplan(rt.fsdp_plan, "trailing")

    def body(h, p):
        p = sharding.apply_fsdp(p, bplan, rt)

        def inner(pp, hh):
            for j in range(r):
                pj = jax.tree.map(lambda a: a[j], pp["local"])
                hh = _dense_block(pj, hh, positions, rt,
                                  window=cfg.sliding_window)
            return _dense_block(pp["global"], hh, positions, rt, window=None)
        return _maybe_remat(inner, rt, train)(p, h), None

    x, _ = lax.scan(body, x, params["blocks"])
    if "trailing" in params:
        def tr(h, p):
            p = sharding.apply_fsdp(p, tplan, rt)
            return _dense_block(p, h, positions, rt,
                                window=cfg.sliding_window), None
        x, _ = lax.scan(tr, x, params["trailing"])
    return x


def loss_fn(params, batch: dict, rt: Runtime):
    out = forward(params, batch, rt, train=True)
    labels = batch["labels"]
    logits = out.logits
    if logits.shape[1] != labels.shape[1]:
        # multimodal prefix (vlm): labels align to the trailing text tokens
        logits = logits[:, -labels.shape[1]:]
    mask = batch.get("loss_mask")
    ce = layers.cross_entropy_vocab_sharded(logits, labels, rt, mask)
    return ce + 0.01 * out.aux_loss, {"ce": ce, "aux": out.aux_loss}
