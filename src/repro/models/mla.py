"""Multi-head Latent Attention (DeepSeek-V3).

Prefill computes standard multi-head attention from the decompressed latents;
decode caches only the compressed latent (kv_lora_rank + rope_dim per token)
and uses the absorbed-matmul trick:

    score_h(t) = (q_nope_h @ W_uk_h) · c_kv(t) + q_rope_h · k_rope(t)
    out_h      = W_uv_h @ (Σ_t p_h(t) · c_kv(t))

The latent cache is sequence-sharded over the ``model`` axis like the GQA
cache (SP decode + LSE combine through ACCL-X).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives
from repro.models import layers
from repro.models.common import ModelConfig, Runtime


def local_heads(cfg: ModelConfig, tp: int) -> int:
    assert cfg.n_heads % tp == 0, "MLA requires n_heads % tp == 0"
    return cfg.n_heads // tp


def init_mla(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": layers.dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "w_uq": layers.dense_init(ks[1], cfg.q_lora_rank, H * qk, dtype),
        "w_dkv": layers.dense_init(ks[2], d, cfg.kv_lora_rank, dtype),
        "w_kr": layers.dense_init(ks[3], d, cfg.qk_rope_dim, dtype),
        "w_uk": layers.dense_init(ks[4], cfg.kv_lora_rank,
                                  H * cfg.qk_nope_dim, dtype),
        "w_uv": layers.dense_init(ks[5], cfg.kv_lora_rank,
                                  H * cfg.v_head_dim, dtype),
        "wo": layers.dense_init(ks[6], H * cfg.v_head_dim, d, dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
    }


def _project(params, x, positions, cfg: ModelConfig, hl: int):
    """Shared q/kv projection. Returns per-device q (B,S,hl,qk), k, v."""
    B, S, _ = x.shape
    nope, ropd = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = layers.rms_norm(jnp.dot(x, params["w_dq"],
                                 preferred_element_type=jnp.float32
                                 ).astype(x.dtype),
                         params["q_norm"], cfg.norm_eps)
    q = layers.col_parallel(cq, params["w_uq"]).reshape(B, S, hl, nope + ropd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = layers.rms_norm(jnp.dot(x, params["w_dkv"],
                                  preferred_element_type=jnp.float32
                                  ).astype(x.dtype),
                          params["kv_norm"], cfg.norm_eps)
    k_rope = jnp.dot(x, params["w_kr"], preferred_element_type=jnp.float32
                     ).astype(x.dtype)                       # (B,S,ropd) shared
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_attention(params, x: jnp.ndarray, positions: jnp.ndarray,
                  rt: Runtime, return_latents: bool = False):
    """Training/prefill MLA. Heads sharded over tp; one row-parallel combine.

    ``return_latents`` additionally returns (ckv, k_rope) for the latent
    decode cache."""
    cfg = rt.cfg
    tp = rt.mesh.tp
    hl = local_heads(cfg, tp)
    B, S, _ = x.shape
    nope, ropd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    x = layers.tp_grad_sum(x, rt, tp > 1)
    q_nope, q_rope, ckv, k_rope = _project(params, x, positions, cfg, hl)
    k_nope = layers.col_parallel(ckv, params["w_uk"]).reshape(B, S, hl, nope)
    v = layers.col_parallel(ckv, params["w_uv"]).reshape(B, S, hl, vd)

    # Fold the shared rope head into per-head keys so the tiled flash path
    # (attention._sdpa) handles MLA identically to standard attention:
    # score = [q_nope|q_rope] · [k_nope|k_rope]  with scale 1/sqrt(nope+ropd).
    from repro.models.attention import _sdpa
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, hl, ropd))],
        axis=-1)
    out = _sdpa(q_cat, k_cat, v, None, None, rt, True, None)
    out = out.reshape(B, S, hl * vd).astype(x.dtype)
    y = layers.row_parallel(out, params["wo"], rt)
    if return_latents:
        return y, (ckv, k_rope)
    return y


class MLACache(NamedTuple):
    ckv: jnp.ndarray      # (B, L_shard, kv_lora_rank)
    k_rope: jnp.ndarray   # (B, L_shard, rope_dim)
    length: jnp.ndarray

    @property
    def seq_shard(self) -> int:
        return self.ckv.shape[1]


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, n_shards: int,
                   dtype) -> MLACache:
    L = max(1, -(-max_len // n_shards))
    return MLACache(
        ckv=jnp.zeros((batch, L, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, L, cfg.qk_rope_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def mla_prefill_cache(cache: MLACache, ckv: jnp.ndarray, k_rope: jnp.ndarray,
                      rt: Runtime) -> MLACache:
    shard = rt.sp_comm().rank() if rt.sp_size > 1 else 0
    L, S = cache.seq_shard, ckv.shape[1]
    pad = rt.sp_size * L - S
    if pad > 0:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return MLACache(
        ckv=lax.dynamic_slice_in_dim(ckv, shard * L, L, 1).astype(cache.ckv.dtype),
        k_rope=lax.dynamic_slice_in_dim(k_rope, shard * L, L, 1
                                        ).astype(cache.k_rope.dtype),
        length=jnp.asarray(S, jnp.int32))


def mla_decode(params, x: jnp.ndarray, cache: MLACache, rt: Runtime
               ) -> tuple[jnp.ndarray, MLACache]:
    """One decode step with the absorbed latent cache. x: (B,1,D)."""
    cfg = rt.cfg
    tp = rt.mesh.tp
    hl = local_heads(cfg, tp)
    B = x.shape[0]
    nope, ropd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    pos = jnp.broadcast_to(cache.length[None][None], (B, 1))
    q_nope, q_rope, ckv_new, kr_new = _project(params, x, pos, cfg, hl)

    # Append the new latent to the sharded cache.
    sp = rt.sp_size
    shard = rt.sp_comm().rank() if sp > 1 else 0
    L = cache.seq_shard
    owner, off = cache.length // L, cache.length % L
    ckv_upd = lax.dynamic_update_slice_in_dim(
        cache.ckv, ckv_new.astype(cache.ckv.dtype), off, axis=1)
    kr_upd = lax.dynamic_update_slice_in_dim(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), off, axis=1)
    mine = owner == shard
    cache = MLACache(k_rope=jnp.where(mine, kr_upd, cache.k_rope),
                     ckv=jnp.where(mine, ckv_upd, cache.ckv),
                     length=cache.length + 1)

    # Absorb W_uk into q: q_abs (B,hl,r); every device needs all heads.
    w_uk = params["w_uk"].reshape(r, hl, nope)
    q_abs_loc = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                           w_uk.transpose(0, 1, 2).astype(jnp.float32)
                           )[:, 0]  # (B,hl,r)
    qr_loc = q_rope[:, 0]  # (B,hl,ropd)
    if tp > 1:
        q_abs = collectives.all_gather(q_abs_loc, rt.tp_comm(), rt.comm, axis=1)
        qr = collectives.all_gather(qr_loc.astype(jnp.float32), rt.tp_comm(),
                                    rt.comm, axis=1)
    else:
        q_abs, qr = q_abs_loc, qr_loc.astype(jnp.float32)
    H = q_abs.shape[1]

    scale = 1.0 / ((nope + ropd) ** 0.5)
    k_pos = shard * L + jnp.arange(L)
    valid = k_pos < cache.length
    bias = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)

    s = (jnp.einsum("bhr,btr->bht", q_abs, cache.ckv.astype(jnp.float32))
         + jnp.einsum("bhd,btd->bht", qr, cache.k_rope.astype(jnp.float32))
         ) * scale + bias[None, None]
    m_loc = jnp.max(s, axis=-1)
    m = (collectives.all_reduce(m_loc, rt.sp_comm(), rt.comm, op="max")
         if sp > 1 else m_loc)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m[..., None]), 0.0)
    s_loc = jnp.sum(p, axis=-1)
    lat_loc = jnp.einsum("bht,btr->bhr", p, cache.ckv.astype(jnp.float32))
    if sp > 1:
        # Fused LSE combine (see attention.decode_attention): denominator
        # and latent partials ride one sum all-reduce — bitwise-identical,
        # one fewer per-layer collective on the latency-bound decode path.
        dl = collectives.all_reduce(
            jnp.concatenate([s_loc[..., None], lat_loc], axis=-1),
            rt.sp_comm(), rt.comm)
        denom, lat = dl[..., 0], dl[..., 1:]
    else:
        denom, lat = s_loc, lat_loc
    lat = lat / jnp.maximum(denom[..., None], 1e-30)      # (B,H,r)

    # Decompress with my local W_uv heads and combine row-parallel.
    mshard = lax.axis_index(rt.mesh.axis_model) if tp > 1 else 0
    start = (mshard * hl) if tp > 1 else 0
    lat_loc = lax.dynamic_slice_in_dim(lat, start, hl, axis=1)
    w_uv = params["w_uv"].reshape(r, hl, vd)
    o = jnp.einsum("bhr,rhv->bhv", lat_loc, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, hl * vd).astype(x.dtype)
    y = layers.row_parallel(o, params["wo"], rt)
    return y, cache
