"""Mamba2 (SSD — state-space duality) layers.

Training/prefill uses the chunked SSD algorithm: within a chunk the output is
an attention-like masked matmul (MXU-friendly — the reason SSD maps well to
TPU), across chunks a small recurrence carries the (heads, d_head, state)
chunk state.  The chunk-state hand-off is the same communication pattern as
the paper's halo exchange — it is what makes the hybrid/SSM architectures
natural targets for ACCL-X sequence parallelism.

TP layout: heads (= d_inner / head_dim) sharded over ``model`` when divisible
(zamba2: 112 heads / 16); otherwise the layer computes replicated (mamba2-130m
has 24 heads — tiny, so replication costs little; recorded as FLOP waste).
B/C/dt projections are small and always computed replicated.

``rt.use_pallas=True`` routes the intra-chunk matmuls to the Pallas SSD
kernel (``repro.kernels.ssd_scan``); the code below is the jnp reference.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers
from repro.models.common import ModelConfig, Runtime


def ssm_dims(cfg: ModelConfig, tp: int):
    """(local_heads, sharded?)"""
    nh = cfg.ssm_heads
    if tp > 1 and nh % tp == 0:
        return nh // tp, True
    return nh, False


def init_ssm(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner
    nh, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 8)
    return {
        "w_z": layers.dense_init(ks[0], d, di, dtype),
        "w_x": layers.dense_init(ks[1], d, di, dtype),
        "w_B": layers.dense_init(ks[2], d, g * n, dtype),
        "w_C": layers.dense_init(ks[3], d, g * n, dtype),
        "w_dt": layers.dense_init(ks[4], d, nh, dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_x": (jax.random.normal(ks[5], (cfg.conv_width, di), jnp.float32)
                   * (1.0 / cfg.conv_width) ** 0.5).astype(dtype),
        "norm": jnp.zeros((di,), dtype),
        "w_out": layers.dense_init(ks[6], di, d, dtype),
    }


def _depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, state=None):
    """Causal depthwise conv. x: (B,S,C), w: (W,C). state: (B,W-1,C) or None.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return jax.nn.silu(y).astype(x.dtype), new_state


def ssd_chunked_ref(x, dt, A, B, C, chunk: int):
    """Reference chunked SSD (scan over chunks; memory O(chunk²)).

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, g, n) with g == 1 (broadcast over heads).
    Returns y: (b, s, h, p) and final state (b, h, n, p).

    Within a chunk the output is an attention-like masked matmul (the SSD
    duality — MXU-friendly); across chunks a (h, n, p) state is carried, the
    neighbor-exchange-shaped recurrence noted in DESIGN.md.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    xs = (to_chunks(x.astype(jnp.float32)),
          to_chunks(dt.astype(jnp.float32)),
          to_chunks(B.astype(jnp.float32))[..., 0, :],
          to_chunks(C.astype(jnp.float32))[..., 0, :])

    def step(h_prev, inp):
        xc, dtc, Bc, Cc = inp          # (b,l,h,p),(b,l,h),(b,l,n),(b,l,n)
        dA = dtc * A[None, None, :]
        cum = jnp.cumsum(dA, axis=1)                       # (b,l,h)
        # Intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j.  Mask the
        # exponent (not the result): exp() of future entries can overflow,
        # and 0*inf would NaN the backward pass.
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # (b,i,j,h)
        Lmat = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        cb = jnp.einsum("bin,bjn->bij", Cc, Bc)
        w = cb[..., None] * Lmat                           # (b,i,j,h)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", w, dtc, xc)
        # Inter-chunk: y_i += C_i exp(cum_i) h_prev
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", Cc, jnp.exp(cum), h_prev)
        # Chunk state hand-off
        decay_end = jnp.exp(cum[:, -1:, :] - cum)          # (b,l,h)
        s_c = jnp.einsum("bjh,bjh,bjn,bjhp->bhnp", decay_end, dtc, Bc, xc)
        h_new = h_prev * jnp.exp(cum[:, -1, :])[..., None, None] + s_c
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    h_final, ys = lax.scan(step, h0, xs)                   # ys: (nc,b,l,h,p)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, h_final


def ssm_forward(params, x: jnp.ndarray, rt: Runtime,
                conv_state=None, ssm_state=None, return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B,S,D) replicated -> (B,S,D)."""
    cfg = rt.cfg
    tp = rt.mesh.tp
    hl, sharded = ssm_dims(cfg, tp)
    B, S, D = x.shape
    p_dim = cfg.ssm_head_dim

    x = layers.tp_grad_sum(x, rt, sharded)
    z = layers.col_parallel(x, params["w_z"]) if sharded else jnp.dot(
        x, params["w_z"], preferred_element_type=jnp.float32).astype(x.dtype)
    xin = layers.col_parallel(x, params["w_x"]) if sharded else jnp.dot(
        x, params["w_x"], preferred_element_type=jnp.float32).astype(x.dtype)
    Bp = jnp.dot(x, params["w_B"], preferred_element_type=jnp.float32
                 ).astype(x.dtype).reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    Cp = jnp.dot(x, params["w_C"], preferred_element_type=jnp.float32
                 ).astype(x.dtype).reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    dt_all = jnp.dot(x, params["w_dt"], preferred_element_type=jnp.float32)

    if sharded:
        shard = lax.axis_index(rt.mesh.axis_model)
        dt = lax.dynamic_slice_in_dim(dt_all, shard * hl, hl, axis=2)
        A_log = lax.dynamic_slice_in_dim(params["A_log"], shard * hl, hl, 0)
        Dp = lax.dynamic_slice_in_dim(params["D"], shard * hl, hl, 0)
        dt_bias = lax.dynamic_slice_in_dim(params["dt_bias"], shard * hl, hl, 0)
        norm_w = lax.dynamic_slice_in_dim(params["norm"], shard * hl * p_dim,
                                          hl * p_dim, 0)
        conv_w = params["conv_x"]  # stored already column-sharded by launcher
    else:
        dt, A_log, Dp, dt_bias, conv_w, norm_w = (
            dt_all, params["A_log"], params["D"], params["dt_bias"],
            params["conv_x"], params["norm"])

    xin, new_conv = _depthwise_conv(xin, conv_w, conv_state)
    dt = jax.nn.softplus(dt + dt_bias[None, None])
    A = -jnp.exp(A_log)

    xh = xin.reshape(B, S, hl, p_dim)
    if rt.use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, h_final = ssd_ops.ssd_chunked(xh, dt, A, Bp, Cp, cfg.ssm_chunk)
    else:
        y, h_final = ssd_chunked_ref(xh, dt, A, Bp, Cp, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * Dp[None, None, :, None]
    y = y.reshape(B, S, hl * p_dim).astype(x.dtype)

    # Gated per-head RMSNorm (grouped per SSD head, so the result is
    # identical under any tp) + output projection.
    yg = (y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
          ).reshape(B, S, hl, p_dim)
    yg = layers.rms_norm(yg, norm_w.reshape(hl, p_dim), cfg.norm_eps)
    y = yg.reshape(B, S, hl * p_dim)
    out = (layers.row_parallel(y, params["w_out"], rt) if sharded
           else jnp.dot(y, params["w_out"], preferred_element_type=jnp.float32
                        ).astype(x.dtype))
    if return_state:
        return out, (new_conv, h_final)
    return out


class SSMState(NamedTuple):
    conv: jnp.ndarray     # (B, W-1, d_inner_local)
    h: jnp.ndarray        # (B, local_heads, state, head_dim) fp32


def init_ssm_state(cfg: ModelConfig, batch: int, tp: int) -> SSMState:
    hl, _ = ssm_dims(cfg, tp)
    di_l = hl * cfg.ssm_head_dim
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di_l), cfg.dtype),
        h=jnp.zeros((batch, hl, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32))


def ssm_decode(params, x: jnp.ndarray, state: SSMState, rt: Runtime
               ) -> tuple[jnp.ndarray, SSMState]:
    """Single-token recurrent step. x: (B,1,D)."""
    cfg = rt.cfg
    tp = rt.mesh.tp
    hl, sharded = ssm_dims(cfg, tp)
    B = x.shape[0]
    p_dim = cfg.ssm_head_dim

    z = layers.col_parallel(x, params["w_z"]) if sharded else jnp.dot(
        x, params["w_z"], preferred_element_type=jnp.float32).astype(x.dtype)
    xin = layers.col_parallel(x, params["w_x"]) if sharded else jnp.dot(
        x, params["w_x"], preferred_element_type=jnp.float32).astype(x.dtype)
    Bp = jnp.dot(x, params["w_B"], preferred_element_type=jnp.float32
                 )[:, 0].reshape(B, cfg.ssm_groups, cfg.ssm_state)[:, 0]
    Cp = jnp.dot(x, params["w_C"], preferred_element_type=jnp.float32
                 )[:, 0].reshape(B, cfg.ssm_groups, cfg.ssm_state)[:, 0]
    dt_all = jnp.dot(x, params["w_dt"], preferred_element_type=jnp.float32)[:, 0]

    if sharded:
        shard = lax.axis_index(rt.mesh.axis_model)
        dt = lax.dynamic_slice_in_dim(dt_all, shard * hl, hl, axis=1)
        A_log = lax.dynamic_slice_in_dim(params["A_log"], shard * hl, hl, 0)
        Dp = lax.dynamic_slice_in_dim(params["D"], shard * hl, hl, 0)
        dt_bias = lax.dynamic_slice_in_dim(params["dt_bias"], shard * hl, hl, 0)
        norm_w = lax.dynamic_slice_in_dim(params["norm"], shard * hl * p_dim,
                                          hl * p_dim, 0)
    else:
        dt, A_log, Dp, dt_bias, norm_w = (dt_all, params["A_log"], params["D"],
                                          params["dt_bias"], params["norm"])

    xin, new_conv = _depthwise_conv(xin, params["conv_x"], state.conv)
    dt = jax.nn.softplus(dt + dt_bias[None])          # (B, hl)
    A = -jnp.exp(A_log)

    xh = xin[:, 0].reshape(B, hl, p_dim).astype(jnp.float32)
    decay = jnp.exp(dt * A[None])                     # (B, hl)
    # h: (B, hl, n, p);  h' = decay·h + dt·B ⊗ x
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bp, xh)
    h_new = state.h * decay[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cp, h_new)         # (B, hl, p)
    y = y + xh * Dp[None, :, None]
    y = y.reshape(B, 1, hl * p_dim).astype(x.dtype)

    yg = (y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
          ).reshape(B, 1, hl, p_dim)
    yg = layers.rms_norm(yg, norm_w.reshape(hl, p_dim), cfg.norm_eps)
    y = yg.reshape(B, 1, hl * p_dim)
    out = (layers.row_parallel(y, params["w_out"], rt) if sharded
           else jnp.dot(y, params["w_out"], preferred_element_type=jnp.float32
                        ).astype(x.dtype))
    return out, SSMState(conv=new_conv, h=h_new)
