"""Serving path: prefill (build caches) + single-token decode, all families.

Caches are pytrees with a leading layer axis so the per-layer loop is a
``lax.scan`` with caches as scanned inputs/outputs — compile time stays O(1)
in depth for 81-layer models.

Memory layout: every KV/latent cache is **sequence-sharded over the model
axis** (see attention.py) — a 512 K-token cache splits 16 ways; partial
attention combines via two small ACCL-X all-reduces (LSE trick).  SSM decode
state is (heads, state, head_dim), sharded over heads when divisible.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers, mla, moe, ssm
from repro.models.common import ModelConfig, Runtime
from repro.models.transformer import _shared_attn_fwd


# ----------------------------------------------------------------------
# Prefill block helpers (mirror transformer.py blocks, capturing caches)
# ----------------------------------------------------------------------

def _prefill_dense(p, x, positions, rt: Runtime, max_len: int, window=None):
    cfg = rt.cfg
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, (k, v) = attention.attention(p["attn"], h, positions, rt, window=window,
                                    return_kv=True)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.mlp(p["mlp"], h, rt, cfg.mlp_type)
    cache = attention.init_kv_cache(cfg, x.shape[0], max_len, rt.sp_size,
                                    cfg.dtype)
    cache = attention.prefill_into_cache(cache, k, v, rt)
    return x, cache


def _prefill_mla(p, x, positions, rt: Runtime, max_len: int):
    cfg = rt.cfg
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, (ckv, k_rope) = mla.mla_attention(p["attn"], h, positions, rt,
                                         return_latents=True)
    x = x + a
    cache = mla.init_mla_cache(cfg, x.shape[0], max_len, rt.sp_size, cfg.dtype)
    cache = mla.mla_prefill_cache(cache, ckv, k_rope, rt)
    return x, cache


def _decode_dense(p, x, cache, rt: Runtime, window=None):
    cfg = rt.cfg
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, cache = attention.decode_attention(p["attn"], h, cache, rt, window=window)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.mlp(p["mlp"], h, rt, cfg.mlp_type)
    return x, cache


# ----------------------------------------------------------------------
# Prefill
# ----------------------------------------------------------------------

class ServeState(NamedTuple):
    caches: Any            # family-specific pytree (leading layer axes)
    last_logits: jnp.ndarray   # (B, V/tp) vocab-sharded
    length: jnp.ndarray


def prefill(params, batch: dict, rt: Runtime, max_len: int) -> ServeState:
    cfg = rt.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens, rt)
    positions = jnp.arange(S)[None, :].repeat(B, 0)

    if cfg.family == "vlm" and "patches" in batch:
        pe = jnp.dot(batch["patches"].astype(x.dtype), params["frontend"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :].repeat(B, 0)

    caches: Any
    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_ratio:
            x, caches = _prefill_local_global(params, x, positions, rt, max_len)
        else:
            def body(h, p):
                return _prefill_dense(p, h, positions, rt, max_len,
                                      cfg.sliding_window)
            x, caches = lax.scan(body, x, params["layers"])
    elif cfg.family == "moe":
        dense_caches = None
        if "dense_layers" in params:
            def dbody(h, p):
                if cfg.use_mla:
                    h2, c = _prefill_mla(p, h, positions, rt, max_len)
                else:
                    h2, c = _prefill_dense_self(p, h, positions, rt, max_len)
                hh = layers.rms_norm(h2, p["ln2"], cfg.norm_eps)
                return h2 + layers.mlp(p["mlp"], hh, rt, cfg.mlp_type), c
            x, dense_caches = lax.scan(dbody, x, params["dense_layers"])

        def mbody(h, p):
            if cfg.use_mla:
                h2, c = _prefill_mla(p, h, positions, rt, max_len)
            else:
                cfg_w = cfg.sliding_window
                hh = layers.rms_norm(h, p["ln1"], cfg.norm_eps)
                a, (k, v) = attention.attention(p["attn"], hh, positions, rt,
                                                window=cfg_w, return_kv=True)
                h2 = h + a
                c = attention.init_kv_cache(cfg, h.shape[0], max_len,
                                            rt.sp_size, cfg.dtype)
                c = attention.prefill_into_cache(c, k, v, rt)
            hh = layers.rms_norm(h2, p["ln2"], cfg.norm_eps)
            y, _aux = moe.moe_block(p["moe"], hh, rt)
            return h2 + y, c
        x, moe_caches = lax.scan(mbody, x, params["layers"])
        caches = {"moe": moe_caches, "dense": dense_caches}
    elif cfg.family == "ssm":
        def sbody(h, p):
            hh = layers.rms_norm(h, p["ln"], cfg.norm_eps)
            y, (conv, hstate) = ssm.ssm_forward(p["ssm"], hh, rt,
                                                return_state=True)
            # ssd state layout (b,h,n,p) -> SSMState layout (b,h,n,p)
            return h + y, ssm.SSMState(conv=conv, h=hstate)
        x, caches = lax.scan(sbody, x, params["layers"])
    elif cfg.family == "hybrid":
        x_embed = x

        def gbody(h, p):
            states = []
            for j in range(cfg.hybrid_attn_every):
                pj = jax.tree.map(lambda a: a[j], p["ssm"])
                hh = layers.rms_norm(h, pj["ln"], cfg.norm_eps)
                y, (conv, hstate) = ssm.ssm_forward(pj["ssm"], hh, rt,
                                                    return_state=True)
                h = h + y
                states.append(ssm.SSMState(conv=conv, h=hstate))
            # shared attention block with its own per-group cache
            sp = params["shared_attn"]
            hcat = jnp.concatenate([h, x_embed], axis=-1)
            hin = jnp.dot(hcat, sp["proj_in"],
                          preferred_element_type=jnp.float32).astype(h.dtype)
            hn = layers.rms_norm(hin, sp["block"]["ln1"], cfg.norm_eps)
            a, (k, v) = attention.attention(sp["block"]["attn"], hn, positions,
                                            rt, return_kv=True)
            hin = hin + a
            hn = layers.rms_norm(hin, sp["block"]["ln2"], cfg.norm_eps)
            hin = hin + layers.mlp(sp["block"]["mlp"], hn, rt, cfg.mlp_type)
            h = h + hin
            c = attention.init_kv_cache(cfg, h.shape[0], max_len, rt.sp_size,
                                        cfg.dtype)
            c = attention.prefill_into_cache(c, k, v, rt)
            return h, {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *states),
                       "attn": c}
        x, gcaches = lax.scan(gbody, x, params["groups"])
        tcaches = None
        if "trailing" in params:
            def tbody(h, p):
                hh = layers.rms_norm(h, p["ln"], cfg.norm_eps)
                y, (conv, hstate) = ssm.ssm_forward(p["ssm"], hh, rt,
                                                    return_state=True)
                return h + y, ssm.SSMState(conv=conv, h=hstate)
            x, tcaches = lax.scan(tbody, x, params["trailing"])
        caches = {"groups": gcaches, "trailing": tcaches}
    elif cfg.family == "audio":
        enc = jnp.dot(batch["frames"].astype(x.dtype), params["frontend"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
        T = enc.shape[1]
        enc_pos = jnp.arange(T)[None, :].repeat(B, 0)

        def ebody(h, p):
            from repro.models.transformer import _dense_block
            return _dense_block(p, h, enc_pos, rt, causal=False), None
        enc, _ = lax.scan(ebody, enc, params["encoder"])
        enc = layers.rms_norm(enc, params["enc_norm"], cfg.norm_eps)

        def xbody(h, p):
            h, self_c = _prefill_dense_self(p, h, positions, rt, max_len)
            # cross-attention + cache of encoder K/V (seq-sharded, frozen)
            hd = attention.attn_dims(cfg, rt.mesh.tp).head_dim
            k = layers.col_parallel(enc, p["xattn"]["wk"]).reshape(B, T, -1, hd)
            v = layers.col_parallel(enc, p["xattn"]["wv"]).reshape(B, T, -1, hd)
            hn = layers.rms_norm(h, p["ln_x"], cfg.norm_eps)
            a = attention.attention(p["xattn"], hn, positions, rt, causal=False,
                                    kv_override=(k, v, enc_pos))
            h = h + a
            dims = attention.attn_dims(cfg, rt.mesh.tp)
            if dims.kv_sharded:
                from repro.core import collectives
                k = collectives.all_gather(k, rt.tp_comm(), rt.comm, axis=2)
                v = collectives.all_gather(v, rt.tp_comm(), rt.comm, axis=2)
            xc = attention.init_kv_cache(cfg, B, T, rt.sp_size, cfg.dtype)
            xc = attention.prefill_into_cache(xc, k, v, rt)
            hn = layers.rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + layers.mlp(p["mlp"], hn, rt, cfg.mlp_type)
            return h, {"self": self_c, "cross": xc}
        x, caches = lax.scan(xbody, x, params["layers"])
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = layers.logits_shard(params["embed"], x[:, -1], rt)
    return ServeState(caches=caches, last_logits=last,
                      length=jnp.asarray(tokens.shape[1], jnp.int32))


def _prefill_dense_self(p, x, positions, rt: Runtime, max_len: int):
    cfg = rt.cfg
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, (k, v) = attention.attention(p["attn"], h, positions, rt, return_kv=True)
    x = x + a
    cache = attention.init_kv_cache(cfg, x.shape[0], max_len, rt.sp_size,
                                    cfg.dtype)
    cache = attention.prefill_into_cache(cache, k, v, rt)
    return x, cache


def _prefill_local_global(params, x, positions, rt: Runtime, max_len: int):
    cfg = rt.cfg
    r = cfg.local_global_ratio

    def body(h, p):
        local_caches = []
        for j in range(r):
            pj = jax.tree.map(lambda a: a[j], p["local"])
            h, c = _prefill_dense(pj, h, positions, rt, max_len,
                                  cfg.sliding_window)
            local_caches.append(c)
        h, gc = _prefill_dense(p["global"], h, positions, rt, max_len, None)
        return h, {"local": jax.tree.map(lambda *xs: jnp.stack(xs),
                                         *local_caches), "global": gc}
    x, caches = lax.scan(body, x, params["blocks"])
    tcaches = None
    if "trailing" in params:
        def tb(h, p):
            return _prefill_dense(p, h, positions, rt, max_len,
                                  cfg.sliding_window)
        x, tcaches = lax.scan(tb, x, params["trailing"])
    return x, {"blocks": caches, "trailing": tcaches}


# ----------------------------------------------------------------------
# Decode step
# ----------------------------------------------------------------------

def decode_step(params, token: jnp.ndarray, state: ServeState, rt: Runtime
                ) -> ServeState:
    """token: (B,) int32 — append one token, return updated state."""
    cfg = rt.cfg
    B = token.shape[0]
    x = layers.embed(params["embed"], token[:, None], rt)
    caches = state.caches

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_ratio:
            x, caches = _decode_local_global(params, x, caches, rt)
        else:
            def body(h, pc):
                p, c = pc
                return _decode_dense(p, h, c, rt, cfg.sliding_window)
            x, new = lax.scan(body, x, (params["layers"], caches))
            caches = new
    elif cfg.family == "moe":
        new_dense = None
        if "dense_layers" in params:
            def dbody(h, pc):
                p, c = pc
                if cfg.use_mla:
                    hh = layers.rms_norm(h, p["ln1"], cfg.norm_eps)
                    a, c = mla.mla_decode(p["attn"], hh, c, rt)
                    h = h + a
                else:
                    h, c = _decode_dense(p, h, c, rt)
                    return h, c
                hh = layers.rms_norm(h, p["ln2"], cfg.norm_eps)
                return h + layers.mlp(p["mlp"], hh, rt, cfg.mlp_type), c
            x, new_dense = lax.scan(dbody, x, (params["dense_layers"],
                                               caches["dense"]))

        def mbody(h, pc):
            p, c = pc
            if cfg.use_mla:
                hh = layers.rms_norm(h, p["ln1"], cfg.norm_eps)
                a, c = mla.mla_decode(p["attn"], hh, c, rt)
                h = h + a
            else:
                hh = layers.rms_norm(h, p["ln1"], cfg.norm_eps)
                a, c = attention.decode_attention(p["attn"], hh, c, rt,
                                                  window=cfg.sliding_window)
                h = h + a
            hh = layers.rms_norm(h, p["ln2"], cfg.norm_eps)
            y, _aux = moe.moe_block(p["moe"], hh, rt)
            return h + y, c
        x, new_moe = lax.scan(mbody, x, (params["layers"], caches["moe"]))
        caches = {"moe": new_moe, "dense": new_dense}
    elif cfg.family == "ssm":
        def sbody(h, pc):
            p, c = pc
            hh = layers.rms_norm(h, p["ln"], cfg.norm_eps)
            y, c = ssm.ssm_decode(p["ssm"], hh, c, rt)
            return h + y, c
        x, caches = lax.scan(sbody, x, (params["layers"], caches))
    elif cfg.family == "hybrid":
        x_embed = x

        def gbody(h, pc):
            p, c = pc
            new_states = []
            for j in range(cfg.hybrid_attn_every):
                pj = jax.tree.map(lambda a: a[j], p["ssm"])
                cj = jax.tree.map(lambda a: a[j], c["ssm"])
                hh = layers.rms_norm(h, pj["ln"], cfg.norm_eps)
                y, cj = ssm.ssm_decode(pj["ssm"], hh, cj, rt)
                h = h + y
                new_states.append(cj)
            sp = params["shared_attn"]
            hcat = jnp.concatenate([h, x_embed], axis=-1)
            hin = jnp.dot(hcat, sp["proj_in"],
                          preferred_element_type=jnp.float32).astype(h.dtype)
            hn = layers.rms_norm(hin, sp["block"]["ln1"], cfg.norm_eps)
            a, ac = attention.decode_attention(sp["block"]["attn"], hn,
                                               c["attn"], rt)
            hin = hin + a
            hn = layers.rms_norm(hin, sp["block"]["ln2"], cfg.norm_eps)
            hin = hin + layers.mlp(sp["block"]["mlp"], hn, rt, cfg.mlp_type)
            h = h + hin
            return h, {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *new_states), "attn": ac}
        x, gnew = lax.scan(gbody, x, (params["groups"], caches["groups"]))
        tnew = caches["trailing"]
        if "trailing" in params:
            def tbody(h, pc):
                p, c = pc
                hh = layers.rms_norm(h, p["ln"], cfg.norm_eps)
                y, c = ssm.ssm_decode(p["ssm"], hh, c, rt)
                return h + y, c
            x, tnew = lax.scan(tbody, x, (params["trailing"],
                                          caches["trailing"]))
        caches = {"groups": gnew, "trailing": tnew}
    elif cfg.family == "audio":
        def xbody(h, pc):
            p, c = pc
            hh = layers.rms_norm(h, p["ln1"], cfg.norm_eps)
            a, sc = attention.decode_attention(p["attn"], hh, c["self"], rt)
            h = h + a
            hh = layers.rms_norm(h, p["ln_x"], cfg.norm_eps)
            a, _ = attention.decode_attention(p["xattn"], hh, c["cross"], rt,
                                              append=False,
                                              q_pos=c["self"].length)
            h = h + a
            hh = layers.rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + layers.mlp(p["mlp"], hh, rt, cfg.mlp_type)
            return h, {"self": sc, "cross": c["cross"]}
        x, caches = lax.scan(xbody, x, (params["layers"], caches))
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.logits_shard(params["embed"], x[:, -1], rt)
    return ServeState(caches=caches, last_logits=logits,
                      length=state.length + 1)


def _decode_local_global(params, x, caches, rt: Runtime):
    cfg = rt.cfg
    r = cfg.local_global_ratio

    def body(h, pc):
        p, c = pc
        new_local = []
        for j in range(r):
            pj = jax.tree.map(lambda a: a[j], p["local"])
            cj = jax.tree.map(lambda a: a[j], c["local"])
            h, cj = _decode_dense(pj, h, cj, rt, cfg.sliding_window)
            new_local.append(cj)
        h, gc = _decode_dense(p["global"], h, c["global"], rt, None)
        return h, {"local": jax.tree.map(lambda *xs: jnp.stack(xs), *new_local),
                   "global": gc}
    x, new_blocks = lax.scan(body, x, (params["blocks"], caches["blocks"]))
    new_trailing = caches["trailing"]
    if "trailing" in params:
        def tb(h, pc):
            p, c = pc
            return _decode_dense(p, h, c, rt, cfg.sliding_window)
        x, new_trailing = lax.scan(tb, x, (params["trailing"],
                                           caches["trailing"]))
    return x, {"blocks": new_blocks, "trailing": new_trailing}
