"""Mixture-of-Experts with expert parallelism over the ``model`` axis.

Expert placement is a flattened (expert × ff-shard) layout so one scheme
covers both assigned MoE architectures on tp=16:

- deepseek-v3: 256 experts → 16 whole experts per device (EP16, tp_inner=1).
- mixtral-8x22b: 8 experts → each expert split into 2 ff-shards across
  device pairs (EP8 × TP2, tp_inner=2).

Activations are replicated across the model axis between blocks, so dispatch
is a *local* capacity-bounded gather (no all-to-all needed for EP-over-TP) and
the combine is a single ACCL-X all-reduce that simultaneously sums expert
contributions and intra-expert ff-shards.  An alternative all-to-all dispatch
(EP over the data axis — tokens travel) is provided for the collective-bound
experiments; it is the MoE pattern whose latency the paper's streaming levers
target.  Under ``Scheduling.OVERLAPPED`` (streaming delivery) both the
dispatch and the combine all-to-all are tiled into independent wire chunks
(``streaming.chunked_all_to_all`` via ``collectives.all_to_all``), so each
exchange overlaps its own transfer — bitwise-identical to the fused op.

Capacity semantics follow Switch/GShard: per expert at most
C = capacity_factor · T · top_k / n_experts tokens; overflow tokens drop that
expert's contribution (their other experts still fire).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives
from repro.models import layers
from repro.models.common import ModelConfig, Runtime


def moe_layout(cfg: ModelConfig, tp: int):
    """(experts_per_device, tp_inner). Requires n_experts % tp == 0 or
    tp % n_experts == 0."""
    E = cfg.n_experts
    if E % tp == 0:
        return E // tp, 1
    if tp % E == 0:
        return 1, tp // E
    raise ValueError(f"n_experts={E} incompatible with tp={tp}")


def init_moe(key, cfg: ModelConfig, dtype, tp: int):
    """Global arrays shaped (tp, E_loc, d, ff_slice) — shard dim 0 by model."""
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e_loc, tp_inner = moe_layout(cfg, tp)
    ffs = ff // tp_inner
    ks = jax.random.split(key, 6)
    scale_in = (1.0 / d) ** 0.5
    scale_out = (1.0 / ff) ** 0.5

    def draw(k, a, b, scale):
        # Canonical (E, a, b) draw, rearranged to the flattened (tp, e_loc,
        # a, b_slice) layout — values are independent of tp.
        full = jax.random.normal(k, (cfg.n_experts, a, b), jnp.float32) * scale
        full = full.reshape(cfg.n_experts, a, tp_inner, b // tp_inner)
        full = jnp.moveaxis(full, 2, 1)           # (E, tp_inner, a, b_slice)
        full = full.reshape(tp, e_loc, a, b // tp_inner)
        return full.astype(dtype)

    def draw_t(k, a, b, scale):
        # Same for (…, a_slice, b) row-sharded layout (w_down).
        full = jax.random.normal(k, (cfg.n_experts, a, b), jnp.float32) * scale
        full = full.reshape(cfg.n_experts, tp_inner, a // tp_inner, b)
        return full.reshape(tp, e_loc, a // tp_inner, b).astype(dtype)

    p = {
        "router": layers.dense_init(ks[0], d, cfg.n_experts, jnp.float32),
        "w_gate": draw(ks[1], d, ff, scale_in),
        "w_up": draw(ks[2], d, ff, scale_in),
        "w_down": draw_t(ks[3], ff, d, scale_out),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], d, ff * cfg.n_shared_experts,
                                      cfg.mlp_type, dtype)
    return p


def _expert_mlp(xg, wg, wu, wd, mlp_type):
    if mlp_type == "swiglu":
        h = jax.nn.silu(jnp.dot(xg, wg, preferred_element_type=jnp.float32))
        h = h * jnp.dot(xg, wu, preferred_element_type=jnp.float32)
    else:
        h = jax.nn.gelu(jnp.dot(xg, wu, preferred_element_type=jnp.float32))
    return jnp.dot(h.astype(xg.dtype), wd, preferred_element_type=jnp.float32)


def moe_block(params, x: jnp.ndarray, rt: Runtime) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) replicated across model axis. Returns (out, aux_loss)."""
    cfg = rt.cfg
    tp = rt.mesh.tp
    e_loc, tp_inner = moe_layout(cfg, tp)
    B, S, D = x.shape
    x_pre_f = x
    x = layers.tp_grad_sum(x, rt, tp > 1)
    T = B * S
    xt = x.reshape(T, D)

    # --- Routing (replicated; fp32) -----------------------------------
    logits = jnp.dot(xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_e = lax.top_k(probs, cfg.n_experts_per_tok)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Load-balance auxiliary loss (Switch): E · Σ_e f_e · P_e
    dispatch_mask = jax.nn.one_hot(top_e, cfg.n_experts, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(dispatch_mask, axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    # Full VALUE on every rank (loss parity across tp); 1/tp on the GRADIENT
    # because this path is computed identically on all ranks while grads are
    # summed over the model axis at sync time.
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    if tp > 1:
        aux = layers.scale_grad(aux, 1.0 / tp)

    # Per-token gate weight for every expert (0 if not selected).
    gates = jnp.sum(dispatch_mask * top_p[..., None], axis=1)    # (T, E)

    # --- Local experts -------------------------------------------------
    cap = int(cfg.capacity_factor * T * cfg.n_experts_per_tok / cfg.n_experts)
    cap = min(T, max(8, cap))   # never more than the tokens we have (decode)
    shard = lax.axis_index(rt.mesh.axis_model) if tp > 1 else 0
    # Device `shard` owns slice index `shard`: experts
    # [shard // tp_inner * e_loc ... ] — with the flattened layout, local
    # expert j has global id (shard // tp_inner) * e_loc + j.
    first_expert = (shard // tp_inner) * e_loc

    wg = params["w_gate"][0] if tp == 1 else params["w_gate"].reshape(
        e_loc, D, -1)
    wu = params["w_up"][0] if tp == 1 else params["w_up"].reshape(e_loc, D, -1)
    wd = params["w_down"][0] if tp == 1 else params["w_down"].reshape(
        e_loc, -1, D)

    out = jnp.zeros((T, D), jnp.float32)
    for j in range(e_loc):
        e_id = first_expert + j
        g_e = jnp.take_along_axis(
            gates, jnp.broadcast_to(e_id, (T,))[:, None], axis=1)[:, 0] \
            if tp > 1 else gates[:, j]
        # Capacity-bounded gather of this expert's tokens.
        sel_g, sel_idx = lax.top_k(g_e, cap)
        keep = sel_g > 0
        xg = jnp.take(xt, sel_idx, axis=0)
        y = _expert_mlp(xg, wg[j], wu[j], wd[j], cfg.mlp_type)
        y = y * (sel_g * keep)[:, None]
        out = out.at[sel_idx].add(jnp.where(keep[:, None], y, 0.0))

    if tp > 1:
        out = collectives.all_reduce(out, rt.tp_comm(), rt.comm)
        # tp_inner shards of one expert both gathered the same tokens and the
        # all-reduce sums their ff-halves — EP-combine and TP-combine in one op.

    y = out.astype(x.dtype).reshape(B, S, D)
    if cfg.n_shared_experts:
        # NOTE: pass the PRE-f input — layers.mlp applies its own f operator;
        # stacking two would double-psum the shared-expert cotangent.
        ff_sh = (cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts
        y = y + layers.mlp(params["shared"], x_pre_f, rt, cfg.mlp_type,
                           sharded=ff_sh % tp == 0 and tp > 1)
    return y, aux.astype(jnp.float32)


def moe_block_a2a(params, x_shard: jnp.ndarray, rt: Runtime
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All-to-all dispatch variant (EP over the *data* axis; tokens travel).

    x_shard: (T_loc, D) — this data-rank's tokens.  Tokens are bucketed per
    destination expert-owner, exchanged with ``all_to_all``, processed by the
    local experts, and returned.  This surfaces the MoE a2a in the HLO for the
    collective roofline; used by the perf experiments.
    """
    cfg = rt.cfg
    dp = rt.mesh.dp
    comm = rt.dp_comm()
    assert cfg.n_experts % dp == 0, "a2a variant needs n_experts % dp == 0"
    e_loc = cfg.n_experts // dp
    T, D = x_shard.shape

    logits = jnp.dot(x_shard.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, cfg.n_experts_per_tok)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    dispatch_mask = jax.nn.one_hot(top_e, cfg.n_experts, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(dispatch_mask, axis=1), axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * jnp.mean(probs, axis=0))
    gates = jnp.sum(dispatch_mask * top_p[..., None], axis=1)

    cap = max(8, int(cfg.capacity_factor * T * cfg.n_experts_per_tok
                     / cfg.n_experts))
    # Bucket per destination rank: (dp, e_loc·cap, D)
    send = jnp.zeros((dp, e_loc * cap, D), x_shard.dtype)
    send_gate = jnp.zeros((dp, e_loc * cap), jnp.float32)
    send_idx = jnp.zeros((dp, e_loc * cap), jnp.int32)
    for e in range(cfg.n_experts):
        owner, slot = e // e_loc, e % e_loc
        g_e = gates[:, e]
        sel_g, sel_i = lax.top_k(g_e, cap)
        xg = jnp.take(x_shard, sel_i, axis=0)
        send = lax.dynamic_update_slice(send, xg[None], (owner, slot * cap, 0))
        send_gate = lax.dynamic_update_slice(send_gate, sel_g[None],
                                             (owner, slot * cap))
        send_idx = lax.dynamic_update_slice(send_idx, sel_i[None],
                                            (owner, slot * cap))

    # Dispatch: overlapped scheduling tiles this into independent wire
    # chunks along D (chunk-level overlap); fused issues one all-to-all.
    recv = collectives.all_to_all(send, comm, rt.comm)          # (dp, e_loc·cap, D)
    wg = params["w_gate"].reshape(-1, D, params["w_gate"].shape[-1])
    wu = params["w_up"].reshape(-1, D, params["w_up"].shape[-1])
    wd = params["w_down"].reshape(-1, params["w_down"].shape[-2], D)
    ys = []
    for j in range(e_loc):
        xg = recv[:, j * cap:(j + 1) * cap].reshape(-1, D)
        y = _expert_mlp(xg, wg[j], wu[j], wd[j], cfg.mlp_type)
        ys.append(y.reshape(dp, cap, D))
    y_out = jnp.concatenate(ys, axis=1)                         # (dp, e_loc·cap, D)
    # Combine: same chunked-overlap routing as the dispatch.
    back = collectives.all_to_all(y_out.astype(x_shard.dtype), comm, rt.comm)

    out = jnp.zeros((T, D), jnp.float32)
    for r in range(dp):
        for j in range(e_loc):
            seg = back[r, j * cap:(j + 1) * cap].astype(jnp.float32)
            g = lax.dynamic_slice(send_gate, (r, j * cap), (1, cap))[0]
            i = lax.dynamic_slice(send_idx, (r, j * cap), (1, cap))[0]
            out = out.at[i].add(seg * jnp.where(g > 0, g, 0.0)[:, None])
    return out.astype(x_shard.dtype), aux
