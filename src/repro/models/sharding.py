"""Parameter sharding rules — single source of truth.

``param_specs`` builds the PartitionSpec tree used to place parameters on the
mesh (also the shard_map in_specs).  ``build_fsdp_plan`` precomputes, from the
*full* stored shapes, which dimension of each layer-stack weight carries an
extra ``data``-axis factor; ``apply_fsdp`` is the in-scan companion that
all-gathers those dims back to full at use time (one layer materialized at a
time — ZeRO-3 style, the per-layer all-gather XLA overlaps with the previous
layer's compute).  All three share ``_base_spec`` so placement and gathering
cannot disagree.

TP rules (model axis):
  embed.table        (V, D)         -> P('model', None)        vocab-sharded
  attn wq            (D, Heff*hd)   -> P(None, 'model')        col-parallel
  attn wk/wv         (D, KV*hd)     -> P(None, 'model') if kv_sharded else repl.
  attn wo            (Heff*hd, D)   -> P('model', None)        row-parallel
  mlp w_up/w_gate    (D, F)         -> P(None, 'model')
  mlp w_down         (F, D)         -> P('model', None)
  moe w_*            (tp, E, D, F)  -> P('model', …)           flattened EP
  mla w_uq/w_uk/w_uv (r, H*dh)      -> P(None, 'model')
  mla wo             (H*vd, D)      -> P('model', None)
  ssm w_z/w_x/conv_x (D|W, DI)      -> P(None, 'model') if heads shardable
  ssm w_out          (DI, D)        -> P('model', None) if heads shardable
  norms/scales/bias                 -> replicated
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core.communicator import Communicator
from repro.models import attention, ssm
from repro.models.common import ModelConfig, MeshContext, Runtime

_STACK_KEYS = ("layers", "blocks", "groups", "trailing", "encoder",
               "dense_layers")
_MIN_FSDP_SHARD = 8   # don't data-shard below this many rows per device


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def _n_stack_dims(names: list[str]) -> int:
    n = 0
    if any(k in names for k in _STACK_KEYS):
        n = 1
        if "blocks" in names and "local" in names:
            n = 2
        if "groups" in names and "ssm" in names:
            n = 2
    return n


def _base_spec(names: list[str], cfg: ModelConfig, tp: int):
    """TP spec entries for the unstacked (body) dims, or None = replicated."""
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    dims = attention.attn_dims(cfg, tp)
    _, ssm_sharded = ssm.ssm_dims(cfg, tp)
    mlp_shardable = bool(cfg.d_ff) and cfg.d_ff % tp == 0 and tp > 1

    if leaf == "table":
        return ("model", None) if tp > 1 and cfg.vocab_size % tp == 0 \
            else (None, None)
    if leaf == "router":
        return (None, None)
    if parent == "moe" and leaf in ("w_gate", "w_up", "w_down"):
        return ("model", None, None, None) if tp > 1 else (None,) * 4
    if leaf == "wq":
        return (None, "model") if dims.q_sharded else (None, None)
    if leaf in ("wk", "wv"):
        return (None, "model") if dims.kv_sharded else (None, None)
    if leaf == "wo":
        if cfg.use_mla:
            return ("model", None) if tp > 1 else (None, None)
        return ("model", None) if dims.q_sharded else (None, None)
    if leaf in ("w_uq", "w_uk", "w_uv"):
        return (None, "model") if tp > 1 else (None, None)
    if leaf in ("w_dq", "w_dkv", "w_kr"):
        return (None, None)
    if leaf in ("w_up", "w_gate"):
        return (None, "model") if mlp_shardable else (None, None)
    if leaf == "w_down":
        return ("model", None) if mlp_shardable else (None, None)
    if leaf in ("w_z", "w_x", "conv_x"):
        return (None, "model") if ssm_sharded else (None, None)
    if leaf == "w_out":
        return ("model", None) if ssm_sharded else (None, None)
    if leaf in ("w_B", "w_C", "w_dt", "proj_in", "frontend"):
        return (None, None)
    return None  # norms, scales, A_log, D, dt_bias, …


def _fsdp_dim(base, body_shape, tp: int, dp: int):
    """First body dim that can take a 'data' factor; -1 if none."""
    if len(body_shape) < 2 or dp <= 1:
        return -1
    entries = list(base) if base is not None else [None] * len(body_shape)
    for j, dim in enumerate(body_shape):
        local = dim // tp if entries[j] == "model" else dim
        if entries[j] not in (None, "model"):
            continue
        if local % dp == 0 and local // dp >= _MIN_FSDP_SHARD:
            return j
    return -1


def param_specs(params: Any, cfg: ModelConfig, mesh: MeshContext,
                fsdp: bool = False):
    tp = mesh.model_size
    dp = mesh.data_sizes[-1] if mesh.data_sizes else 1

    def spec_of(path, leaf):
        names = _path_names(path)
        n_stack = _n_stack_dims(names)
        base = _base_spec(names, cfg, tp)
        body = list(base) if base is not None else [None] * (leaf.ndim - n_stack)
        if fsdp and n_stack > 0:
            j = _fsdp_dim(base, leaf.shape[n_stack:], tp, dp)
            if j >= 0:
                body[j] = ("model", "data") if body[j] == "model" else "data"
        return P(*((None,) * n_stack + tuple(body)))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def build_fsdp_plan(params: Any, cfg: ModelConfig, mesh: MeshContext):
    """Pytree of int codes matching ``params``: -1 = no gather, else
    gather_dim*100 + body_ndim.  gather_dim is in *body* coordinates (stack
    dims stripped); apply_fsdp offsets by leftover leading dims at use."""
    tp = mesh.model_size
    dp = mesh.data_sizes[-1] if mesh.data_sizes else 1

    def plan_of(path, leaf):
        names = _path_names(path)
        n_stack = _n_stack_dims(names)
        if n_stack == 0:
            return -1
        base = _base_spec(names, cfg, tp)
        body_shape = leaf.shape[n_stack:]
        j = _fsdp_dim(base, body_shape, tp, dp)
        return j * 100 + len(body_shape) if j >= 0 else -1

    return jax.tree_util.tree_map_with_path(plan_of, params)


def subplan(plan, key: str):
    return None if plan is None else plan.get(key)


def apply_fsdp(layer_params: Any, plan: Any, rt: Runtime):
    """All-gather 'data'-factored weight dims inside a layer scan body."""
    if plan is None or rt.mesh.data_sizes[-1] == 1:
        return layer_params
    data_axis = rt.mesh.data_axes[-1]
    comm = Communicator((data_axis,), (rt.mesh.data_sizes[-1],))

    def fix(leaf, code):
        if code < 0:
            return leaf
        j, body_ndim = divmod(code, 100)
        extra = leaf.ndim - body_ndim   # leftover stack dims at this site
        return collectives.all_gather(leaf, comm, rt.comm, axis=j + extra,
                                      tiled=True)

    return jax.tree.map(fix, layer_params, plan)


def grad_model_sum_mask(params: Any, cfg: ModelConfig, tp: int,
                        seq_parallel: bool = False):
    """1 where the gradient must be SUMMED over the model axis at sync time.

    These are params stored replicated but *used* shardwise (each TP rank
    back-propagates only the slice it consumed): replicated-KV weights under
    head-sharded attention, MLA down-projections, sliced SSM scalars, and the
    MoE router.  Everything else is either storage-sharded (grads local) or
    replicated-identical (grads equal on every rank).
    """
    dims = attention.attn_dims(cfg, tp)
    _, ssm_sharded = ssm.ssm_dims(cfg, tp)
    # Under Megatron-SP the per-block layernorms run on seq SHARDS: their
    # grads are token-partial and must be summed over the model axis.
    sp_active = (seq_parallel and tp > 1 and dims.q_sharded
                 and cfg.family in ("dense", "vlm")
                 and not cfg.local_global_ratio)

    def mask_of(path, leaf):
        if tp == 1:
            return 0
        names = _path_names(path)
        leaf_name = names[-1]
        parent = names[-2] if len(names) >= 2 else ""
        if sp_active and leaf_name in ("ln1", "ln2") and "layers" in names:
            return 1
        if cfg.use_mla and leaf_name in ("w_dq", "w_dkv", "w_kr", "q_norm",
                                         "kv_norm"):
            return 1
        if not cfg.use_mla and leaf_name in ("q_norm", "k_norm")                 and dims.q_sharded:
            return 1
        if leaf_name in ("wk", "wv") and dims.q_sharded and not dims.kv_sharded:
            return 1
        if ssm_sharded and parent == "ssm" and leaf_name in (
                "w_B", "w_C", "w_dt", "A_log", "D", "dt_bias", "norm"):
            return 1
        if leaf_name == "router":
            return 1
        return 0

    return jax.tree_util.tree_map_with_path(mask_of, params)


def model_sharded_mask(pspec_tree):
    """1 where the param (hence its grad) is sharded over the model axis.

    Used for the global grad-norm: model-sharded leaves hold disjoint grad
    shards (sum their ||.||^2 over the model axis); replicated leaves hold
    identical grads (count once).
    """
    def of(spec):
        for e in spec:
            if e == "model" or (isinstance(e, tuple) and "model" in e):
                return 1
        return 0
    return jax.tree.map(of, pspec_tree)
