"""Attention — GQA / sliding-window / local-global, with TP + SP decode.

Parallel layouts (manual SPMD inside shard_map):

- **Training / prefill**: Q heads sharded over the ``model`` axis when
  divisible (KV weights replicated when ``n_kv_heads % tp != 0`` — the
  standard KV-replication of GQA under wide TP); otherwise the whole attention
  computes replicated (tiny-head archs, e.g. gemma3's 4 heads on tp=16 — the
  FLOP waste shows up in the roofline's MODEL/HLO ratio and is a hillclimb
  lever).
- **Decode**: the KV cache is sharded over the ``model`` axis along the
  *sequence* dimension (sequence-parallel decode).  Every device attends its
  slice of the timeline for *all* heads and the partial results are combined
  with a log-sum-exp reduction — two small ACCL-X all-reduces (max + sum).
  This is uniform over every kv-head count and is what makes ``long_500k``
  decode feasible: 512 K tokens of KV split 16 ways.

The jnp path below is the reference; ``rt.use_pallas=True`` routes the core
attention to the Pallas flash kernel (``repro.kernels.flash_attention``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives
from repro.models import layers
from repro.models.common import ModelConfig, Runtime


class AttnDims(NamedTuple):
    n_heads: int          # effective (possibly zero-padded) q heads
    n_real_heads: int     # q heads carrying real weights
    n_kv: int             # global kv heads
    head_dim: int
    q_sharded: bool       # q heads sharded over tp
    kv_sharded: bool      # kv heads sharded over tp
    local_heads: int      # q heads computed on this device
    local_kv: int         # kv heads computed on this device


def attn_dims(cfg: ModelConfig, tp: int) -> AttnDims:
    """Resolve the TP layout for attention heads.

    When n_heads % tp != 0 and shard_attn='auto', q heads are padded to the
    next tp multiple with zero-weight heads (wo rows are zero, so padded heads
    contribute exactly nothing) provided the padded grouping stays GQA-valid.
    """
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    h_eff = cfg.padded_heads or H   # config-level: same grouping at every tp
    kv_sharded = KV > 0 and KV % tp == 0 and tp > 1
    if tp == 1 or KV == 0:
        return AttnDims(h_eff, H, KV, hd, False, False, h_eff, KV)
    if h_eff % tp == 0 and cfg.shard_attn != "replicate":
        local = h_eff // tp
        group = h_eff // KV
        if h_eff % KV == 0 and (group % local == 0 or local % group == 0):
            return AttnDims(h_eff, H, KV, hd, True, kv_sharded, local,
                            KV // tp if kv_sharded else KV)
    # Fallback: replicated attention compute on every tp rank.
    return AttnDims(h_eff, H, KV, hd, False, False, h_eff, KV)


def init_attention(key, cfg: ModelConfig, dtype, tp: int = 1):
    """Full (unsharded) parameter arrays; the launcher shards them.

    Zero-padded head columns/rows are part of the stored arrays so that the
    global weight shape divides the tp axis.
    """
    hd = cfg.resolved_head_dim
    dims = attn_dims(cfg, tp)
    ks = jax.random.split(key, 4)
    wq = layers.dense_init(ks[0], cfg.d_model, dims.n_real_heads * hd, dtype)
    wo = layers.dense_init(ks[3], dims.n_real_heads * hd, cfg.d_model, dtype)
    pad = (dims.n_heads - dims.n_real_heads) * hd
    if pad:
        wq = jnp.concatenate([wq, jnp.zeros((cfg.d_model, pad), dtype)], axis=1)
        wo = jnp.concatenate([wo, jnp.zeros((pad, cfg.d_model), dtype)], axis=0)
    p = {
        "wq": wq,
        "wk": layers.dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": layers.dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _mask(q_len: int, kv_len: int, q_offset, causal: bool,
          window: Optional[int]) -> jnp.ndarray:
    """Additive mask (q_len, kv_len). q_offset = absolute pos of query 0."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((q_len, kv_len), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


_DENSE_SDPA_MAX_T = 4096   # above this, use the tiled (flash-style) path
_TILE_Q = 1024
_TILE_K = 1024


def _tile_scores(q_tile, k_tile, q0, k0, causal, window, softcap, v_dim):
    """q_tile: (B,Lq,KV,rep,hd) f32; k_tile: (B,Lk,KV,hd) f32.
    Returns masked scores (B,KV,rep,Lq,Lk)."""
    hd = q_tile.shape[-1]
    s = jnp.einsum("bsgrd,btgd->bgrst", q_tile, k_tile) / (hd ** 0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q0 + jnp.arange(q_tile.shape[1])[:, None]
    k_pos = k0 + jnp.arange(k_tile.shape[1])[None, :]
    ok = jnp.ones(q_pos.shape[:1] + k_pos.shape[1:], bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (k_pos > q_pos - window)
    return jnp.where(ok[None, None, None], s, -jnp.inf)


def _sdpa_dense(q, k, v, softcap, causal, window):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, S, KV, rep, hd).astype(jnp.float32)
    s = _tile_scores(qg, k.astype(jnp.float32), 0, 0, causal, window, softcap,
                     v.shape[-1])
    probs = jax.nn.softmax(s, axis=-1)
    probs = jnp.where(jnp.isfinite(s), probs, 0.0)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd if v.shape[-1] == hd else v.shape[-1]
                       ).astype(q.dtype)


def _sdpa_tiled(q, k, v, softcap, causal, window, trimmed: bool):
    """Flash-style two-level tiling in pure jnp.

    Outer loop over query tiles; inner ``fori_loop`` over KV tiles with a
    running (m, l, acc) online softmax.  ``trimmed=True`` statically skips KV
    tiles that are fully masked (causal future / outside the sliding window) —
    the FLOP-trimming optimization of the perf log; ``False`` visits every
    tile and masks (baseline).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    vd = v.shape[-1]
    # Pad KV time to a tile multiple so dynamic_slice never clamps.
    t_pad = (-T) % _TILE_K
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if t_pad:
        kf = jnp.pad(kf, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    n_q = -(-S // _TILE_Q)
    outs = []
    for qi in range(n_q):
        q0 = qi * _TILE_Q
        lq = min(S, q0 + _TILE_Q) - q0
        qt = q[:, q0:q0 + lq].reshape(B, lq, KV, rep, hd).astype(jnp.float32)
        # Static KV range for this query tile.
        hi = min(T, q0 + lq) if (causal and trimmed) else T
        lo = 0
        if window is not None and trimmed:
            lo = max(0, q0 - window + 1) // _TILE_K * _TILE_K
        n_k = -(-(hi - lo) // _TILE_K)

        def kv_step(i, carry, q0=q0, lq=lq, qt=qt, lo=lo):
            m, l, acc = carry
            k0 = lo + i * _TILE_K
            kt = lax.dynamic_slice_in_dim(kf, k0, _TILE_K, axis=1)
            vt = lax.dynamic_slice_in_dim(vf, k0, _TILE_K, axis=1)
            s = _tile_scores(qt, kt, q0, k0, causal, window, softcap, vd)
            # mask out-of-range kv positions (tail tile)
            k_pos = k0 + jnp.arange(_TILE_K)
            s = jnp.where((k_pos < T)[None, None, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.exp(m - m_new)
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrst,btgd->bgrsd", p, vt)
            acc = acc * corr[..., None] + pv
            return m_new, l, acc

        m0 = jnp.full((B, KV, rep, lq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, lq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, lq, vd), jnp.float32)
        if n_k <= 0:
            m_f, l_f, acc = m0, l0, a0
        else:
            m_f, l_f, acc = lax.fori_loop(0, n_k, lambda i, c: kv_step(i, c),
                                          (m0, l0, a0))
        o = acc / jnp.maximum(l_f[..., None], 1e-30)
        o = jnp.moveaxis(o, 3, 1).reshape(B, lq, H, vd)
        outs.append(o)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _sdpa(q, k, v, mask, softcap: Optional[float], rt: Runtime,
          causal: bool, window: Optional[int]):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd_v) -> (B,S,H,hd_v).  fp32 softmax.

    Dispatch: Pallas flash kernel (TPU) > dense einsum (short seq) > tiled
    flash-style jnp (long seq; `attn_tiling`='trimmed' statically skips
    fully-masked tiles).
    """
    del mask  # positions are reconstructed inside the tile helpers
    T = k.shape[1]
    if rt.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      softcap=softcap)
    tiling = getattr(rt, "attn_tiling", "auto")
    if (tiling == "dense") or (tiling == "auto" and T <= _DENSE_SDPA_MAX_T):
        return _sdpa_dense(q, k, v, softcap, causal, window)
    return _sdpa_tiled(q, k, v, softcap, causal, window,
                       trimmed=(tiling == "trimmed"))


def attention(params, x: jnp.ndarray, positions: jnp.ndarray, rt: Runtime,
              window: Optional[int] = None, causal: Optional[bool] = None,
              kv_override: Optional[tuple] = None, return_kv: bool = False,
              sp: bool = False):
    """Full self-attention (training / prefill). x: (B,S,D) replicated.

    ``kv_override`` = (k, v, kv_positions) for cross-attention.
    ``return_kv`` additionally returns post-rope full-head (B,S,KV,hd) k/v
    for cache construction at prefill (all-gathered if kv was TP-sharded).
    Returns (B,S,D) replicated (row-parallel combine via ACCL-X).
    """
    cfg, mesh = rt.cfg, rt.mesh
    dims = attn_dims(cfg, mesh.tp)
    causal = cfg.causal if causal is None else causal
    B, S, D = x.shape
    hd = dims.head_dim

    if sp and dims.q_sharded:
        # Megatron-SP: x arrives seq-sharded; the all-gather's transpose
        # performs the f-operator's cotangent sum.
        x = layers.sp_all_gather(x, rt)
        B, S, D = x.shape
    else:
        x = layers.tp_grad_sum(x, rt, dims.q_sharded)
    q = layers.col_parallel(x, params["wq"]).reshape(B, S, -1, hd)
    if kv_override is None:
        k = layers.col_parallel(x, params["wk"]).reshape(B, S, -1, hd)
        v = layers.col_parallel(x, params["wv"]).reshape(B, S, -1, hd)
        kv_positions = positions
    else:
        k, v, kv_positions = kv_override

    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    if kv_override is None:
        k = layers.apply_rope(k, kv_positions, cfg.rope_theta)

    kv_full = None
    if return_kv:
        if dims.kv_sharded:
            kv_full = (collectives.all_gather(k, rt.tp_comm(), rt.comm, axis=2),
                       collectives.all_gather(v, rt.tp_comm(), rt.comm, axis=2))
        else:
            kv_full = (k, v)

    if dims.q_sharded and not dims.kv_sharded:
        # KV computed replicated; slice the kv heads this device's q group needs.
        group = dims.n_heads // dims.n_kv
        shard = lax.axis_index(mesh.axis_model)
        n_need = max(1, dims.local_heads // group)
        start = (shard * dims.local_heads) // group
        k = lax.dynamic_slice_in_dim(k, start, n_need, axis=2)
        v = lax.dynamic_slice_in_dim(v, start, n_need, axis=2)

    out = _sdpa(q, k, v, None, cfg.attn_logit_softcap, rt, causal, window)
    if dims.n_heads != dims.n_real_heads:
        # Zero the zero-weight padded heads' outputs: keeps wo pad rows at
        # exactly zero gradient (identity math at any tp).
        if dims.q_sharded:
            shard = lax.axis_index(mesh.axis_model)
            gidx = shard * dims.local_heads + jnp.arange(dims.local_heads)
        else:
            gidx = jnp.arange(dims.local_heads)
        out = out * (gidx < dims.n_real_heads)[None, None, :, None]
    out = out.reshape(B, S, -1)
    if dims.q_sharded:
        if sp:
            partial = jnp.dot(out, params["wo"],
                              preferred_element_type=jnp.float32)
            y = layers.sp_reduce_scatter(partial, rt).astype(x.dtype)
        else:
            y = layers.row_parallel(out, params["wo"], rt)
    else:
        # Replicated attention: wo applied fully on every device, no combine.
        y = jnp.dot(out, params["wo"], preferred_element_type=jnp.float32
                    ).astype(x.dtype)
    if return_kv:
        return y, kv_full
    return y


# ----------------------------------------------------------------------
# Decode with sequence-sharded KV cache (SP decode + LSE combine)
# ----------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_shard, KV, hd) — this device's slice of time
    v: jnp.ndarray
    length: jnp.ndarray   # () int32 — global tokens already in cache

    @property
    def seq_shard(self) -> int:
        return self.k.shape[1]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_shards: int,
                  dtype) -> KVCache:
    shard_len = max(1, -(-max_len // n_shards))
    hd = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, shard_len, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, shard_len, cfg.n_kv_heads, hd), dtype),
        length=jnp.zeros((), jnp.int32))


def prefill_into_cache(cache: KVCache, k_full: jnp.ndarray, v_full: jnp.ndarray,
                       rt: Runtime) -> KVCache:
    """Scatter full-sequence K/V (replicated) into the seq-sharded cache."""
    sp = rt.sp_comm()
    shard = sp.rank() if rt.sp_size > 1 else 0
    S = k_full.shape[1]
    L = cache.seq_shard
    start = shard * L
    # static-shape path: pad k_full to n_shards*L then slice
    pad = rt.sp_size * L - S
    if pad > 0:
        k_full = jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_full = jnp.pad(v_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_slice = lax.dynamic_slice_in_dim(k_full, start, L, axis=1)
    v_slice = lax.dynamic_slice_in_dim(v_full, start, L, axis=1)
    return KVCache(k=k_slice.astype(cache.k.dtype),
                   v=v_slice.astype(cache.v.dtype),
                   length=jnp.asarray(S, jnp.int32))


def append_to_cache(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                    rt: Runtime) -> KVCache:
    """Write one new (B,1,KV,hd) entry at global position cache.length."""
    shard = rt.sp_comm().rank() if rt.sp_size > 1 else 0
    L = cache.seq_shard
    owner = cache.length // L
    off = cache.length % L
    k_upd = lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            off, axis=1)
    v_upd = lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            off, axis=1)
    mine = owner == shard
    return KVCache(k=jnp.where(mine, k_upd, cache.k),
                   v=jnp.where(mine, v_upd, cache.v),
                   length=cache.length + 1)


def decode_attention(params, x: jnp.ndarray, cache: KVCache, rt: Runtime,
                     window: Optional[int] = None, append: bool = True,
                     q_pos=None) -> tuple[jnp.ndarray, KVCache]:
    """One decode step. x: (B,1,D) replicated. Returns (B,1,D), new cache.

    All projections are computed replicated (decode is memory-bound; the q/kv
    matmuls are tiny), attention runs over each device's sequence shard, and
    partials combine with the LSE trick: two ACCL-X all-reduces.

    ``append=False`` attends a frozen cache (cross-attention); ``q_pos``
    overrides the query's rope position (defaults to cache.length).
    """
    cfg, mesh = rt.cfg, rt.mesh
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    dims = attn_dims(cfg, mesh.tp)

    # Replicated projections: full q/k/v on every device (all heads).
    if dims.q_sharded:
        q_loc = layers.col_parallel(x, params["wq"]).reshape(B, 1, dims.local_heads, hd)
        q = collectives.all_gather(q_loc, rt.tp_comm(), rt.comm, axis=2)
    else:
        q = jnp.dot(x, params["wq"], preferred_element_type=jnp.float32
                    ).astype(x.dtype).reshape(B, 1, dims.n_heads, hd)
    if dims.kv_sharded:
        k_loc = layers.col_parallel(x, params["wk"]).reshape(B, 1, dims.local_kv, hd)
        v_loc = layers.col_parallel(x, params["wv"]).reshape(B, 1, dims.local_kv, hd)
        k_new = collectives.all_gather(k_loc, rt.tp_comm(), rt.comm, axis=2)
        v_new = collectives.all_gather(v_loc, rt.tp_comm(), rt.comm, axis=2)
    else:
        k_new = jnp.dot(x, params["wk"], preferred_element_type=jnp.float32
                        ).astype(x.dtype).reshape(B, 1, dims.n_kv, hd)
        v_new = jnp.dot(x, params["wv"], preferred_element_type=jnp.float32
                        ).astype(x.dtype).reshape(B, 1, dims.n_kv, hd)

    pos = cache.length[None] if q_pos is None else jnp.asarray(q_pos)[None]
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = layers.rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, jnp.broadcast_to(pos[None], (B, 1)), cfg.rope_theta)
    if append:
        k_new = layers.apply_rope(
            k_new, jnp.broadcast_to(cache.length[None][None], (B, 1)),
            cfg.rope_theta)
        cache = append_to_cache(cache, k_new, v_new, rt)

    # Local attention over this device's slice of the timeline.
    tp = mesh.tp
    sp = rt.sp_size
    shard = rt.sp_comm().rank() if sp > 1 else 0
    L = cache.seq_shard
    k_pos = shard * L + jnp.arange(L)
    valid = k_pos < cache.length
    if window is not None:
        valid &= k_pos > cache.length - 1 - window
    bias = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)

    KV = dims.n_kv
    rep = dims.n_heads // KV
    qg = q.reshape(B, KV, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bgrd,btgd->bgrt", qg, cache.k.astype(jnp.float32))
    scores = scores / (hd ** 0.5) + bias[None, None, None, :]
    if cfg.attn_logit_softcap:
        scores = cfg.attn_logit_softcap * jnp.tanh(scores / cfg.attn_logit_softcap)
    m_loc = jnp.max(scores, axis=-1)                      # (B,KV,rep)
    if sp > 1:
        m = collectives.all_reduce(m_loc, rt.sp_comm(), rt.comm, op="max")
    else:
        m = m_loc
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    s_loc = jnp.sum(p, axis=-1)                           # (B,KV,rep)
    o_loc = jnp.einsum("bgrt,btgd->bgrd", p, cache.v.astype(jnp.float32))
    if sp > 1:
        # Fused LSE combine: softmax denominator and weighted values share
        # one sum all-reduce (psum of a concat == concat of psums, bitwise)
        # — decode pays two small ACCL-X combines per layer (max + sum),
        # not three, and the per-op dispatch cost is what dominates the
        # latency-bound decode phase.
        so = collectives.all_reduce(
            jnp.concatenate([s_loc[..., None], o_loc], axis=-1),
            rt.sp_comm(), rt.comm)
        s, o = so[..., 0], so[..., 1:]
    else:
        s, o = s_loc, o_loc
    out = o / jnp.maximum(s[..., None], 1e-30)
    out = out.reshape(B, 1, dims.n_heads, hd)
    if dims.n_heads != dims.n_real_heads:
        out = out * (jnp.arange(dims.n_heads) < dims.n_real_heads
                     )[None, None, :, None]
    out = out.reshape(B, 1, dims.n_heads * hd).astype(x.dtype)

    if dims.q_sharded:
        # Row-parallel output projection: slice my heads from the combined out.
        mshard = lax.axis_index(mesh.axis_model)
        start = mshard * dims.local_heads * hd
        out_loc = lax.dynamic_slice_in_dim(out, start, dims.local_heads * hd, axis=2)
        y = layers.row_parallel(out_loc, params["wo"], rt)
    else:
        y = jnp.dot(out, params["wo"], preferred_element_type=jnp.float32
                    ).astype(x.dtype)
    return y, cache
