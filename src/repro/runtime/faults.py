"""Deterministic fault injection + degradation monitoring for the elastic
runtime.

The paper's configurability claim inverts under failure: when a link degrades
or a rank dies, the *previously optimal* CommConfig is no longer optimal, so
fault handling must re-enter the tuning loop, not just restart.  This module
supplies the two halves the recovery path needs:

**Injection** — a :class:`FaultSchedule` is a seeded, reproducible list of
events (``DEGRADED_LINK(edge, slowdown)``, ``RANK_LOST(rank, step)``,
``STRAGGLER(rank, factor)``, ``PREEMPT(step)``); the :class:`FaultInjector`
fires them at step boundaries:

- degraded links land at the **wire layer**: the active slowdowns are folded
  into the :class:`~repro.core.topology.TorusSpec`
  (:meth:`FaultInjector.degrade_spec`), whose routed permutes then execute
  real extra hold rounds — measured latency grows, values stay bitwise
  identical;
- rank loss lands at the **driver layer**: :class:`RankLostError` unwinds the
  step loop and the driver re-forms on the survivors' sub-torus
  (``TorusSpec.shrink``) from the last checkpoint;
- stragglers land at the **host layer** as injected step-boundary delay —
  exactly what :class:`~repro.runtime.fault_tolerance.StepWatchdog` flags;
- preemptions set the :class:`~repro.runtime.fault_tolerance.
  PreemptionGuard` flag, driving the drain path.

The same seed replays the same schedule: kill-and-resume runs are
reproducible end to end, which is what lets tests assert bitwise-identical
result streams across two faulted runs.

**Monitoring** — the :class:`DegradationMonitor` is the decision consumer of
the obs substrate: per-edge latency samples (plus ``comm.edge_bytes{hops=}``
traffic deltas and ``watchdog.stragglers`` from the metrics registry) are
compared against a per-edge EWMA baseline; an edge whose samples exceed
``threshold x baseline`` for ``hysteresis`` *consecutive* observations is
confirmed degraded — the runtime then re-routes around it
(``TorusSpec.with_reroute``) and re-selects configs from the calibrated
model (:func:`repro.tune.elastic.model_reselect`).  A post-switch cooldown
and the consecutive-streak rule keep steady noise from flapping selection.
"""
from __future__ import annotations

import dataclasses
import json
import random
import time
from pathlib import Path
from typing import Callable, Optional, Sequence

from repro.obs import metrics as obs_metrics


# ----------------------------------------------------------------------
# Events
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradedLink:
    """Physical link ``edge`` runs ``slowdown``x slower from ``step`` on."""
    step: int
    edge: tuple[int, int]
    slowdown: float
    kind: str = dataclasses.field(default="degraded_link", repr=False)


@dataclasses.dataclass(frozen=True)
class RankLost:
    """Rank ``rank`` dies at the boundary before executing ``step``."""
    step: int
    rank: int
    kind: str = dataclasses.field(default="rank_lost", repr=False)


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Rank ``rank`` runs ``factor``x slower for ``duration`` steps."""
    step: int
    rank: int
    factor: float
    duration: int = 5
    kind: str = dataclasses.field(default="straggler", repr=False)


@dataclasses.dataclass(frozen=True)
class Preempt:
    """The scheduler preempts the job at ``step`` (SIGTERM-equivalent)."""
    step: int
    kind: str = dataclasses.field(default="preempt", repr=False)


@dataclasses.dataclass(frozen=True)
class ChunkLoss:
    """The wire starts losing chunks from ``step`` on — the step-level
    handle on :mod:`repro.core.reliable`'s chunk-granularity fault
    injection.  ``drop``/``dup``/``reorder`` are per-transmission
    probabilities; outcomes are drawn deterministically from the schedule
    seed, so two runs under the same schedule fault identically."""
    step: int
    drop: float
    dup: float = 0.0
    reorder: float = 0.0
    kind: str = dataclasses.field(default="chunk_loss", repr=False)


_KINDS = {"degraded_link": DegradedLink, "rank_lost": RankLost,
          "straggler": Straggler, "preempt": Preempt,
          "chunk_loss": ChunkLoss}


class RankLostError(RuntimeError):
    """Raised by the injector when a rank dies; carries (rank, step) so the
    recovery path knows who to exclude and where to resume."""

    def __init__(self, rank: int, step: int):
        super().__init__(f"rank {rank} lost at step {step}")
        self.rank = rank
        self.step = step


# ----------------------------------------------------------------------
# Schedule
# ----------------------------------------------------------------------

SCHEDULE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An ordered, reproducible list of fault events.

    Three ways in: :meth:`generate` (seeded random schedule),
    :meth:`parse` (the compact CLI spelling, e.g.
    ``"degraded_link@5=0-1x3.0;rank_lost@10=r5;straggler@7=r2x4.0;
    preempt@30"``), or :meth:`from_json`/:meth:`load` (the persisted form —
    what the CI smoke passes to ``python -m repro.runtime.elastic``).
    """
    events: tuple = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.step, e.kind))))

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def at(self, step: int) -> list:
        return [e for e in self.events if e.step == step]

    def through(self, step: int) -> list:
        return [e for e in self.events if e.step <= step]

    # -- seeded generation ---------------------------------------------
    @classmethod
    def generate(cls, seed: int, n_steps: int, spec=None,
                 n_ranks: Optional[int] = None,
                 degraded_links: int = 1, rank_losses: int = 0,
                 stragglers: int = 1, preempts: int = 0,
                 slowdown_range=(2.0, 4.0),
                 factor_range=(2.0, 6.0)) -> "FaultSchedule":
        """A reproducible random schedule: same (seed, args) -> same events.

        ``spec`` (a TorusSpec) supplies the physical links degradations can
        hit; without one, ring edges ``(i, i+1)`` over ``n_ranks`` are used.
        Events land in the middle 80% of the run so recovery has steps left
        to prove itself on.
        """
        rng = random.Random(seed)
        if spec is not None:
            n_ranks = spec.n_ranks
            links = [(spec.rank_at(a), spec.rank_at(b))
                     for a, b in _torus_links(spec.shape)]
        elif n_ranks:
            links = [(i, (i + 1) % n_ranks) for i in range(n_ranks)]
        else:
            raise ValueError("generate needs spec= or n_ranks=")
        lo, hi = max(1, n_steps // 10), max(2, (9 * n_steps) // 10)
        step = lambda: rng.randrange(lo, hi)
        events: list = []
        for _ in range(degraded_links):
            events.append(DegradedLink(step(), tuple(rng.choice(links)),
                                       round(rng.uniform(*slowdown_range), 2)))
        for _ in range(stragglers):
            events.append(Straggler(step(), rng.randrange(n_ranks),
                                    round(rng.uniform(*factor_range), 2)))
        for _ in range(rank_losses):
            events.append(RankLost(step(), rng.randrange(n_ranks)))
        for _ in range(preempts):
            events.append(Preempt(step()))
        return cls(events=tuple(events), seed=seed)

    # -- compact CLI spelling ------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "FaultSchedule":
        """``kind@step[=args]`` items joined by ``;``:

        - ``degraded_link@5=0-1x3.0``  (edge 0-1, 3x slower from step 5)
        - ``rank_lost@10=r5``          (rank 5 dies before step 10)
        - ``straggler@7=r2x4.0``       (rank 2 runs 4x slower from step 7)
        - ``preempt@30``
        - ``chunk_loss@5=0.05``        (wire drops 5% of chunks from step 5;
          optional ``d``/``r`` suffixes add duplicate/reorder rates, e.g.
          ``chunk_loss@5=0.05d0.02r0.1``)

        Malformed items — an unknown kind, a missing/negative step, a
        missing or trailing argument, a slowdown/straggler factor below 1,
        a self-loop edge, an out-of-range loss rate — and exact duplicate
        events all raise ``ValueError`` naming the offending item: a bad
        compact string must never silently drop or double-fire an event.
        """
        events: list = []
        seen: set = set()
        for item in filter(None, (s.strip() for s in text.split(";"))):
            head, _, arg = item.partition("=")
            kind, at, step_s = head.partition("@")
            try:
                if not at or not step_s:
                    raise ValueError("missing '@step'")
                step = int(step_s)
                if step < 0:
                    raise ValueError(f"step must be >= 0, got {step}")
                if kind == "degraded_link":
                    edge_s, x, slow_s = arg.partition("x")
                    a_s, dash, b_s = edge_s.partition("-")
                    if not (x and dash):
                        raise ValueError("expected A-BxSLOWDOWN")
                    a, b, slow = int(a_s), int(b_s), float(slow_s)
                    if a == b:
                        raise ValueError(f"edge {a}-{b} is a self-loop")
                    if slow < 1.0:
                        raise ValueError(
                            f"slowdown must be >= 1, got {slow}")
                    ev = DegradedLink(step, (a, b), slow)
                elif kind == "rank_lost":
                    ev = RankLost(step, _parse_rank(arg))
                elif kind == "straggler":
                    rank_s, x, fac_s = arg.partition("x")
                    if not x:
                        raise ValueError("expected rRANKxFACTOR")
                    fac = float(fac_s)
                    if fac < 1.0:
                        raise ValueError(f"factor must be >= 1, got {fac}")
                    ev = Straggler(step, _parse_rank(rank_s), fac)
                elif kind == "preempt":
                    if arg:
                        raise ValueError(
                            f"preempt takes no argument, got {arg!r}")
                    ev = Preempt(step)
                elif kind == "chunk_loss":
                    ev = ChunkLoss(step, **_parse_rates(arg))
                else:
                    raise ValueError(f"unknown fault kind {kind!r}")
            except (ValueError, TypeError) as e:
                raise ValueError(f"bad fault item {item!r}: {e}") from None
            if ev in seen:
                raise ValueError(f"duplicate fault item {item!r}: the event "
                                 f"would fire twice")
            seen.add(ev)
            events.append(ev)
        return cls(events=tuple(events))

    # -- persistence ----------------------------------------------------
    def to_json(self) -> str:
        evs = []
        for e in self.events:
            d = dataclasses.asdict(e)
            d["kind"] = e.kind
            evs.append(d)
        return json.dumps({"version": SCHEDULE_VERSION, "seed": self.seed,
                           "events": evs}, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        payload = json.loads(text)
        if payload.get("version") != SCHEDULE_VERSION:
            raise ValueError(f"unsupported fault schedule version "
                             f"{payload.get('version')!r}")
        events = []
        for d in payload.get("events", ()):
            d = dict(d)
            klass = _KINDS[d.pop("kind")]
            if "edge" in d:
                d["edge"] = tuple(d["edge"])
            events.append(klass(**d))
        return cls(events=tuple(events), seed=payload.get("seed"))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path) -> "FaultSchedule":
        return cls.from_json(Path(path).read_text())


def _parse_rank(arg: str) -> int:
    """``r5`` or ``5`` -> 5; anything else (``rr5``, ``r-1``, empty) raises."""
    s = arg[1:] if arg.startswith("r") else arg
    if not s.isdigit():
        raise ValueError(f"expected a rank like 'r5', got {arg!r}")
    return int(s)


def _parse_rates(arg: str) -> dict:
    """``0.05[d<dup>][r<reorder>]`` -> ChunkLoss rate kwargs."""
    out = {"drop": arg, "dup": "0", "reorder": "0"}
    rest = arg
    for key, mark in (("reorder", "r"), ("dup", "d")):
        head, sep, tail = rest.rpartition(mark)
        if sep:
            out[key] = tail
            rest = head
    out["drop"] = rest
    rates = {}
    for key, s in out.items():
        try:
            v = float(s)
        except ValueError:
            raise ValueError(f"bad {key} rate {s!r} in {arg!r}") from None
        if not 0.0 <= v < 1.0:
            raise ValueError(f"{key} rate must be in [0, 1), got {v}")
        rates[key] = v
    if not any(rates.values()):
        raise ValueError(f"chunk_loss needs a non-zero rate, got {arg!r}")
    return rates


def _torus_links(shape: tuple[int, int]) -> list[tuple[int, int]]:
    """All physical (cell, cell) single-hop links of an R x C torus."""
    rows, cols = shape
    links = set()
    for r in range(rows):
        for c in range(cols):
            cell = r * cols + c
            if cols > 1:
                right = r * cols + (c + 1) % cols
                links.add((min(cell, right), max(cell, right)))
            if rows > 1:
                down = ((r + 1) % rows) * cols + c
                links.add((min(cell, down), max(cell, down)))
    return sorted(links)


# ----------------------------------------------------------------------
# Injector
# ----------------------------------------------------------------------

class FaultInjector:
    """Fire a :class:`FaultSchedule` into a running step loop.

    The loop calls :meth:`poll` at every step boundary; the injector fires
    each event exactly once (events whose step was skipped over — e.g. a
    segment boundary every 10 steps — fire at the first boundary past
    them):

    - ``DegradedLink``  -> recorded in :attr:`active_slowdowns`; the caller
      rebuilds its wire plans via :meth:`degrade_spec` when :meth:`poll`
      returns a non-empty fired list.
    - ``Straggler``     -> host-side delay injected at the polled boundary
      (``sleep(base_step_s * (factor - 1))`` for the event's duration) —
      what the StepWatchdog measures and flags.
    - ``Preempt``       -> ``guard.request()`` (the software-triggered
      drain).
    - ``RankLost``      -> raises :class:`RankLostError` (after applying
      everything else due at the same boundary).
    """

    def __init__(self, schedule: FaultSchedule, base_step_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep):
        self.schedule = schedule
        self.base_step_s = base_step_s
        self._sleep = sleep
        self._fired: set[int] = set()       # indices into schedule.events
        self.active_slowdowns: dict[tuple[int, int], float] = {}
        self._stragglers: list[Straggler] = []
        self._chunk_loss: dict[str, float] = {}
        self.fired_events: list = []

    def poll(self, step: int, guard=None) -> list:
        """Fire everything due at or before ``step``; returns the newly
        fired events (empty most steps).  Raises :class:`RankLostError`
        last, so same-boundary degradations/preempts are not lost."""
        fired: list = []
        lost: Optional[RankLost] = None
        for i, ev in enumerate(self.schedule.events):
            if i in self._fired or ev.step > step:
                continue
            self._fired.add(i)
            fired.append(ev)
            self.fired_events.append(ev)
            reg = obs_metrics.registry()
            reg.counter("faults.injected", kind=ev.kind).inc()
            if isinstance(ev, DegradedLink):
                a, b = ev.edge
                key = (min(a, b), max(a, b))
                self.active_slowdowns[key] = max(
                    ev.slowdown, self.active_slowdowns.get(key, 1.0))
            elif isinstance(ev, Straggler):
                self._stragglers.append(ev)
            elif isinstance(ev, ChunkLoss):
                for key in ("drop", "dup", "reorder"):
                    self._chunk_loss[key] = max(
                        getattr(ev, key), self._chunk_loss.get(key, 0.0))
            elif isinstance(ev, Preempt):
                if guard is not None:
                    guard.request()
            elif isinstance(ev, RankLost):
                lost = ev
        delay = self.straggler_delay_s(step)
        if delay > 0.0:
            self._sleep(delay)
        if lost is not None:
            raise RankLostError(lost.rank, step)
        return fired

    def straggler_delay_s(self, step: int) -> float:
        """Extra host time this boundary owes to active stragglers."""
        extra = 0.0
        for s in self._stragglers:
            if s.step <= step < s.step + s.duration:
                extra = max(extra, self.base_step_s * (s.factor - 1.0))
        return extra

    def wire_faults(self):
        """The chunk-level :class:`repro.core.reliable.WireFaults` schedule
        the fired ``chunk_loss`` events imply (None while none has fired).
        The caller activates it with ``reliable.inject`` around its traced
        step — the wire-granularity extension of the step-level schedule.
        Seeded from the FaultSchedule seed, so outcomes replay exactly."""
        if not self._chunk_loss:
            return None
        from repro.core import reliable
        drop = self._chunk_loss.get("drop", 0.0)
        # A requested loss rate guarantees at least one observable loss:
        # the first transmission of the first message is pinned dropped, so
        # short traces (few messages on the wire) still exercise recovery
        # instead of depending on how early the seeded draws happen to hit.
        pinned = frozenset({(0, 0, 0)}) if drop > 0.0 else frozenset()
        return reliable.WireFaults(seed=self.schedule.seed or 0,
                                   drop=drop,
                                   dup=self._chunk_loss.get("dup", 0.0),
                                   reorder=self._chunk_loss.get("reorder", 0.0),
                                   drop_events=pinned)

    def degrade_spec(self, spec):
        """Fold the active link slowdowns into ``spec`` (a TorusSpec) —
        the wire-layer injection point.  Identity when nothing is active
        or there is no torus."""
        if spec is None or not self.active_slowdowns:
            return spec
        for (a, b), f in sorted(self.active_slowdowns.items()):
            spec = spec.with_link_slowdown(a, b, f)
        return spec

    def edge_latency_samples(self, step: int, edges: Sequence[tuple],
                             noise: float = 0.05) -> dict:
        """Synthetic per-edge latency telemetry (arbitrary units): 1.0 x
        the edge's active slowdown x seeded multiplicative noise.  This is
        the emulation stand-in for per-edge wire timing a real fabric
        exports; deterministic in (schedule seed, step, edge) so monitor
        tests replay exactly."""
        out = {}
        for a, b in edges:
            key = (min(int(a), int(b)), max(int(a), int(b)))
            # String seed: tuple seeds go through hash() and depend on
            # PYTHONHASHSEED — a fresh process would sample differently.
            rng = random.Random(f"{self.schedule.seed or 0}:{step}:{key}")
            base = self.active_slowdowns.get(key, 1.0)
            out[key] = base * (1.0 + rng.uniform(-noise, noise))
        return out


# ----------------------------------------------------------------------
# Degradation monitor
# ----------------------------------------------------------------------

class DegradationMonitor:
    """Hysteresis-gated detector of degraded-but-alive links.

    Feed it per-edge latency samples each step (:meth:`observe`); it keeps a
    per-edge EWMA baseline (updated only from samples it does NOT flag, so a
    degradation can't normalize itself into the baseline) and flags samples
    above ``threshold x baseline``.  An edge is **confirmed** — returned
    from :meth:`observe` exactly once per episode — only after ``hysteresis``
    consecutive flagged samples, and further confirmations for that edge are
    suppressed for ``cooldown`` steps after a switch: one noisy step never
    triggers re-selection, and steady noise never flaps it.

    It is also the obs substrate's decision consumer: :meth:`registry_deltas`
    reads ``comm.edge_bytes{hops=}`` and ``watchdog.stragglers`` deltas from
    the metrics registry since the last call.  :meth:`observe` skips streak
    updates when the registry shows no comm traffic since the last
    observation (``require_traffic=True``) — no evidence, no verdict — and
    exposes the straggler delta so a driver can couple watchdog pressure
    with edge flags.
    """

    def __init__(self, threshold: float = 1.5, hysteresis: int = 3,
                 cooldown: int = 20, alpha: float = 0.2,
                 retransmit_threshold: int = 0,
                 registry: Optional[obs_metrics.Registry] = None):
        if hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")
        self.threshold = threshold
        self.hysteresis = hysteresis
        self.cooldown = cooldown
        self.alpha = alpha
        # wire.retransmits deltas above this per observation count toward a
        # wire-degradation streak (0 = any retransmission is evidence).
        self.retransmit_threshold = retransmit_threshold
        self._reg = registry or obs_metrics.registry()
        self._baseline: dict[tuple, float] = {}
        self._streak: dict[tuple, int] = {}
        self._cooldown_until: dict[tuple, int] = {}
        self._last_counts: dict[str, float] = {}
        self.confirmed: set[tuple] = set()
        self.last_straggler_delta = 0
        self._wire_streak = 0
        self._wire_cooldown_until = -1
        self.last_retransmit_delta = 0
        self.wire_confirmed = False      # newly confirmed this observe()
        self.wire_confirmations = 0

    # -- obs substrate --------------------------------------------------
    def registry_deltas(self) -> dict:
        """Per-series deltas since the last call for the series the monitor
        consumes: ``comm.edge_bytes{hops=...}`` (keyed by hop distance) and
        ``watchdog.stragglers``."""
        snap = self._reg.find("comm.edge_bytes")
        snap["watchdog.stragglers"] = self._reg.counter(
            "watchdog.stragglers").value
        snap["wire.retransmits"] = self._reg.counter(
            "wire.retransmits").value
        deltas: dict = {"edge_bytes": {}, "stragglers": 0, "traffic": 0.0,
                        "retransmits": 0}
        for rendered, val in snap.items():
            prev = self._last_counts.get(rendered, 0)
            self._last_counts[rendered] = val
            d = val - prev
            name, labels = obs_metrics.parse_labels(rendered)
            if name == "comm.edge_bytes":
                hops = int(labels.get("hops", 1))
                deltas["edge_bytes"][hops] = (
                    deltas["edge_bytes"].get(hops, 0) + d)
                deltas["traffic"] += d
            elif name == "wire.retransmits":
                deltas["retransmits"] += d
            else:
                deltas["stragglers"] += d
        return deltas

    # -- detection ------------------------------------------------------
    def observe(self, step: int, edge_latency: dict,
                require_traffic: bool = False) -> list[tuple]:
        """Ingest one step's per-edge samples; returns edges *newly
        confirmed* degraded this step (usually empty)."""
        deltas = self.registry_deltas()
        self.last_straggler_delta = deltas["stragglers"]
        self._observe_wire(step, deltas["retransmits"])
        if require_traffic and deltas["traffic"] <= 0:
            return []
        confirmed_now: list[tuple] = []
        for edge, x in edge_latency.items():
            edge = (min(edge), max(edge))
            x = float(x)
            base = self._baseline.get(edge)
            if base is None:
                self._baseline[edge] = x
                self._streak[edge] = 0
                continue
            if x > self.threshold * base:
                self._streak[edge] = self._streak.get(edge, 0) + 1
            else:
                self._streak[edge] = 0
                # Only unflagged samples refresh the baseline: a slow edge
                # must not drag its own baseline up until it looks normal.
                self._baseline[edge] = (1 - self.alpha) * base + self.alpha * x
            if (self._streak[edge] >= self.hysteresis
                    and step >= self._cooldown_until.get(edge, -1)):
                self._cooldown_until[edge] = step + self.cooldown
                self._streak[edge] = 0
                self.confirmed.add(edge)
                confirmed_now.append(edge)
                self._reg.counter("monitor.confirmations").inc()
        return confirmed_now

    def _observe_wire(self, step: int, retransmit_delta: float) -> None:
        """The retransmit-rate degradation signal (PR 9): sustained
        ``wire.retransmits`` growth across ``hysteresis`` consecutive
        observations confirms a lossy wire — same streak + cooldown
        discipline as the per-edge latency signal, surfaced via
        :attr:`wire_confirmed` for one observe() so the elastic loop can
        re-select loss-priced configs exactly once per episode."""
        self.last_retransmit_delta = retransmit_delta
        self.wire_confirmed = False
        if retransmit_delta > self.retransmit_threshold:
            self._wire_streak += 1
        else:
            self._wire_streak = 0
        if (self._wire_streak >= self.hysteresis
                and step >= self._wire_cooldown_until):
            self._wire_cooldown_until = step + self.cooldown
            self._wire_streak = 0
            self.wire_confirmed = True
            self.wire_confirmations += 1
            self._reg.counter("monitor.wire_confirmations").inc()

    def baseline(self, edge: tuple) -> Optional[float]:
        return self._baseline.get((min(edge), max(edge)))
