"""Fault-tolerance runtime: straggler watchdog, preemption handler, elastic
re-meshing.

At 1000+ nodes, *something* is always failing.  The framework's contract:

1. **Checkpoint/restart** — async sharded checkpoints every N steps
   (repro.checkpoint) + restore-with-resharding onto whatever mesh survives.
2. **Preemption** — SIGTERM triggers a synchronous emergency checkpoint at
   the next step boundary (the loop polls a flag; the handler never touches
   jax state from the signal context).
3. **Straggler mitigation** — a step-time watchdog keeps a robust running
   estimate (median + MAD); steps slower than ``median + k·MAD`` are logged
   with their host metadata.  On a real deployment this feeds the scheduler
   that re-shards around the slow host; here it drives tests and metrics.
4. **Elastic re-mesh** — given a checkpoint and a NEW device topology,
   ``elastic_restore`` rebuilds the session on the surviving mesh and
   reshards every array (ZeRO slices are re-flattened automatically since
   the optimizer state layout is a pure function of (params, mesh)).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ----------------------------------------------------------------------
# Straggler watchdog
# ----------------------------------------------------------------------

@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    threshold: float


class StepWatchdog:
    """Robust step-time outlier detection (median + k·MAD).

    Retention is bounded for long-running jobs: ``events`` keeps the most
    recent ``max_events`` stragglers (older ones are counted in
    ``events_dropped`` and the ``watchdog.events_dropped`` metrics counter,
    never silently lost), and ``durations`` keeps enough history for the
    rolling ``window`` plus a stable ``median_step`` — O(1) memory over an
    unbounded run instead of one float per step forever.

    Every completed step emits a ``watchdog.step`` instant event when
    tracing is on; detected stragglers additionally emit
    ``watchdog.straggler`` and bump the ``watchdog.stragglers`` counter.
    """

    def __init__(self, k: float = 5.0, warmup: int = 5, window: int = 50,
                 max_events: int = 256):
        self.k = k
        self.warmup = warmup
        self.window = window
        self.max_events = max_events
        self.durations: deque[float] = deque(maxlen=max(4 * window, 200))
        self.events: deque[StragglerEvent] = deque(maxlen=max_events)
        self.events_dropped = 0
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int):
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> Optional[StragglerEvent]:
        if self._t0 is None:
            return None
        dt = time.perf_counter() - self._t0
        # Consume the start mark: a second end_step at the same boundary is
        # a no-op instead of appending the duration twice (which would skew
        # the median and could emit a phantom straggler).
        self._t0 = None
        hist = list(self.durations)[-self.window:]
        event = None
        if len(hist) >= self.warmup:
            med = statistics.median(hist)
            mad = statistics.median([abs(x - med) for x in hist]) or 1e-9
            thr = med + self.k * mad
            if dt > thr:
                event = StragglerEvent(self._step, dt, thr)
                if len(self.events) == self.events.maxlen:
                    self.events_dropped += 1
                    obs_metrics.registry().counter(
                        "watchdog.events_dropped").inc()
                self.events.append(event)
                obs_metrics.registry().counter("watchdog.stragglers").inc()
                obs_trace.instant("watchdog.straggler", cat="watchdog",
                                  step=self._step, ms=dt * 1e3,
                                  threshold_ms=thr * 1e3)
        self.durations.append(dt)
        obs_trace.instant("watchdog.step", cat="watchdog", step=self._step,
                          ms=dt * 1e3)
        return event

    @property
    def median_step(self) -> float:
        return statistics.median(self.durations) if self.durations else 0.0


# ----------------------------------------------------------------------
# Preemption handling
# ----------------------------------------------------------------------

class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the training loop checkpoints and exits
    at the next step boundary."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = threading.Event()
        self._old = {}
        self._signals = signals

    def __enter__(self):
        for sig in self._signals:
            self._old[sig] = signal.signal(sig, self._handler)
        return self

    def __exit__(self, *exc):
        for sig, old in self._old.items():
            signal.signal(sig, old)
        return False

    def _handler(self, signum, frame):
        self._requested.set()

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()

    def request(self):   # for tests / software-triggered drain
        self._requested.set()


# ----------------------------------------------------------------------
# Elastic re-meshing
# ----------------------------------------------------------------------

def elastic_restore(ckpt_dir, cfg, new_mesh, comm, oc, step: Optional[int] = None,
                    fsdp: bool = False):
    """Rebuild a training session on a NEW mesh from a checkpoint.

    The checkpoint stores full (unsharded) arrays; the session on the
    surviving topology re-shards them via device_put. The ZeRO optimizer
    slices are NOT restored (their layout depends on the dead mesh) — they
    are reconstructed deterministically, which costs one step of Adam
    history on re-scale; params and step counter survive exactly.
    """
    from jax.sharding import NamedSharding
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch import setup

    ck = Checkpointer(ckpt_dir)
    step = ck.latest_step() if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    sess = setup.build_session(cfg, new_mesh, comm, oc=oc, fsdp=fsdp,
                               concrete=True)
    shardings = jax.tree.map(lambda s: NamedSharding(new_mesh, s),
                             sess.param_spec)
    params = ck.restore(step, sess.params, target_sharding=shardings)
    sess.params = params
    sess.opt_state = setup.init_opt_state(sess)
    # carry the step counter forward
    import jax.numpy as jnp
    sess.opt_state["step"] = jax.device_put(
        jnp.asarray(step, jnp.int32),
        NamedSharding(new_mesh, jax.sharding.PartitionSpec()))
    return sess, step
