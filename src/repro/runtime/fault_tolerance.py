"""Fault-tolerance runtime: straggler watchdog, preemption handler, elastic
re-meshing.

At 1000+ nodes, *something* is always failing.  The framework's contract:

1. **Checkpoint/restart** — async sharded checkpoints every N steps
   (repro.checkpoint) + restore-with-resharding onto whatever mesh survives.
2. **Preemption** — SIGTERM triggers a synchronous emergency checkpoint at
   the next step boundary (the loop polls a flag; the handler never touches
   jax state from the signal context).
3. **Straggler mitigation** — a step-time watchdog keeps a robust running
   estimate (median + MAD); steps slower than ``median + k·MAD`` are logged
   with their host metadata.  On a real deployment this feeds the scheduler
   that re-shards around the slow host; here it drives tests and metrics.
4. **Elastic re-mesh** — given a checkpoint and a NEW device topology,
   ``elastic_restore`` rebuilds the session on the surviving mesh and
   reshards every array (ZeRO slices are re-flattened automatically since
   the optimizer state layout is a pure function of (params, mesh)).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ----------------------------------------------------------------------
# Straggler watchdog
# ----------------------------------------------------------------------

@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    threshold: float


class StepWatchdog:
    """Robust step-time outlier detection (median + k·MAD).

    Retention is bounded for long-running jobs: ``events`` keeps the most
    recent ``max_events`` stragglers (older ones are counted in
    ``events_dropped`` and the ``watchdog.events_dropped`` metrics counter,
    never silently lost), and ``durations`` keeps enough history for the
    rolling ``window`` plus a stable ``median_step`` — O(1) memory over an
    unbounded run instead of one float per step forever.

    Every completed step emits a ``watchdog.step`` instant event when
    tracing is on; detected stragglers additionally emit
    ``watchdog.straggler`` and bump the ``watchdog.stragglers`` counter.
    """

    def __init__(self, k: float = 5.0, warmup: int = 5, window: int = 50,
                 max_events: int = 256):
        self.k = k
        self.warmup = warmup
        self.window = window
        self.max_events = max_events
        self.durations: deque[float] = deque(maxlen=max(4 * window, 200))
        self.events: deque[StragglerEvent] = deque(maxlen=max_events)
        self.events_dropped = 0
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int):
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> Optional[StragglerEvent]:
        if self._t0 is None:
            return None
        dt = time.perf_counter() - self._t0
        # Consume the start mark: a second end_step at the same boundary is
        # a no-op instead of appending the duration twice (which would skew
        # the median and could emit a phantom straggler).
        self._t0 = None
        hist = list(self.durations)[-self.window:]
        event = None
        if len(hist) >= self.warmup:
            med = statistics.median(hist)
            mad = statistics.median([abs(x - med) for x in hist]) or 1e-9
            thr = med + self.k * mad
            if dt > thr:
                event = StragglerEvent(self._step, dt, thr)
                if len(self.events) == self.events.maxlen:
                    self.events_dropped += 1
                    obs_metrics.registry().counter(
                        "watchdog.events_dropped").inc()
                self.events.append(event)
                obs_metrics.registry().counter("watchdog.stragglers").inc()
                obs_trace.instant("watchdog.straggler", cat="watchdog",
                                  step=self._step, ms=dt * 1e3,
                                  threshold_ms=thr * 1e3)
        self.durations.append(dt)
        obs_trace.instant("watchdog.step", cat="watchdog", step=self._step,
                          ms=dt * 1e3)
        return event

    @property
    def median_step(self) -> float:
        return statistics.median(self.durations) if self.durations else 0.0


# ----------------------------------------------------------------------
# Preemption handling
# ----------------------------------------------------------------------

class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the training loop checkpoints and exits
    at the next step boundary.

    Contract details that matter in production:

    - **SIGINT is guarded by default** — a Ctrl-C drains exactly like a
      scheduler's SIGTERM instead of stack-tracing mid-step.
    - **Pre-existing custom handlers are chained**, not dropped: if the
      launcher installed its own SIGTERM hook, the guard sets its flag and
      then calls the old handler.  Default dispositions (``SIG_DFL``,
      ``SIG_IGN``, Python's KeyboardInterrupt handler) are *replaced* — the
      whole point is to turn them into a drain.
    - **Nested / re-entrant use restores correctly**: each ``__enter__``
      pushes the handlers it displaced and ``__exit__`` pops exactly that
      frame, so an inner guard (e.g. an eval loop inside the train loop)
      hands the signals back to the outer one, not to the defaults.
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = threading.Event()
        self._stack: list[dict] = []
        self._signals = tuple(signals)

    @staticmethod
    def _chainable(old) -> bool:
        """Is ``old`` a custom handler worth chaining?  Dispositions and
        Python's default KeyboardInterrupt raiser are not — replacing them
        IS the guard's job."""
        return callable(old) and old is not signal.default_int_handler

    def __enter__(self):
        frame = {}
        for sig in self._signals:
            old = signal.getsignal(sig)
            frame[sig] = old
            chain = old if self._chainable(old) else None

            def handler(signum, sframe, _chain=chain):
                self._requested.set()
                if _chain is not None:
                    _chain(signum, sframe)

            signal.signal(sig, handler)
        self._stack.append(frame)
        return self

    def __exit__(self, *exc):
        frame = self._stack.pop()
        for sig, old in frame.items():
            signal.signal(sig, old)
        return False

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()

    def request(self):   # for tests / software-triggered drain
        self._requested.set()


# ----------------------------------------------------------------------
# Elastic re-meshing
# ----------------------------------------------------------------------

def survivor_topology(topology, new_mesh):
    """The :class:`~repro.core.topology.TorusSpec` the survivors re-form on:
    ``topology.shrink`` at the new mesh's device count (identity when the
    count is unchanged or there was no torus)."""
    if topology is None:
        return None
    n_new = int(np.prod(list(new_mesh.shape.values())))
    return topology if n_new == topology.n_ranks else topology.shrink(n_new)


def _ring_hops(spec) -> int:
    """Worst-case hop distance of the rank ring on ``spec`` (the LM TP
    combine's wire pattern) — what the re-selection prices the new fabric
    at."""
    if spec is None:
        return 1
    n = spec.n_ranks
    return max((spec.hops(i, (i + 1) % n) for i in range(n)), default=1)


def elastic_restore(ckpt_dir, cfg, new_mesh, comm, oc, step: Optional[int] = None,
                    fsdp: bool = False, reselect: bool = False,
                    tune_db_path=None, topology=None,
                    objective: str = "latency"):
    """Rebuild a training session on a NEW mesh from a checkpoint.

    The checkpoint stores full (unsharded) arrays; the session on the
    surviving topology re-shards them via device_put. The ZeRO optimizer
    slices are NOT restored (their layout depends on the dead mesh) — they
    are reconstructed deterministically, which costs one step of Adam
    history on re-scale; params and step counter survive exactly.

    ``reselect=True`` makes recovery tuner-aware: the dead mesh's
    ``topology`` (a TorusSpec, optional) is shrunk onto the survivors
    (:func:`survivor_topology`) and the session's CommConfig is re-selected
    by extrapolating the calibrated Eq. 1 model over the TuneDB
    (:func:`repro.tune.elastic.model_reselect`) at the new ring's hop
    distance — the previously optimal config was tuned for a fabric that no
    longer exists, and re-measuring it mid-recovery would cost a sweep.  No
    sweep runs on this path (``sweep.runs`` stays flat); a cold DB falls
    back to nearest-measured selection.
    """
    from jax.sharding import NamedSharding
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch import setup

    ck = Checkpointer(ckpt_dir)
    step = ck.latest_step() if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    if reselect:
        from repro.core.config import CommConfig
        from repro.tune import topology_key
        from repro.tune.db import TuneDB
        from repro.tune.elastic import model_reselect
        new_topo = survivor_topology(topology, new_mesh)
        db = TuneDB.load(tune_db_path)
        n_new = int(np.prod(list(new_mesh.shape.values())))
        fallback_kw = {}
        if isinstance(comm, CommConfig):
            fallback_kw["fallback"] = comm   # keep the old config on a cold DB
        comm = model_reselect(
            "all_reduce", 4 * cfg.d_model * 1024, db=db,
            hops=_ring_hops(new_topo), objective=objective,
            topo=topology_key(n_devices=n_new), **fallback_kw)
    sess = setup.build_session(cfg, new_mesh, comm, oc=oc, fsdp=fsdp,
                               concrete=True)
    shardings = jax.tree.map(lambda s: NamedSharding(new_mesh, s),
                             sess.param_spec)
    params = ck.restore(step, sess.params, target_sharding=shardings)
    sess.params = params
    sess.opt_state = setup.init_opt_state(sess)
    # carry the step counter forward
    import jax.numpy as jnp
    sess.opt_state["step"] = jax.device_put(
        jnp.asarray(step, jnp.int32),
        NamedSharding(new_mesh, jax.sharding.PartitionSpec()))
    return sess, step


def resume_session(ckpt_dir, sess, step: Optional[int] = None):
    """Same-mesh resume after a preemption drain.

    Restores params at the newest committed step, and — when the drain also
    persisted the optimizer state (``emergency_save(..., opt_state=...)``
    writes it under ``<ckpt_dir>/opt``) — restores the exact Adam moments
    too, so the resumed loss stream is bitwise-identical to the
    uninterrupted run.  Without a drained opt state the optimizer is
    re-initialized (one step of Adam history lost), matching
    :func:`elastic_restore`.
    """
    from pathlib import Path
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch import setup

    ck = Checkpointer(ckpt_dir)
    step = ck.latest_step() if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    shardings = jax.tree.map(lambda s: NamedSharding(sess.mesh, s),
                             sess.param_spec)
    sess.params = ck.restore(step, sess.params, target_sharding=shardings)
    opt_ck = Checkpointer(Path(ckpt_dir) / "opt")
    if opt_ck.latest_step() == step:
        opt_shardings = jax.tree.map(
            lambda s: NamedSharding(sess.mesh, s), sess.opt_spec)
        sess.opt_state = opt_ck.restore(step, sess.opt_state,
                                        target_sharding=opt_shardings)
    else:
        sess.opt_state = setup.init_opt_state(sess)
    sess.opt_state["step"] = jax.device_put(
        jnp.asarray(step, jnp.int32),
        NamedSharding(sess.mesh, jax.sharding.PartitionSpec()))
    return sess, step
