"""Elastic SWE runtime: run a simulation through a fault schedule and keep
the answer.

The segment loop (``run_swe_elastic``) is the paper's latency story told
under failure: every ``segment`` steps it snapshots the **global-order**
state (:func:`repro.swe.driver.flatten_state` — partition-count-portable, so
it restores onto any survivor mesh), polls the
:class:`~repro.runtime.faults.FaultInjector`, feeds edge telemetry to the
:class:`~repro.runtime.faults.DegradationMonitor`, and reacts:

- **DEGRADED_LINK fires** -> the wire layer slows down *physically*
  (``TorusSpec.link_slowdowns`` inserts hold rounds into the routed
  permutes), but the runtime's routes and configs stay put — belief lags
  reality until the monitor confirms.
- **Monitor confirms an edge** (hysteresis met) -> re-route around it
  (``with_reroute``) and re-select per-round configs from the calibrated
  Eq. 1 model (:func:`repro.tune.elastic.reselect_round_configs`).  No sweep
  runs — the report carries the ``sweep.runs`` counter delta as the witness.
- **RANK_LOST fires** -> the run unwinds to the last segment snapshot,
  re-forms on the survivors' sub-torus (``TorusSpec.shrink``), model-
  re-selects configs for the new fabric, and replays from the snapshot.
  Everything about recovery is deterministic, so two same-seed runs produce
  bitwise-identical digest streams, and the final state digest matches the
  no-fault reference (store-and-forward routing, hold rounds, and
  repartitioning are all value-preserving).

``python -m repro.runtime.elastic`` is the CLI the CI kill-and-resume smoke
drives: run a schedule, emit a JSON report (digest stream, recoveries,
re-selections, sweep delta), optionally diff the final digest against a
no-fault reference run.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core import reliable
from repro.core.config import Reliability
from repro.obs import metrics as obs_metrics
from repro.runtime.faults import (DegradationMonitor, FaultInjector,
                                  FaultSchedule, RankLostError)


@dataclasses.dataclass
class Recovery:
    """One recovery action taken mid-run."""
    step: int
    kind: str                  # "rank_lost" | "degraded_link" | "lossy_wire"
    detail: str
    wall_s: float
    configs_before: list
    configs_after: list

    def config_changed(self) -> bool:
        return self.configs_before != self.configs_after


@dataclasses.dataclass
class ElasticReport:
    """What a faulted run produced — the CI smoke's comparison payload."""
    digests: list            # (step, sha256) after every segment
    final_digest: str
    steps_run: int
    n_parts: list            # partition count per segment
    recoveries: list         # list[Recovery]
    sweep_runs_delta: int    # MUST be 0: no sweep during recovery
    drained: bool = False
    # Reliable-wire deltas over the run (0 on a clean wire — the fault-free
    # self-check; > 0 is the witness that chunk-loss recovery actually fired).
    wire_retransmits: int = 0
    wire_dup_dropped: int = 0
    wire_timeouts: int = 0

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=1, sort_keys=True)


def _sim_configs(sim) -> list:
    """The run's effective per-round configs as comparable primitives."""
    from repro.tune.space import config_to_dict
    cfgs = sim.round_cfgs if sim.round_cfgs else [sim.comm_cfg]
    return [sorted(config_to_dict(c).items()) for c in cfgs]


def _survivor_mesh(n: int):
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:n]), ("data",))


def _physical_edges(spec) -> list:
    """Rank pairs of every physical link on ``spec`` (telemetry targets)."""
    if spec is None:
        return []
    from repro.runtime.faults import _torus_links
    return [(spec.rank_at(a), spec.rank_at(b))
            for a, b in _torus_links(spec.shape)]


def reselect_swe(pm, topology, db, objective: str, fallback,
                 loss: float = 0.0):
    """Model-based per-round selection for an SWE exchange pattern on
    ``topology`` — the recovery-time twin of ``build_simulation``'s
    measured selection.  Returns ``(representative_cfg, round_cfgs)``.
    ``loss`` > 0 prices candidates for a lossy wire (guaranteed delivery
    with the Eq. 1 retransmit surcharge)."""
    from repro.core.communicator import Communicator
    from repro.tune.elastic import reselect_round_configs
    halo_bytes = int(pm.s_max) * 3 * 4
    comm = Communicator(("data",), (pm.n_parts,), topo=topology)
    return reselect_round_configs(pm.rounds, comm, halo_bytes, db=db,
                                  objective=objective, loss=loss,
                                  fallback=fallback)


def run_swe_elastic(n_elements: int, n_devices: int, topology,
                    comm_cfg="auto", n_steps: int = 30, segment: int = 10,
                    schedule: Optional[FaultSchedule] = None,
                    tune_db_path=None, objective: str = "latency",
                    monitor: Optional[DegradationMonitor] = None,
                    guard=None, seed: int = 0,
                    base_step_s: float = 0.0,
                    log=lambda s: None) -> ElasticReport:
    """Run the SWE simulation for ``n_steps`` under a fault schedule.

    See the module docstring for the recovery semantics.  ``monitor=None``
    installs a default :class:`DegradationMonitor` (hysteresis 3, cooldown
    2 segments); ``schedule=None`` runs fault-free (the reference run).
    """
    from repro.swe import driver
    from repro.tune.db import TuneDB

    reg = obs_metrics.registry()
    sweep_runs0 = reg.counter("sweep.runs").value
    schedule = schedule or FaultSchedule()
    injector = FaultInjector(schedule, base_step_s=base_step_s)
    monitor = monitor or DegradationMonitor(threshold=1.5, hysteresis=3,
                                            cooldown=2 * segment)
    db = TuneDB.load(tune_db_path)

    mesh = _survivor_mesh(n_devices)
    sim = driver.build_simulation(n_elements, mesh, comm_cfg,
                                  tune_db_path=tune_db_path,
                                  objective=objective, topology=topology)
    fallback_cfg = sim.comm_cfg       # recovery's cold-DB fallback
    believed_spec = topology          # what routing/selection assumes
    state, t = sim.state, 0.0

    digests: list = []
    n_parts_hist: list = []
    recoveries: list = []
    drained = False

    # Reliable-wire bookkeeping: counter baseline for the report deltas,
    # the currently injected WireFaults (None = clean wire), and the
    # per-trace counter delta replays re-charge (see the segment loop).
    wire0 = reliable.wire_counters()
    wire_stack = contextlib.ExitStack()
    active_wire = None
    last_trace_delta: dict = {}

    # Segment-boundary snapshot (global order) — the in-memory checkpoint
    # rank-loss recovery unwinds to.
    snap_state = driver.flatten_state(sim, np.asarray(state))
    snap_step, snap_t = 0, 0.0

    # Seed the monitor's per-edge baselines from the healthy fabric (before
    # any event fires): a fault active at a monitor's FIRST sample of an
    # edge would otherwise become that edge's "normal".
    if topology is not None:
        monitor.observe(0, injector.edge_latency_samples(
            0, _physical_edges(topology)))

    def rebuild(spec, n_parts, initial_global, rep_cfg, round_cfgs):
        m = _survivor_mesh(n_parts)
        s = driver.build_simulation(n_elements, m, rep_cfg,
                                    topology=spec,
                                    initial_state=initial_global)
        s.round_cfgs = round_cfgs
        return s

    step = 0
    try:
        while step < n_steps:
            n_inner = min(segment, n_steps - step)
            try:
                fired = injector.poll(step, guard=guard)
            except RankLostError as e:
                # --- rank-loss recovery: survivors re-form from the snapshot
                t0 = time.perf_counter()
                before = _sim_configs(sim)
                survivors = sim.pm.n_parts - 1
                if survivors < 1:
                    raise
                new_topo = (believed_spec.shrink(survivors)
                            if believed_spec is not None else None)
                from repro.swe.partition import partition_mesh
                pm = partition_mesh(sim.mesh, survivors, snap_state)
                rep, rcfgs = reselect_swe(pm, new_topo, db, objective,
                                          fallback_cfg)
                sim = rebuild(new_topo, survivors, snap_state, rep, rcfgs)
                believed_spec = new_topo
                injector.active_slowdowns.clear()   # dead rank's fabric is gone
                state, t = sim.state, snap_t
                step = snap_step
                recoveries.append(Recovery(
                    step=e.step, kind="rank_lost",
                    detail=f"rank {e.rank} lost; {survivors} survivors on "
                           f"{new_topo.name if new_topo else 'flat'}",
                    wall_s=time.perf_counter() - t0,
                    configs_before=before, configs_after=_sim_configs(sim)))
                log(f"[elastic] rank {e.rank} lost at step {e.step}: resumed "
                    f"from step {snap_step} on {survivors} partitions")
                continue

            if guard is not None and guard.preempted:
                drained = True
                break

            if any(ev.kind == "degraded_link" for ev in fired):
                # Wire-layer injection: physics change, belief doesn't.  The
                # degraded spec's routed plans carry the hold rounds; routes and
                # configs stay what the healthy fabric chose.
                phys = injector.degrade_spec(
                    believed_spec.without_degradations()
                    if believed_spec is not None else None)
                if phys is not None:
                    sim = rebuild(phys, sim.pm.n_parts,
                                  driver.flatten_state(sim, np.asarray(state)),
                                  sim.comm_cfg, sim.round_cfgs)
                    state = sim.state
                    log(f"[elastic] degraded links now "
                        f"{dict(injector.active_slowdowns)}")

            wf = injector.wire_faults()
            if wf != active_wire:
                # chunk_loss fired (or escalated): inject the chunk-level
                # schedule and promote the run's configs to guaranteed delivery
                # — best-effort messages cannot survive a dropping wire.
                wire_stack.close()
                wire_stack = contextlib.ExitStack()
                if wf is not None:
                    wire_stack.enter_context(reliable.inject(wf))
                active_wire = wf
                last_trace_delta = {}
                if wf is not None and wf.lossy():
                    rep = dataclasses.replace(
                        sim.comm_cfg, reliability=Reliability.GUARANTEED)
                    rcfgs = ([dataclasses.replace(
                        c, reliability=Reliability.GUARANTEED)
                        for c in sim.round_cfgs] if sim.round_cfgs else None)
                    if (rep, rcfgs) != (sim.comm_cfg, sim.round_cfgs):
                        sim = rebuild(
                            getattr(sim, "topology", believed_spec),
                            sim.pm.n_parts,
                            driver.flatten_state(sim, np.asarray(state)),
                            rep, rcfgs)
                        state = sim.state
                    log(f"[elastic] chunk loss active (drop={wf.drop:.1%}): "
                        f"wire promoted to guaranteed delivery")

            run = driver.make_sim_runner(sim, n_inner)
            seg_wire = reliable.wire_counters()
            state = run(state, t)
            import jax
            jax.block_until_ready(state)
            if active_wire is not None:
                # wire.* counters increment at TRACE time; a replayed program
                # still EXECUTES its recovery rounds, so re-charge the last
                # traced delta once per replayed segment — that steady
                # per-observation signal is what the monitor's retransmit
                # streak detects.
                now = reliable.wire_counters()
                delta = {k: now[k] - seg_wire[k] for k in seg_wire}
                if any(delta.values()):
                    last_trace_delta = delta
                elif last_trace_delta:
                    for k, v in last_trace_delta.items():
                        if v:
                            reg.counter(f"wire.{k}").inc(v)
            t += sim.swe.dt * n_inner
            step += n_inner

            # Segment boundary: snapshot + digest + telemetry -> monitor.
            snap_state = driver.flatten_state(sim, np.asarray(state))
            snap_step, snap_t = step, t
            digests.append((step, driver.state_digest(sim, np.asarray(state))))
            n_parts_hist.append(sim.pm.n_parts)

            spec_now = getattr(sim, "topology", None)
            if spec_now is not None:
                samples = injector.edge_latency_samples(
                    step, _physical_edges(spec_now))
                confirmed = monitor.observe(step, samples)
                if confirmed:
                    # --- degraded-but-alive recovery: re-route + re-select
                    t0 = time.perf_counter()
                    before = _sim_configs(sim)
                    believed = believed_spec.without_degradations() \
                        if believed_spec is not None else None
                    for (a, b) in sorted(monitor.confirmed):
                        f = injector.active_slowdowns.get((a, b), 1.0)
                        if f > 1.0 and believed is not None:
                            believed = believed.with_link_slowdown(a, b, f)
                    phys = believed.with_reroute(True) if believed is not None \
                        else None
                    rep, rcfgs = reselect_swe(sim.pm, phys, db, objective,
                                              fallback_cfg)
                    sim = rebuild(phys, sim.pm.n_parts, snap_state, rep, rcfgs)
                    believed_spec = phys
                    state = sim.state
                    recoveries.append(Recovery(
                        step=step, kind="degraded_link",
                        detail=f"confirmed {sorted(confirmed)}; rerouted + "
                               f"model-reselected",
                        wall_s=time.perf_counter() - t0,
                        configs_before=before, configs_after=_sim_configs(sim)))
                    log(f"[elastic] degradation confirmed on {sorted(confirmed)}"
                        f": rerouted and re-selected")
                if monitor.wire_confirmed:
                    # --- lossy-wire recovery: the retransmit streak confirmed a
                    # dropping wire; re-select with the Eq. 1 loss surcharge so
                    # segment sizes suit the lossy link (no sweep runs).
                    t0 = time.perf_counter()
                    before = _sim_configs(sim)
                    loss_est = (active_wire.drop
                                if active_wire is not None else 0.0)
                    rep, rcfgs = reselect_swe(sim.pm, spec_now, db, objective,
                                              fallback_cfg, loss=loss_est)
                    rep = dataclasses.replace(
                        rep, reliability=Reliability.GUARANTEED)
                    rcfgs = ([dataclasses.replace(
                        c, reliability=Reliability.GUARANTEED) for c in rcfgs]
                        if rcfgs else None)
                    sim = rebuild(spec_now, sim.pm.n_parts, snap_state, rep,
                                  rcfgs)
                    state = sim.state
                    recoveries.append(Recovery(
                        step=step, kind="lossy_wire",
                        detail=f"retransmit streak confirmed (last delta "
                               f"{monitor.last_retransmit_delta}); loss-aware "
                               f"model re-selection at loss={loss_est:g}",
                        wall_s=time.perf_counter() - t0,
                        configs_before=before, configs_after=_sim_configs(sim)))
                    log(f"[elastic] lossy wire confirmed at step {step}: "
                        f"re-selected for loss={loss_est:g}")

    finally:
        wire_stack.close()
    wire1 = reliable.wire_counters()
    final = driver.state_digest(sim, np.asarray(state))
    return ElasticReport(
        digests=digests, final_digest=final, steps_run=step,
        n_parts=n_parts_hist, recoveries=recoveries,
        sweep_runs_delta=reg.counter("sweep.runs").value - sweep_runs0,
        drained=drained,
        wire_retransmits=int(wire1["retransmits"] - wire0["retransmits"]),
        wire_dup_dropped=int(wire1["dup_dropped"] - wire0["dup_dropped"]),
        wire_timeouts=int(wire1["timeouts"] - wire0["timeouts"]))


# ----------------------------------------------------------------------
# CLI — what the CI kill-and-resume smoke runs
# ----------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse
    import os

    p = argparse.ArgumentParser(
        description="Run the SWE simulation under a fault schedule")
    p.add_argument("--devices", type=int, default=8)
    p.add_argument("--topology", default="4x2",
                   help="TorusSpec, e.g. 4x2 or 4x4:snake")
    p.add_argument("--elements", type=int, default=400)
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--segment", type=int, default=10)
    p.add_argument("--schedule", default=None,
                   help="compact schedule, e.g. 'rank_lost@10=r5'")
    p.add_argument("--schedule-file", default=None,
                   help="JSON FaultSchedule file (overrides --schedule)")
    p.add_argument("--tune-db", default=None)
    p.add_argument("--objective", default="latency",
                   choices=("latency", "e2e"))
    p.add_argument("--json", default=None, help="write the report here")
    p.add_argument("--check-against", default=None,
                   help="reference report JSON; fail unless final digests "
                        "match")
    p.add_argument("--expect-recovery", action="store_true",
                   help="fail unless >=1 recovery happened (and no sweep "
                        "ran during it)")
    p.add_argument("--chunk-loss", type=float, default=0.0,
                   help="wire chunk-drop probability from step 0 "
                        "(shorthand for a chunk_loss@0 schedule event)")
    p.add_argument("--chunk-dup", type=float, default=0.0,
                   help="wire chunk-duplicate probability from step 0")
    p.add_argument("--chunk-reorder", type=float, default=0.0,
                   help="wire chunk-reorder probability from step 0")
    p.add_argument("--expect-retransmits", action="store_true",
                   help="fail unless the run retransmitted at least one "
                        "chunk (the chaos smoke's recovery witness)")
    args = p.parse_args(argv)

    # Must precede the first jax import.
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    from repro.core.topology import TorusSpec
    topology = TorusSpec.parse(args.topology) if args.topology else None
    schedule = None
    if args.schedule_file:
        schedule = FaultSchedule.load(args.schedule_file)
    elif args.schedule:
        schedule = FaultSchedule.parse(args.schedule)
    if args.chunk_loss or args.chunk_dup or args.chunk_reorder:
        from repro.runtime.faults import ChunkLoss
        ev = ChunkLoss(0, drop=args.chunk_loss, dup=args.chunk_dup,
                       reorder=args.chunk_reorder)
        schedule = FaultSchedule(
            events=(schedule.events if schedule else ()) + (ev,),
            seed=schedule.seed if schedule else None)

    report = run_swe_elastic(
        args.elements, args.devices, topology, n_steps=args.steps,
        segment=args.segment, schedule=schedule, tune_db_path=args.tune_db,
        objective=args.objective, log=print)

    print(f"steps_run={report.steps_run} final={report.final_digest[:16]} "
          f"recoveries={len(report.recoveries)} "
          f"sweep_runs_delta={report.sweep_runs_delta} "
          f"wire_retransmits={report.wire_retransmits}")
    for r in report.recoveries:
        print(f"  [{r.kind}@{r.step}] {r.detail} "
              f"({r.wall_s*1e3:.0f}ms, config_changed={r.config_changed()})")

    if args.json:
        Path(args.json).write_text(report.to_json())
    rc = 0
    if args.expect_recovery:
        if not report.recoveries:
            print("FAIL: expected at least one recovery, saw none")
            rc = 1
        if report.sweep_runs_delta != 0:
            print(f"FAIL: {report.sweep_runs_delta} sweep(s) ran during "
                  f"the faulted run — recovery must be model-based")
            rc = 1
    has_chunk_loss = (schedule is not None
                      and any(ev.kind == "chunk_loss"
                              for ev in schedule.events))
    if args.expect_retransmits and report.wire_retransmits <= 0:
        print("FAIL: expected chunk retransmissions, wire_retransmits=0 "
              "(chunk-loss injection never reached the wire)")
        rc = 1
    if not has_chunk_loss and report.wire_retransmits != 0:
        print(f"FAIL: {report.wire_retransmits} retransmission(s) on a "
              f"clean wire — the zero-fault fast path must be overhead-free")
        rc = 1
    if args.check_against:
        ref = json.loads(Path(args.check_against).read_text())
        if ref["final_digest"] != report.final_digest:
            print(f"FAIL: final digest {report.final_digest[:16]} != "
                  f"reference {ref['final_digest'][:16]}")
            rc = 1
        else:
            print("final digest matches reference")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
