"""Streaming (chunked, overlapped) communication engine.

The paper's *streaming* mode forwards message data into the consuming kernel
via AXI streams while the transfer is still in flight.  The TPU-native
equivalent: split the message into wire chunks and issue one
``collective-permute`` per chunk with **no serializing dependency** between
them — XLA's latency-hiding scheduler then runs chunk *i+1*'s DMA while the
consumer computes on chunk *i* (``collective-permute-start``/``-done`` pairs
in the compiled HLO).

Transport semantics (paper §3.4):

- **unordered** ("UDP"): all chunk permutes are independent → maximal overlap,
  but arrival order across messages is not defined; multi-source consumers
  must reorder (see the shallow-water halo's buffered receive).
- **ordered** ("TCP"): chunk *i* may only start once chunk *i - window* has
  been delivered (ack window).  Expressed as a data dependency through
  ``lax.optimization_barrier``; ``window`` is the TCP window-scaling analogue
  and ``chunk_bytes`` the jumbo-frame/MSS analogue.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import CommConfig, CommMode, Transport
from repro.core import plugins


def num_chunks(nbytes: int, cfg: CommConfig) -> int:
    return max(1, min(cfg.max_chunks, math.ceil(nbytes / cfg.chunk_bytes)))


def split_chunks(x: jnp.ndarray, n: int):
    """Flatten and split into n equal chunks (zero-padded). Returns
    (chunks[(n, L)], unsplit_fn)."""
    flat = x.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    shape, dtype = x.shape, x.dtype

    def unsplit(cs: jnp.ndarray) -> jnp.ndarray:
        return cs.reshape(-1)[:size].reshape(shape).astype(dtype)

    return chunks, unsplit


def chunked_permute(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                    axis_name: str, cfg: CommConfig) -> jnp.ndarray:
    """Streaming point-to-point transfer of ``x`` along ``perm``.

    One ppermute per wire chunk; chunks are independent (unordered) or chained
    with an ack window (ordered).  Wire format per the compression plugin.
    """
    n = num_chunks(x.size * x.dtype.itemsize, cfg)
    chunks, unsplit = split_chunks(x, n)
    received = []
    for i in range(n):
        payload = chunks[i]
        if cfg.transport == Transport.ORDERED and i >= cfg.window:
            # Ack chain: chunk i waits until chunk i-window was delivered.
            payload, _ = lax.optimization_barrier((payload, received[i - cfg.window]))
        enc, dec = plugins.wire_encode(payload, cfg)
        out = jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm=list(perm)), enc)
        received.append(dec(out))
    return unsplit(jnp.stack(received))


def buffered_permute(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                     axis_name: str, cfg: CommConfig) -> jnp.ndarray:
    """Buffered transfer: one whole-message permute, then a staging copy.

    The ``optimization_barrier`` models the receive buffer in global memory —
    the consumer cannot observe any element until the *entire* message has
    landed (the paper's l_m staging-copy term, which also halves effective
    peak throughput to (1/bw_link + 1/bw_mem)^-1).
    """
    enc, dec = plugins.wire_encode(x, cfg)
    out = jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm=list(perm)), enc)
    out = lax.optimization_barrier(out)
    return dec(out)


def pipelined_consume(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                      axis_name: str, cfg: CommConfig,
                      consume: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
                      init):
    """Stream ``x`` to the neighbor and fold ``consume`` over arriving chunks.

    ``consume(carry, chunk) -> carry`` runs on chunk *i* while chunk *i+1* is
    in flight — the paper's 'process incoming data before the transmission is
    complete'.  Returns (carry, received_message).
    """
    n = num_chunks(x.size * x.dtype.itemsize, cfg)
    chunks, unsplit = split_chunks(x, n)
    carry = init
    received = []
    for i in range(n):
        enc, dec = plugins.wire_encode(chunks[i], cfg)
        out = jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm=list(perm)), enc)
        r = dec(out)
        received.append(r)
        carry = consume(carry, r)
    return carry, unsplit(jnp.stack(received))


def double_buffered_exchange(payloads: Sequence[jnp.ndarray],
                             perms: Sequence[Sequence[tuple[int, int]]],
                             axis_name: str, cfg: CommConfig,
                             consume: Callable | None = None,
                             init=None):
    """Multi-round exchange through two alternating halo buffers.

    Round ``r`` lands in buffer ``r % 2``.  Under ordered transport the ack
    chain runs *within* a buffer (round ``r`` waits on round ``r - 2``), so
    the consumer can fold buffer A's message while buffer B's chunks are in
    flight — the double-buffering that lets the element update start before
    the whole exchange has completed.  Each round's transfer is
    :func:`pipelined_consume` (streaming) or :func:`buffered_permute`
    (buffered), so chunk-level pipelining still applies inside a round.

    ``consume(carry, round_index, message) -> carry`` folds each round's
    reassembled message as soon as its buffer allows (e.g. scatter-add into
    the halo slots).  Returns ``(carry, received)`` with ``received`` in
    round order; values are bitwise-identical to a serialized exchange —
    only the dependency structure differs.
    """
    bufs: tuple[list, list] = ([], [])
    carry = init
    received = []
    for r, (payload, perm) in enumerate(zip(payloads, perms)):
        buf = bufs[r % 2]
        if cfg.transport == Transport.ORDERED and buf:
            # Per-buffer ack chain: no cross-buffer serialization.
            payload, _ = lax.optimization_barrier((payload, buf[-1]))
        if cfg.mode == CommMode.STREAMING:
            carry, msg = pipelined_consume(
                payload, perm, axis_name, cfg, lambda c, _chunk: c, carry)
        else:
            msg = buffered_permute(payload, perm, axis_name, cfg)
        if consume is not None:
            carry = consume(carry, r, msg)
        buf.append(msg)
        received.append(msg)
    return carry, received


def overlapped_matmul_allreduce(h: jnp.ndarray, w: jnp.ndarray,
                                axis_names, cfg: CommConfig,
                                n_chunks: int | None = None) -> jnp.ndarray:
    """Row-parallel TP matmul with the reduction streamed against compute.

    ``h``: (tokens, ff_shard) activation shard; ``w``: (ff_shard, d) weight
    shard; result: (tokens, d) fully reduced.  Token rows are split into
    chunks; each chunk's psum is independent of the next chunk's matmul, so
    the scheduler overlaps collective *i* with compute *i+1* (streaming TP).
    With ``n_chunks=1`` this degrades to the buffered (sequential) pattern.
    """
    tokens = h.shape[0]
    if n_chunks is None:
        out_bytes = tokens * w.shape[1] * 4
        n_chunks = num_chunks(out_bytes, cfg)
    n_chunks = max(1, min(n_chunks, tokens))
    while tokens % n_chunks:
        n_chunks -= 1
    import dataclasses as _dc
    from repro.core import collectives
    from repro.core.communicator import Communicator
    from repro.core.config import Compression
    axes = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    comm = Communicator(axes, (1,) * len(axes))
    # The chunked overlap IS the streaming mechanism here; the per-chunk
    # combine itself uses the native collective.
    cfg_native = _dc.replace(
        cfg, algorithm="native",
        compression=(Compression.NONE if cfg.compression == Compression.INT8
                     else cfg.compression))
    parts = []
    rows = tokens // n_chunks
    for i in range(n_chunks):
        hc = lax.dynamic_slice_in_dim(h, i * rows, rows, axis=0)
        partial = jnp.dot(hc, w, preferred_element_type=jnp.float32)
        parts.append(collectives.all_reduce(partial, comm, cfg_native))
    return jnp.concatenate(parts, axis=0).astype(h.dtype)
