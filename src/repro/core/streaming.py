"""Streaming (chunked, overlapped) communication engine.

The paper's *streaming* mode forwards message data into the consuming kernel
via AXI streams while the transfer is still in flight.  The TPU-native
equivalent: split the message into wire chunks and issue one
``collective-permute`` per chunk with **no serializing dependency** between
them — XLA's latency-hiding scheduler then runs chunk *i+1*'s DMA while the
consumer computes on chunk *i* (``collective-permute-start``/``-done`` pairs
in the compiled HLO).

Transport semantics (paper §3.4):

- **unordered** ("UDP"): all chunk permutes are independent → maximal overlap,
  but arrival order across messages is not defined; multi-source consumers
  must reorder (see the shallow-water halo's buffered receive).
- **ordered** ("TCP"): chunk *i* may only start once chunk *i - window* has
  been delivered (ack window).  Expressed as a data dependency through
  ``lax.optimization_barrier``; ``window`` is the TCP window-scaling analogue
  and ``chunk_bytes`` the jumbo-frame/MSS analogue.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import CommConfig, CommMode, Compression, Transport
from repro.core import plans, plugins, reliable
from repro.obs import trace as obs_trace


def num_chunks(nbytes: int, cfg: CommConfig) -> int:
    return max(1, min(cfg.max_chunks, math.ceil(nbytes / cfg.chunk_bytes)))


def wire_permute(t: jnp.ndarray, axis_name: str, perm) -> jnp.ndarray:
    """One wire traversal of an (encoded) tensor: a plain edge list is a
    single ``ppermute``; a :class:`~repro.core.topology.RoutedPerm` (virtual
    multi-hop torus transport) executes each store-and-forward batch as
    sequential single-hop permutes — intermediate ranks forward, arrived
    messages hold via self-edges — and merges batches by destination mask
    (a pure select).  Values are bitwise-identical to the direct permute;
    only the number of physically executed hops differs.
    """
    from repro.core import topology
    if not isinstance(perm, topology.RoutedPerm):
        return lax.ppermute(t, axis_name, perm=list(perm))

    def run_batch(batch):
        out = t
        for rnd in batch.rounds:
            out = lax.ppermute(out, axis_name, perm=list(rnd))
        return out

    if len(perm.batches) == 1:
        return run_batch(perm.batches[0])
    idx = lax.axis_index(axis_name)
    acc = jnp.zeros_like(t)
    for batch in perm.batches:
        out = run_batch(batch)
        is_dst = jnp.zeros((), bool)
        for d in batch.dests:
            is_dst = jnp.logical_or(is_dst, idx == d)
        acc = jnp.where(is_dst, out, acc)
    return acc


def aligned_chunks(x: jnp.ndarray, cfg: CommConfig, align: int = 1
                   ) -> tuple[int, int]:
    """Wire-chunk geometry for streaming ``x``: (n_chunks, chunk_elems).

    ``chunk_elems`` is a multiple of ``align`` flat elements, so a wire chunk
    never splits a logical row of ``align`` elements — the recv_slot-aligned
    chunking that lets a halo consumer scatter-fold whole rows per chunk.
    Derived once per (shape, dtype, config, align) via the plan cache.
    """
    p = plans.chunk_plan(x.shape, x.dtype, cfg, align=align)
    return p.n_chunks, p.chunk_elems


def split_chunks(x: jnp.ndarray, n: int):
    """Flatten and split into n equal chunks (zero-padded). Returns
    (chunks[(n, L)], unsplit_fn)."""
    flat = x.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    shape, dtype = x.shape, x.dtype

    def unsplit(cs: jnp.ndarray) -> jnp.ndarray:
        return cs.reshape(-1)[:size].reshape(shape).astype(dtype)

    return chunks, unsplit


def _reliable_stream(rplan, chunks, perm, axis_name: str, cfg: CommConfig,
                     consume: Callable | None = None, init=None):
    """Execute a :class:`repro.core.reliable.DeliveryPlan`: one real wire
    round per slot, value-preserving.

    Every slot — original transmission, lost transmission, duplicate,
    backoff hold — runs a full ``wire_permute`` of its sequence's chunk, so
    recovery costs real permute rounds (the topology layer's hold-round
    idiom at wire granularity).  Only ``DELIVER`` slots land in the
    receiver's reassembly buffer; the wire output of every other slot is
    threaded through ``lax.optimization_barrier`` into the next slot's
    payload (or the final message), which (a) stops XLA dead-code-eliminating
    the unused permute and (b) serializes recovery after the fault it
    repairs.  Ordered transport chains slot *j* on slot *j - window*'s wire
    output — the ack window at slot granularity, covering retransmissions
    too.

    ``consume(carry, seq, chunk)`` is fired in sequence order via the
    reassembly flush: seq *i* is folded only once every seq ``<= i`` has
    been delivered, so a pipelined consumer's fold order — and therefore
    its float accumulation — is bitwise-identical under any wire reorder.

    Returns ``(carry, [chunk_0, ..., chunk_{n-1}])`` in sequence order.
    """
    reliable.record(rplan, cfg)
    ordered = cfg.transport == Transport.ORDERED
    received: dict = {}
    outs: list = []
    waste = None
    carry = init
    next_flush = 0
    for j, slot in enumerate(rplan.slots):
        payload = chunks[slot.seq]
        with obs_trace.span("wire.slot", cat="wire", slot=j, of=len(rplan.slots),
                            seq=slot.seq, action=slot.action,
                            attempt=slot.attempt):
            deps = []
            if ordered and j >= cfg.window:
                deps.append(outs[j - cfg.window])
            if waste is not None:
                deps.append(waste)
                waste = None
            if deps:
                bar = lax.optimization_barrier((payload, *deps))
                payload = bar[0]
            enc, dec = plugins.wire_encode(payload, cfg)
            out = jax.tree.map(lambda t: wire_permute(t, axis_name, perm),
                               enc)
            outs.append(out)
            if slot.action == reliable.DELIVER:
                received[slot.seq] = dec(out)
            else:
                waste = out
        if consume is not None:
            while next_flush in received:
                carry = consume(carry, next_flush, received[next_flush])
                next_flush += 1
    if waste is not None:
        # A trailing non-delivered slot (e.g. a duplicate of the last chunk):
        # anchor its wire output on the final message so it survives DCE.
        last = max(received)
        merged = lax.optimization_barrier((received[last], waste))
        received[last] = merged[0]
    return carry, [received[i] for i in range(rplan.n_chunks)]


def chunked_permute(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                    axis_name: str, cfg: CommConfig) -> jnp.ndarray:
    """Streaming point-to-point transfer of ``x`` along ``perm``.

    One ppermute per wire chunk; chunks are independent (unordered) or chained
    with an ack window (ordered).  Wire format per the compression plugin.
    The chunk layout and ack-window structure replay from the plan cache.
    """
    plan = plans.chunk_plan(x.shape, x.dtype, cfg, equal_split=True)
    n = plan.n_chunks
    chunks, unsplit = split_chunks(x, n)
    rplan = reliable.plan_for(cfg, n)
    if rplan is not None:
        _, seq_chunks = _reliable_stream(rplan, chunks, perm, axis_name, cfg)
        return unsplit(jnp.stack(seq_chunks))
    received = []
    for i in range(n):
        payload = chunks[i]
        with obs_trace.span("wire.chunk", cat="wire", chunk=i, of=n,
                            elems=int(payload.size),
                            acked=int(plan.ack_of[i])):
            if plan.ack_of[i] >= 0:
                # Ack chain: chunk i waits until chunk i-window was delivered.
                payload, _ = lax.optimization_barrier(
                    (payload, received[plan.ack_of[i]]))
            enc, dec = plugins.wire_encode(payload, cfg)
            out = jax.tree.map(lambda t: wire_permute(t, axis_name, perm),
                               enc)
            received.append(dec(out))
    return unsplit(jnp.stack(received))


def buffered_permute(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                     axis_name: str, cfg: CommConfig) -> jnp.ndarray:
    """Buffered transfer: one whole-message permute, then a staging copy.

    The ``optimization_barrier`` models the receive buffer in global memory —
    the consumer cannot observe any element until the *entire* message has
    landed (the paper's l_m staging-copy term, which also halves effective
    peak throughput to (1/bw_link + 1/bw_mem)^-1).
    """
    rplan = reliable.plan_for(cfg, 1)
    if rplan is not None:
        # Buffered = a one-chunk message: losing it on the wire costs a
        # whole-message retransmit (why small segments win lossy links).
        _, seq_chunks = _reliable_stream(rplan, [x], perm, axis_name, cfg)
        out = lax.optimization_barrier(seq_chunks[0])
        return out
    with obs_trace.span("wire.message", cat="wire", elems=int(x.size)):
        enc, dec = plugins.wire_encode(x, cfg)
        out = jax.tree.map(lambda t: wire_permute(t, axis_name, perm), enc)
        out = lax.optimization_barrier(out)
        return dec(out)


def pipelined_consume(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
                      axis_name: str, cfg: CommConfig,
                      consume: Callable, init, align: int = 1):
    """Stream ``x`` to the neighbor and fold ``consume`` over arriving wire
    chunks.

    ``consume(carry, chunk_index, chunk) -> carry`` runs on chunk *i* while
    chunk *i+1* is in flight — the paper's 'process incoming data before the
    transmission is complete'.  ``chunk`` is the decoded flat chunk
    (``chunk_elems`` elements; the tail chunk is zero-padded).  Chunk
    boundaries fall on multiples of ``align`` flat elements, so a consumer
    that folds logical rows of ``align`` elements (the halo's recv_slot rows)
    never sees a split row.  Ordered transport chains chunk *i* on the
    delivery of chunk *i - window* (the ack window), exactly like
    :func:`chunked_permute`.  Returns (carry, received_message).
    """
    plan = plans.chunk_plan(x.shape, x.dtype, cfg, align=align)
    n, chunk_elems = plan.n_chunks, plan.chunk_elems
    flat = x.reshape(-1)
    pad = n * chunk_elems - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, chunk_elems)
    rplan = reliable.plan_for(cfg, n)
    if rplan is not None:
        carry, seq_chunks = _reliable_stream(rplan, chunks, perm, axis_name,
                                             cfg, consume=consume, init=init)
        msg = (jnp.stack(seq_chunks).reshape(-1)[: x.size]
               .reshape(x.shape).astype(x.dtype))
        return carry, msg
    carry = init
    received = []
    for i in range(n):
        payload = chunks[i]
        with obs_trace.span("wire.chunk", cat="wire", chunk=i, of=n,
                            elems=int(chunk_elems),
                            acked=int(plan.ack_of[i])):
            if plan.ack_of[i] >= 0:
                payload, _ = lax.optimization_barrier(
                    (payload, received[plan.ack_of[i]]))
            enc, dec = plugins.wire_encode(payload, cfg)
            out = jax.tree.map(lambda t: wire_permute(t, axis_name, perm),
                               enc)
            r = dec(out)
            received.append(r)
            carry = consume(carry, i, r)
    msg = jnp.stack(received).reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)
    return carry, msg


def double_buffered_exchange(payloads: Sequence[jnp.ndarray],
                             perms: Sequence[Sequence[tuple[int, int]]],
                             axis_name: str, cfg: CommConfig,
                             consume: Callable | None = None,
                             init=None,
                             chunk_consume: Callable | None = None,
                             chunk_align: int = 1):
    """Multi-round exchange through two alternating halo buffers.

    Round ``r`` lands in buffer ``r % 2``.  Under ordered transport the ack
    chain runs *within* a buffer (round ``r`` waits on round ``r - 2``), so
    the consumer can fold buffer A's message while buffer B's chunks are in
    flight — the double-buffering that lets the element update start before
    the whole exchange has completed.  Each round's transfer is
    :func:`pipelined_consume` (streaming) or :func:`buffered_permute`
    (buffered), so chunk-level pipelining still applies inside a round.

    Two consume granularities:

    - ``consume(carry, round_index, message) -> carry`` folds each round's
      reassembled message as soon as its buffer allows (e.g. scatter-add
      into the halo slots).
    - ``chunk_consume(carry, round_index, chunk_index, chunk) -> carry``
      folds each ``chunk_align``-aligned wire chunk *as it lands* (streaming
      rounds only): a single large neighbor message overlaps its own
      assembly instead of fencing the fold on the full round.  When given,
      it replaces ``consume`` for streaming rounds; buffered rounds (which
      have no wire chunks) still fold through ``consume``.

    Returns ``(carry, received)`` with ``received`` in round order; values
    are bitwise-identical to a serialized exchange — only the dependency
    structure differs.
    """
    from repro.core import topology
    bufs: tuple[list, list] = ([], [])
    carry = init
    received = []
    for r, (payload, perm) in enumerate(zip(payloads, perms)):
        buf = bufs[r % 2]
        hops = (perm.max_hops if isinstance(perm, topology.RoutedPerm)
                else 1)
        with obs_trace.span("round", cat="collective", round=r, buf=r % 2,
                            hops=hops, elems=int(payload.size)):
            if cfg.transport == Transport.ORDERED and buf:
                # Per-buffer ack chain: no cross-buffer serialization.
                payload, _ = lax.optimization_barrier((payload, buf[-1]))
            if cfg.mode == CommMode.STREAMING:
                if chunk_consume is not None:
                    carry, msg = pipelined_consume(
                        payload, perm, axis_name, cfg,
                        lambda c, i, ch, _r=r: chunk_consume(c, _r, i, ch),
                        carry, align=chunk_align)
                else:
                    carry, msg = pipelined_consume(
                        payload, perm, axis_name, cfg,
                        lambda c, _i, _chunk: c, carry)
                    if consume is not None:
                        carry = consume(carry, r, msg)
            else:
                msg = buffered_permute(payload, perm, axis_name, cfg)
                if consume is not None:
                    carry = consume(carry, r, msg)
        buf.append(msg)
        received.append(msg)
    return carry, received


def overlapped_matmul_allreduce(h: jnp.ndarray, w: jnp.ndarray,
                                comm, cfg: CommConfig,
                                n_chunks: int | None = None) -> jnp.ndarray:
    """Row-parallel TP matmul with the reduction double-buffered against
    compute.

    ``h``: (tokens, ff_shard) activation shard; ``w``: (ff_shard, d) weight
    shard; result: (tokens, d) fully reduced.  ``comm`` is the caller's TP
    :class:`~repro.core.communicator.Communicator`, reused — not rebuilt —
    so ``torus_hops`` and hop-aware ``select_config`` describe the real
    topology of the TP axis (axis name(s) are still accepted and wrap a
    size-unknown communicator for backward compatibility).

    Token rows are split into wire chunks; each chunk's psum is independent
    of the next chunk's matmul, so the scheduler overlaps collective *i*
    with compute *i+1* (streaming TP).  Under ordered transport the chunks
    form a two-deep ack chain — chunk *i*'s matmul waits on the delivery of
    reduce *i − 2*, the per-layer double buffering of the TP reduce — never
    on the whole history.  With ``n_chunks=1`` this degrades to the
    buffered (sequential) pattern.  Bitwise-identical to the fused
    matmul + all-reduce: row chunking and identity barriers never change
    the arithmetic.
    """
    tokens = h.shape[0]
    if n_chunks is None:
        # Derive the chunk geometry through the plan cache (align = output
        # row width, so a chunk never splits a token row): repeated per-layer
        # combines of the same shape replay one cached ChunkPlan.
        p = plans.chunk_plan((tokens, w.shape[1]), jnp.float32, cfg,
                             align=w.shape[1])
        n_chunks = p.n_chunks
    n_chunks = max(1, min(n_chunks, tokens))
    while tokens % n_chunks:
        n_chunks -= 1
    import dataclasses as _dc
    from repro.core import collectives
    from repro.core.communicator import Communicator
    if not isinstance(comm, Communicator):
        axes = (comm,) if isinstance(comm, str) else tuple(comm)
        comm = Communicator(axes, (1,) * len(axes))
    # The chunked overlap IS the streaming mechanism here; the per-chunk
    # combine itself uses the native collective.
    cfg_native = _dc.replace(
        cfg, algorithm="native",
        compression=(Compression.NONE if cfg.compression == Compression.INT8
                     else cfg.compression))
    parts: list[jnp.ndarray] = []
    rows = tokens // n_chunks
    for i in range(n_chunks):
        hc = lax.dynamic_slice_in_dim(h, i * rows, rows, axis=0)
        if cfg.transport == Transport.ORDERED and i >= 2:
            # Double-buffered ack chain: two reduce buffers alternate; the
            # next chunk's compute waits only on its own buffer's delivery.
            hc, _ = lax.optimization_barrier((hc, parts[i - 2]))
        partial = jnp.dot(hc, w, preferred_element_type=jnp.float32)
        parts.append(collectives.all_reduce(partial, comm, cfg_native))
    return jnp.concatenate(parts, axis=0).astype(h.dtype)


def chunked_all_to_all(x: jnp.ndarray, comm, cfg: CommConfig,
                       split_axis: int = 0, concat_axis: int = 0) -> jnp.ndarray:
    """Streaming all-to-all (MoE dispatch/combine): tile a non-exchanged
    axis into wire chunks, one ``lax.all_to_all`` per chunk.

    Chunk *i*'s exchange carries no data dependency on chunk *i+1*'s
    (unordered transport), so the latency-hiding scheduler overlaps the
    chunks' transfers with each other and with the consumer's per-chunk
    work; ordered transport chains chunk *i* on chunk *i − window* (ack
    window).  Values are bitwise-identical to the single fused all-to-all —
    tiling a non-split axis only partitions pure data movement.  Falls back
    to one call when no tileable axis exists (1-D payloads) or the message
    fits a single chunk.
    """
    axis_names = comm.axis_names if hasattr(comm, "axis_names") else comm

    def one(t: jnp.ndarray) -> jnp.ndarray:
        if cfg.compression != Compression.NONE and cfg.enable_compression_plugin:
            orig = t.dtype
            y = lax.all_to_all(t.astype(jnp.bfloat16), axis_names,
                               split_axis=split_axis, concat_axis=concat_axis,
                               tiled=True)
            return y.astype(orig)
        return lax.all_to_all(t, axis_names, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    tile_axis = next((a for a in range(x.ndim - 1, -1, -1)
                      if a not in (split_axis % x.ndim, concat_axis % x.ndim)),
                     None)
    if tile_axis is None:
        return one(x)
    n = min(num_chunks(x.size * x.dtype.itemsize, cfg), x.shape[tile_axis])
    if n <= 1:
        return one(x)
    dim = x.shape[tile_axis]
    width = math.ceil(dim / n)
    outs: list[jnp.ndarray] = []
    for i, start in enumerate(range(0, dim, width)):
        sl = lax.slice_in_dim(x, start, min(start + width, dim), axis=tile_axis)
        if cfg.transport == Transport.ORDERED and i >= cfg.window:
            sl, _ = lax.optimization_barrier((sl, outs[i - cfg.window]))
        outs.append(one(sl))
    return jnp.concatenate(outs, axis=tile_axis)
