"""ACCL-X collectives — MPI-like operations over mesh axes.

Two algorithm families, selected by ``CommConfig.algorithm``:

- ``native`` — XLA built-ins (``psum``/``all_gather``/``psum_scatter``/
  ``all_to_all``).  Fastest path when no wire-format control is needed.
- ``ring``   — explicit ``ppermute`` ring algorithms (the CCLO analogue).
  Required for wire compression (int8/bf16 payloads) and for transport/window
  experiments, because XLA built-ins cannot carry a custom wire format.

All functions are SPMD: call them inside ``shard_map`` with the communicator's
axes in scope.  Point-to-point ops take explicit (src, dst) edge lists, as the
shallow-water halo exchange does (paper §4.1).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.communicator import Communicator
from repro.core.config import (CommConfig, CommMode, Compression, Scheduling,
                               Transport)
from repro.core import plans, plugins, streaming, topology
from repro.obs import metrics as obs_metrics, trace as obs_trace


def _nbytes(x) -> int:
    """Static per-rank byte count of a (possibly traced) payload."""
    try:
        return int(x.size) * int(x.dtype.itemsize)
    except (AttributeError, TypeError):
        return 0


def _record_edges(comm: Communicator, perm, nbytes: int) -> None:
    """Per-edge byte accounting: every edge moves ``nbytes``, counted under
    its torus hop distance (the per-edge axis of the paper's Fig. 9)."""
    reg = obs_metrics.registry()
    reg.counter("comm.bytes").inc(nbytes * len(perm))
    for s, d in perm:
        reg.counter("comm.edge_bytes",
                    hops=comm.torus_hops(int(s), int(d))).inc(nbytes)


def resolve_config(cfg, collective: str = "all_reduce",
                   msg_bytes: int = 1 << 20, mesh=None,
                   db_path=None, hops: int | None = None,
                   objective: str = "latency",
                   torus: str | None = None,
                   consumer: str | None = None) -> CommConfig:
    """Resolve a ``CommConfig | "auto" | None`` to a concrete config.

    ``"auto"`` asks the autotuner (:func:`repro.tune.select_config`) for the
    fastest *measured* config for this collective/size/topology, falling back
    to ``OPTIMIZED_CONFIG`` on a cold cache.  ``hops`` is the worst-case torus
    hop distance of the communication pattern (``Communicator.torus_hops``) —
    multi-hop edges prefer configs measured at the same distance (the paper's
    direct-link vs Ethernet-switch distinction).  ``objective="e2e"`` ranks
    by the measured consumer-loop time instead of bare collective latency
    (§5: what wins the microbench is not what scales the application);
    ``consumer`` names which consumer loop's measurements to prefer
    ("decode_step" vs "prefill" vs "row_parallel" — serving's phases
    resolve different configs from the same TuneDB).
    Host-side only — call it before tracing, never inside ``shard_map``.
    """
    if isinstance(cfg, CommConfig):
        return cfg
    if cfg is None or cfg == "auto":
        from repro.tune import select_config
        return select_config(collective, msg_bytes, mesh=mesh, path=db_path,
                             hops=hops, objective=objective, torus=torus,
                             consumer=consumer)
    raise TypeError(f"comm config must be CommConfig or 'auto', got {cfg!r}")


# ----------------------------------------------------------------------
# Point-to-point
# ----------------------------------------------------------------------

def sendrecv(x: jnp.ndarray, perm: Sequence[tuple[int, int]],
             comm: Communicator, cfg: CommConfig) -> jnp.ndarray:
    """Single send/recv along an edge list (each rank sends at most once).

    On a communicator placed on a virtual torus
    (:class:`~repro.core.topology.TorusSpec`) every multi-hop edge is routed:
    the transfer physically executes one single-hop permute per torus hop
    (store-and-forward through the intermediate ranks), value-identical to
    the direct permute.
    """
    perm = plans.validated_perm(comm, perm)
    nbytes = _nbytes(x)
    hops = comm.max_hops(perm)
    _record_edges(comm, perm, nbytes)
    perm = topology.routed_perm(comm, perm)
    with obs_trace.span("sendrecv", cat="collective", nbytes=nbytes,
                        hops=hops, edges=len(perm.edges)
                        if isinstance(perm, topology.RoutedPerm)
                        else len(perm),
                        mode=cfg.mode, transport=cfg.transport,
                        scheduling=cfg.scheduling,
                        reliability=cfg.reliability):
        if cfg.mode == CommMode.STREAMING:
            return streaming.chunked_permute(x, perm, comm.axis, cfg)
        return streaming.buffered_permute(x, perm, comm.axis, cfg)


def edge_color_rounds(edges: Sequence[tuple[int, int]]):
    """Greedily color a multi-neighbor exchange into ppermute-able rounds.

    Each round is a valid permutation fragment: every rank appears at most
    once as source and once as destination.  The number of rounds is the
    N_max of Eq. 3 — each neighbor costs one more scheduled command.
    Derived once per edge list and replayed from the plan cache.
    """
    return plans.edge_rounds(edges)


def multi_neighbor_exchange(payloads: Sequence[jnp.ndarray],
                            rounds: Sequence[Sequence[tuple[int, int]]],
                            comm: Communicator, cfg,
                            consume=None, init=None,
                            chunk_consume=None, chunk_align: int = 1):
    """Halo exchange with several neighbors: one sendrecv per round.

    ``payloads[r]`` is this rank's message for round ``r`` (ranks not sending
    in a round pass a dummy of the same shape).  Unordered transport leaves
    rounds independent (they overlap); ordered transport chains them.
    Overlapped scheduling routes through the double-buffered engine: rounds
    alternate between two buffers and the ordered ack chain runs per buffer,
    so a consumer can fold one buffer while the other is in flight.

    ``cfg`` may be a sequence of per-round configs (the SWE driver's
    per-edge hop-aware selection: each round's edges share a hop distance
    and get the config tuned for it).  Per-round configs apply to the
    serially scheduled path; the double-buffered overlapped engine pipelines
    all rounds as one schedule and requires a uniform config.

    Overlapped scheduling additionally accepts the engine's consume hooks:
    ``consume(carry, round, message)`` folds whole rounds, and
    ``chunk_consume(carry, round, chunk_index, chunk)`` folds each
    ``chunk_align``-aligned wire chunk as it lands (chunk-level halo
    consume — see :func:`repro.core.streaming.double_buffered_exchange`).
    When either hook is given the return value is ``(carry, received)``;
    otherwise just ``received`` (round order).
    """
    round_cfgs = None
    if not isinstance(cfg, CommConfig):
        round_cfgs = list(cfg)
        if len(round_cfgs) != len(rounds):
            raise ValueError(f"{len(round_cfgs)} per-round configs for "
                             f"{len(rounds)} rounds")
        # Degenerate empty pattern: behave like the uniform-config call
        # (no rounds means no config is ever consulted).
        cfg = round_cfgs[0] if round_cfgs else CommConfig()
    obs_metrics.registry().counter("comm.exchange_rounds").inc(len(rounds))
    exchange_span = obs_trace.span(
        "multi_neighbor", cat="collective", rounds=len(rounds),
        hops=comm.max_hops([e for r in rounds for e in r]),
        nbytes=_nbytes(payloads[0]) if payloads else 0,
        mode=cfg.mode, transport=cfg.transport, scheduling=cfg.scheduling,
        reliability=cfg.reliability)
    if cfg.scheduling == Scheduling.OVERLAPPED:
        if round_cfgs is not None and any(c != cfg for c in round_cfgs):
            raise ValueError(
                "per-round configs require serial scheduling; the "
                "double-buffered overlapped engine pipelines all rounds "
                "under one config")
        # One CommPlan per (pattern, config, payload): the round structure is
        # validated once and replayed, and the chunk/ack layout it caches is
        # what pipelined_consume replays per round.
        if payloads:
            plan = plans.get_plan("multi_neighbor", comm, cfg,
                                  payloads[0].shape, payloads[0].dtype,
                                  align=chunk_align, rounds=rounds)
            rounds = list(plan.perms)
        else:
            # no payload to key a plan on, but malformed rounds must still
            # be rejected, as they always were
            rounds = [plans.validated_perm(comm, perm) for perm in rounds]
        # Virtual-torus lowering happens per round inside the engine so the
        # double-buffered ack chain still runs per buffer.
        rounds = [topology.routed_perm(comm, perm) for perm in rounds]
        with exchange_span:
            carry, received = streaming.double_buffered_exchange(
                payloads, rounds, comm.axis, cfg, consume=consume, init=init,
                chunk_consume=chunk_consume, chunk_align=chunk_align)
        if consume is not None or chunk_consume is not None:
            return carry, received
        return received
    received = []
    prev = None
    with exchange_span:
        for r, (payload, perm) in enumerate(zip(payloads, rounds)):
            rcfg = round_cfgs[r] if round_cfgs is not None else cfg
            if rcfg.transport == Transport.ORDERED and prev is not None:
                payload, _ = lax.optimization_barrier((payload, prev))
            out = sendrecv(payload, perm, comm, rcfg)
            received.append(out)
            prev = out
    return received


# ----------------------------------------------------------------------
# Ring collectives (explicit ppermute algorithms; support wire compression)
# ----------------------------------------------------------------------

def _ring_send(payload: jnp.ndarray, comm: Communicator, cfg: CommConfig) -> jnp.ndarray:
    """One ring hop with wire encoding.  On a virtual torus the rank ring's
    multi-hop edges (e.g. row-major wraps) are routed through the fabric —
    place ranks with ``topology.snake_placement`` for an all-hop-1 ring."""
    enc, dec = plugins.wire_encode(payload, cfg)
    perm = topology.routed_perm(comm, comm.ring_perm())
    out = jax.tree.map(
        lambda t: streaming.wire_permute(t, comm.axis, perm), enc)
    return dec(out)


def ring_all_reduce(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
                    op: str = "sum") -> jnp.ndarray:
    """Ring all-reduce = reduce-scatter phase + all-gather phase.

    2·(n−1) ppermute steps moving 2·(n−1)/n of the data per rank — the
    bandwidth-optimal schedule ACCL's CCLO implements.  With int8 wire format
    the bytes-on-wire shrink 4x (compression plugin).
    """
    n = comm.size
    if n == 1:
        return x
    reducer = plugins.reduce_op(op, cfg)
    d = comm.rank()
    flat = x.reshape(-1)
    orig_size = flat.shape[0]
    pad = (-orig_size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    acc = flat.reshape(n, -1)
    if acc.dtype in (jnp.bfloat16, jnp.float16):
        acc = acc.astype(jnp.float32)

    # Phase 1: reduce-scatter. After n-1 steps rank d holds the fully reduced
    # segment (d+1) mod n.
    for t in range(n - 1):
        send_idx = (d - t) % n
        payload = jnp.take(acc, send_idx, axis=0)
        recvd = _ring_send(payload, comm, cfg)
        recv_idx = (d - 1 - t) % n
        updated = reducer(jnp.take(acc, recv_idx, axis=0), recvd)
        acc = lax.dynamic_update_index_in_dim(acc, updated, recv_idx, axis=0)

    my_idx = (d + 1) % n
    cur = jnp.take(acc, my_idx, axis=0)
    out = jnp.zeros_like(acc)
    out = lax.dynamic_update_index_in_dim(out, cur, my_idx, axis=0)

    # Phase 2: all-gather the reduced segments around the ring.
    for t in range(n - 1):
        recvd = _ring_send(cur, comm, cfg)
        idx = (d - t) % n
        out = lax.dynamic_update_index_in_dim(out, recvd, idx, axis=0)
        cur = recvd

    return out.reshape(-1)[:orig_size].reshape(x.shape).astype(x.dtype)


def ring_all_gather(x: jnp.ndarray, comm: Communicator, cfg: CommConfig) -> jnp.ndarray:
    """Ring all-gather; returns (n, *x.shape) stacked by source rank."""
    n = comm.size
    if n == 1:
        return x[None]
    d = comm.rank()
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, d, axis=0)
    cur = x
    for t in range(n - 1):
        recvd = _ring_send(cur, comm, cfg)
        idx = (d - 1 - t) % n
        out = lax.dynamic_update_index_in_dim(out, recvd, idx, axis=0)
        cur = recvd
    return out


def ring_reduce_scatter(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
                        op: str = "sum") -> jnp.ndarray:
    """Reduce-scatter over leading dim (must divide by comm.size)."""
    n = comm.size
    if n == 1:
        return x
    assert x.shape[0] % n == 0, f"leading dim {x.shape[0]} not divisible by {n}"
    reducer = plugins.reduce_op(op, cfg)
    d = comm.rank()
    acc = x.reshape(n, x.shape[0] // n, *x.shape[1:])
    if acc.dtype in (jnp.bfloat16, jnp.float16):
        acc = acc.astype(jnp.float32)
    # Ring offset chosen so rank d finishes holding fully reduced segment d.
    for t in range(n - 1):
        send_idx = (d - t - 1) % n
        payload = jnp.take(acc, send_idx, axis=0)
        recvd = _ring_send(payload, comm, cfg)
        recv_idx = (d - t - 2) % n
        updated = reducer(jnp.take(acc, recv_idx, axis=0), recvd)
        acc = lax.dynamic_update_index_in_dim(acc, updated, recv_idx, axis=0)
    return jnp.take(acc, d, axis=0).astype(x.dtype)


# ----------------------------------------------------------------------
# Dispatching wrappers
# ----------------------------------------------------------------------

def _all_reduce_sum_fwd(x, comm: Communicator, cfg: CommConfig):
    if cfg.algorithm == "ring" and comm.single_axis and comm.size > 1:
        return ring_all_reduce(x, comm, cfg, "sum")
    if cfg.compression == Compression.BF16:
        enc, dec = plugins.wire_encode(x, cfg)
        return dec(lax.psum(enc, comm.axis_names))
    return lax.psum(x, comm.axis_names)


def all_reduce(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
               op: str = "sum") -> jnp.ndarray:
    """All-reduce with *replicated-output* gradient semantics.

    This framework maintains replication invariants manually (the Megatron
    f/g operator scheme): the output of a forward all-reduce is replicated,
    so its true VJP is the identity — every rank's cotangent already equals
    the logical cotangent.  shard_map's default transpose (psum again, or the
    ring algorithm's permute chain) would compound a tp× factor per combine.
    """
    with obs_trace.span("all_reduce", cat="collective", op=op,
                        nbytes=_nbytes(x), algorithm=cfg.algorithm,
                        mode=cfg.mode, transport=cfg.transport,
                        scheduling=cfg.scheduling,
                        reliability=cfg.reliability,
                        hops=comm.max_hops(comm.ring_perm())
                        if cfg.algorithm == "ring" and comm.single_axis
                        else 1):
        if op == "sum":
            @jax.custom_vjp
            def f(v):
                return _all_reduce_sum_fwd(v, comm, cfg)

            def fwd(v):
                return _all_reduce_sum_fwd(v, comm, cfg), None

            def bwd(_, ct):
                return (ct,)

            f.defvjp(fwd, bwd)
            return f(x)
        if cfg.algorithm == "ring" and comm.single_axis:
            return ring_all_reduce(x, comm, cfg, op)
        if op == "max":
            return lax.pmax(x, comm.axis_names)
        if op == "min":
            return lax.pmin(x, comm.axis_names)
        raise ValueError(f"native all_reduce does not support op={op}")


def all_gather(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
               axis: int = 0, tiled: bool = True) -> jnp.ndarray:
    with obs_trace.span("all_gather", cat="collective", nbytes=_nbytes(x),
                        algorithm=cfg.algorithm, mode=cfg.mode,
                        transport=cfg.transport, scheduling=cfg.scheduling,
                        reliability=cfg.reliability):
        if cfg.algorithm == "ring" and comm.single_axis:
            stacked = ring_all_gather(x, comm, cfg)
            if not tiled:
                return stacked
            n = comm.size
            parts = [jnp.take(stacked, i, axis=0) for i in range(n)]
            return jnp.concatenate(parts, axis=axis)
        return lax.all_gather(x, comm.axis_names, axis=axis, tiled=tiled)


def reduce_scatter(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
                   op: str = "sum") -> jnp.ndarray:
    with obs_trace.span("reduce_scatter", cat="collective",
                        nbytes=_nbytes(x), algorithm=cfg.algorithm,
                        mode=cfg.mode, transport=cfg.transport,
                        scheduling=cfg.scheduling,
                        reliability=cfg.reliability):
        if cfg.algorithm == "ring" and comm.single_axis:
            return ring_reduce_scatter(x, comm, cfg, op)
        assert op == "sum"
        return lax.psum_scatter(x, comm.axis_names, scatter_dimension=0,
                                tiled=True)


def all_to_all(x: jnp.ndarray, comm: Communicator, cfg: CommConfig,
               split_axis: int = 0, concat_axis: int = 0) -> jnp.ndarray:
    """All-to-all (MoE dispatch). Wire compression via bf16 cast if enabled.

    Overlapped scheduling with streaming delivery tiles the message into
    independent wire chunks (:func:`repro.core.streaming.chunked_all_to_all`)
    so the dispatch/combine overlaps its own transfer — bitwise-identical
    to the fused op.
    """
    with obs_trace.span("all_to_all", cat="collective", nbytes=_nbytes(x),
                        mode=cfg.mode, transport=cfg.transport,
                        scheduling=cfg.scheduling,
                        reliability=cfg.reliability):
        if (cfg.scheduling == Scheduling.OVERLAPPED
                and cfg.mode == CommMode.STREAMING):
            return streaming.chunked_all_to_all(x, comm, cfg, split_axis,
                                                concat_axis)
        if (cfg.compression != Compression.NONE
                and cfg.enable_compression_plugin):
            orig = x.dtype
            y = lax.all_to_all(x.astype(jnp.bfloat16), comm.axis_names,
                               split_axis=split_axis, concat_axis=concat_axis,
                               tiled=True)
            return y.astype(orig)
        return lax.all_to_all(x, comm.axis_names, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def broadcast(x: jnp.ndarray, root: int, comm: Communicator,
              cfg: CommConfig) -> jnp.ndarray:
    """Broadcast from ``root`` (one-to-all)."""
    d = comm.rank()
    masked = jnp.where(d == root, x, jnp.zeros_like(x))
    return all_reduce(masked, comm, cfg, op="sum")


def hierarchical_all_reduce(x: jnp.ndarray, inner: Communicator,
                            outer: Communicator, cfg: CommConfig) -> jnp.ndarray:
    """Cross-pod all-reduce: RS in-pod (ICI) → AR across pods (DCN) → AG in-pod.

    Moves 1/n_inner of the data over the slow outer links — the torus version
    of the paper's switch-topology tuning.  Requires leading dim divisible by
    the inner size; falls back to flat psum otherwise.
    """
    with obs_trace.span("hierarchical_all_reduce", cat="collective",
                        nbytes=_nbytes(x), inner=inner.size,
                        outer=outer.size, mode=cfg.mode,
                        transport=cfg.transport,
                        scheduling=cfg.scheduling,
                        reliability=cfg.reliability):
        flat = x.reshape(-1)
        n = inner.size
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        seg = reduce_scatter(flat, inner, cfg)
        seg = all_reduce(seg, outer, cfg)
        full = all_gather(seg, inner, cfg, axis=0, tiled=True)
        return full[: x.size].reshape(x.shape)
