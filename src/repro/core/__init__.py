"""ACCL-X — the paper's communication framework, adapted to TPU/JAX.

Public API:
    CommConfig / CommMode / Scheduling / Transport / Compression / HardwareSpec
    Communicator
    collectives: sendrecv, multi_neighbor_exchange, all_reduce, all_gather,
                 reduce_scatter, all_to_all, broadcast, hierarchical_all_reduce,
                 resolve_config ("auto" -> autotuned CommConfig via repro.tune)
    streaming:   chunked_permute, buffered_permute, pipelined_consume,
                 double_buffered_exchange, overlapped_matmul_allreduce,
                 chunked_all_to_all
    latmodel:    pingping_latency, eq2_throughput, eq3_l_comm, roofline_terms
    plans:       CommPlan cache (schedules derived once, replayed per call)
    topology:    TorusSpec virtual multi-hop torus placement + routed transport
    scheduler:   HostScheduledRunner, FusedRunner, make_runner
"""
from repro.core.config import (
    BASELINE_CONFIG, MINIMAL_CONFIG, OPTIMIZED_CONFIG, V5E,
    CommConfig, CommMode, Compression, HardwareSpec, Scheduling, Transport,
)
from repro.core.communicator import Communicator
from repro.core.topology import TorusSpec
from repro.core import (collectives, latmodel, plans, plugins, scheduler,
                        streaming, topology)

__all__ = [
    "BASELINE_CONFIG", "MINIMAL_CONFIG", "OPTIMIZED_CONFIG", "V5E",
    "CommConfig", "CommMode", "Compression", "HardwareSpec", "Scheduling",
    "Transport", "Communicator", "TorusSpec", "collectives", "latmodel",
    "plans", "plugins", "scheduler", "streaming", "topology",
]
