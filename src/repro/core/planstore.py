"""Disk-backed CommPlan/program store — warm starts across processes.

The in-process :mod:`repro.core.plans` cache reproduces the ACCL+ resident
plan store and wins ~35x on warm sweeps, but every new CLI invocation, CI
job, and serving replica starts cold: full rebuild of every schedule plus a
full XLA recompile of every program.  This module is the persistence layer
that closes that gap — a versioned, crash-safe, shared directory of plan
entries keyed by the exact same value scheme the in-memory cache uses:

- **Plan entries** (chunk layouts, edge-color rounds, ring/neighbor perms,
  aggregate :class:`~repro.core.plans.CommPlan`) serialize to one small JSON
  file each under ``<dir>/plans/``.  Keys are canonicalized to pure JSON
  primitives (``plans._cfg_key`` stamps a schema version and folds enum
  members to their string values) and hashed into the filename; the full key
  is stored in the entry and checked on read, so a hash collision or a
  recycled file can never answer the wrong lookup.
- **Traced programs** persist two ways.  Host-level programs whose example
  arguments are known at build time (the sweep's jitted microbenchmarks,
  via ``plans.jitted_program(..., example_args=...)``) are AOT-compiled and
  serialized whole (``jax.experimental.serialize_executable``) under
  ``<dir>/programs/`` — a fresh process deserializes and runs, paying
  neither trace nor compile.  Everything else goes through **JAX's
  persistent compilation cache**: activating a store points
  ``jax_compilation_cache_dir`` at ``<dir>/xla-cache/`` (with the
  min-size/min-time thresholds dropped so every program qualifies), so a
  fresh process re-traces but replays the expensive XLA compile from disk.

Durability contract:

- **Atomic writes** — entries are written to a unique temp file in the same
  directory and ``os.replace``d into place; a reader never observes a torn
  entry, and two processes racing the same key both land a valid file (last
  writer wins with identical content).
- **Corrupt/stale entries are misses, never crashes** — unparseable JSON, a
  schema-version mismatch, a key mismatch, or an undecodable value all count
  ``plans.disk_misses`` (and ``plans.disk_corrupt``), best-effort unlink the
  bad file, and let the caller rebuild and overwrite.
- **Versioning** — every entry embeds :data:`SCHEMA_VERSION`; bumping it (or
  the ``plans._cfg_key`` schema stamp) invalidates the whole store in place
  without a migration step.

Activation: set ``REPRO_PLAN_DIR=/path`` (picked up lazily, survives the
sweep CLI's re-exec) or call :func:`configure` (the ``--plan-dir`` CLI
flags).  When no directory is configured the module is inert and the plan
cache behaves exactly as before — memory-only.

Counters (in the :mod:`repro.obs.metrics` registry): ``plans.disk_hits``,
``plans.disk_misses``, ``plans.disk_writes``, ``plans.disk_corrupt``.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Any, Optional

from repro.obs import metrics as obs_metrics

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_PLAN_DIR"

# plans._memo kinds whose values serialize to JSON and persist here.
# "program" (compiled callables) is deliberately absent: it persists through
# the JAX compilation cache wired by _wire_jax_cache instead.
DISK_KINDS = frozenset({"chunks", "rounds", "ring", "perm", "plan", "wire"})

#: Sentinel returned by :meth:`PlanStore.get` when no usable entry exists
#: (distinct from a legitimately-cached ``None`` value).
MISSING = object()

_LOCK = threading.RLock()
_OVERRIDE: Optional[str] = None      # configure() override; None = env rules
_EXPLICIT = False                    # configure() was called (even with "")
_STORES: dict[str, "PlanStore"] = {}
_WIRED_DIRS: set[str] = set()

_DISK_STAT_NAMES = ("disk_hits", "disk_misses", "disk_writes", "disk_corrupt")
_DISK_STATS = {k: obs_metrics.registry().counter(f"plans.{k}")
               for k in _DISK_STAT_NAMES}


def configure(path: os.PathLike | str | None, wire_jax: bool = True
              ) -> Optional[Path]:
    """Explicitly set the store directory (CLI ``--plan-dir``).

    ``path=None`` clears the override so ``REPRO_PLAN_DIR`` governs again;
    ``path=""`` disables the store even when the env var is set.  Returns
    the resolved directory (None when disabled).  ``wire_jax=False`` skips
    pointing JAX's compilation cache at the store (unit tests that must not
    mutate global jax config).
    """
    global _OVERRIDE, _EXPLICIT
    with _LOCK:
        _OVERRIDE = str(path) if path is not None else None
        _EXPLICIT = path is not None
    store = active(wire_jax=wire_jax)
    return store.root if store is not None else None


def plan_dir() -> Optional[Path]:
    """The configured store directory: explicit :func:`configure` override
    first, then ``REPRO_PLAN_DIR``; None when neither is set."""
    with _LOCK:
        if _EXPLICIT:
            return Path(_OVERRIDE) if _OVERRIDE else None
    env = os.environ.get(ENV_VAR, "")
    return Path(env) if env else None


def active(wire_jax: bool = True) -> Optional["PlanStore"]:
    """The live :class:`PlanStore` for the configured directory, or None
    when persistence is off.  First activation of a directory wires the JAX
    persistent compilation cache into it (the traced-program half)."""
    d = plan_dir()
    if d is None:
        return None
    key = str(d)
    with _LOCK:
        store = _STORES.get(key)
        if store is None:
            store = _STORES[key] = PlanStore(d)
        if wire_jax and key not in _WIRED_DIRS:
            _WIRED_DIRS.add(key)
            _wire_jax_cache(d)
    return store


def disk_stats() -> dict:
    """Current ``plans.disk_*`` counter values."""
    return {k: int(c.value) for k, c in _DISK_STATS.items()}


def reset_disk_stats() -> None:
    for c in _DISK_STATS.values():
        c.reset()


def _wire_jax_cache(root: Path) -> None:
    """Point JAX's persistent compilation cache at ``<root>/xla-cache`` so
    traced programs (the sweep's jitted microbenchmarks, the driver's step
    programs) skip XLA compilation in every later process.  Thresholds are
    dropped to zero so the small host-CPU programs of the emulated runs
    qualify.  Best-effort: an old jax without a knob just skips it."""
    try:
        import jax
    except Exception:  # noqa: BLE001 — store stays usable for plan entries
        return
    cache_dir = root / "xla-cache"
    try:
        cache_dir.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    except Exception:  # noqa: BLE001
        return
    for name, value in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                        ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(name, value)
        except Exception:  # noqa: BLE001
            pass


# ----------------------------------------------------------------------
# Key canonicalization
# ----------------------------------------------------------------------

def canonical_key(key: Any) -> str:
    """Deterministic JSON encoding of a plan key.

    Keys are nested tuples of JSON primitives (the ``plans._cfg_key``
    canonicalization guarantees no enum objects leak in); tuples become
    lists.  Anything else raises ``TypeError`` — the caller treats the key
    as non-persistable and stays memory-only rather than writing a lossy
    entry."""
    return json.dumps(_jsonable_key(key), separators=(",", ":"),
                      allow_nan=False)


def _jsonable_key(obj: Any) -> Any:
    if isinstance(obj, (list, tuple)):
        return [_jsonable_key(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"non-serializable plan-key component: {obj!r} "
                    f"({type(obj).__name__})")


def _tuplify(obj: Any) -> Any:
    """Inverse of :func:`_jsonable_key` for values: JSON lists back to the
    tuples the in-memory cache stores."""
    if isinstance(obj, list):
        return tuple(_tuplify(v) for v in obj)
    return obj


# ----------------------------------------------------------------------
# Value (de)serialization per kind
# ----------------------------------------------------------------------

def _encode_value(kind: str, value: Any) -> Any:
    if kind == "chunks":
        return {"n_chunks": value.n_chunks, "chunk_elems": value.chunk_elems,
                "ack_of": list(value.ack_of)}
    if kind == "wire":
        return {"n_chunks": value.n_chunks,
                "slots": [[s.seq, s.action, s.attempt] for s in value.slots],
                "retransmits": value.retransmits,
                "dup_dropped": value.dup_dropped,
                "timeouts": value.timeouts,
                "backoff_holds": value.backoff_holds}
    if kind == "plan":
        chunks = None
        if value.chunks is not None:
            chunks = _encode_value("chunks", value.chunks)
        return {"collective": value.collective,
                "comm_key": _jsonable_key(value.comm_key),
                "cfg_key": _jsonable_key(value.cfg_key),
                "shape": list(value.shape), "dtype": value.dtype,
                "chunks": chunks, "rounds": _jsonable_key(value.rounds),
                "perms": _jsonable_key(value.perms),
                "ring": _jsonable_key(value.ring),
                "extra": _jsonable_key(value.extra)}
    # rounds / ring / perm: nested tuples of ints
    return _jsonable_key(value)


def _decode_value(kind: str, payload: Any) -> Any:
    from repro.core import plans
    if kind == "chunks":
        return plans.ChunkPlan(n_chunks=int(payload["n_chunks"]),
                               chunk_elems=int(payload["chunk_elems"]),
                               ack_of=tuple(int(a) for a in payload["ack_of"]))
    if kind == "wire":
        from repro.core import reliable
        return reliable.DeliveryPlan(
            n_chunks=int(payload["n_chunks"]),
            slots=tuple(reliable.Slot(int(s), str(a), int(k))
                        for s, a, k in payload["slots"]),
            retransmits=int(payload["retransmits"]),
            dup_dropped=int(payload["dup_dropped"]),
            timeouts=int(payload["timeouts"]),
            backoff_holds=int(payload["backoff_holds"]))
    if kind == "plan":
        chunks = (None if payload["chunks"] is None
                  else _decode_value("chunks", payload["chunks"]))
        return plans.CommPlan(
            collective=payload["collective"],
            comm_key=_tuplify(payload["comm_key"]),
            cfg_key=_tuplify(payload["cfg_key"]),
            shape=tuple(int(s) for s in payload["shape"]),
            dtype=payload["dtype"], chunks=chunks,
            rounds=_tuplify(payload["rounds"]),
            perms=_tuplify(payload["perms"]),
            ring=_tuplify(payload["ring"]),
            extra=_tuplify(payload["extra"]))
    return _tuplify(payload)


# ----------------------------------------------------------------------
# The store
# ----------------------------------------------------------------------

class PlanStore:
    """One plan directory: JSON plan entries + the XLA compilation cache.

    Thread-safe within a process (the module lock covers filesystem ops);
    cross-process safety comes from atomic replace-on-write — concurrent
    writers of one key both produce a valid file, readers see old or new,
    never torn."""

    def __init__(self, root: os.PathLike | str):
        self.root = Path(root)
        self.plans_path = self.root / "plans"
        self.programs_path = self.root / "programs"

    def _entry_path(self, kind: str, canon: str) -> Path:
        digest = hashlib.sha256(
            f"{kind}\x00{canon}".encode()).hexdigest()[:32]
        return self.plans_path / f"{kind}-{digest}.json"

    def get(self, kind: str, key: Any) -> Any:
        """The stored value for ``(kind, key)``, or :data:`MISSING`.

        Every failure mode — absent file, torn/corrupt JSON, schema-version
        mismatch, key mismatch, undecodable value — is a miss: the bad file
        is best-effort removed and the caller rebuilds and overwrites."""
        try:
            canon = canonical_key(key)
        except TypeError:
            return MISSING
        path = self._entry_path(kind, canon)
        try:
            raw = path.read_text()
        except (OSError, UnicodeDecodeError):
            _DISK_STATS["disk_misses"].inc()
            return MISSING
        try:
            entry = json.loads(raw)
            if (entry.get("schema") != SCHEMA_VERSION
                    or entry.get("kind") != kind
                    or entry.get("key") != json.loads(canon)):
                raise ValueError("stale or mismatched entry")
            value = _decode_value(kind, entry["value"])
        except Exception:  # noqa: BLE001 — any bad entry is a rebuildable miss
            _DISK_STATS["disk_corrupt"].inc()
            _DISK_STATS["disk_misses"].inc()
            try:
                path.unlink()
            except OSError:
                pass
            return MISSING
        _DISK_STATS["disk_hits"].inc()
        return value

    def put(self, kind: str, key: Any, value: Any) -> bool:
        """Persist ``value`` under ``(kind, key)`` atomically (write a
        unique temp file, then ``os.replace``).  Returns False — without
        raising — when the key/value is not serializable or the filesystem
        refuses; persistence is an optimization, never a failure source."""
        try:
            canon = canonical_key(key)
            payload = {"schema": SCHEMA_VERSION, "kind": kind,
                       "key": json.loads(canon),
                       "value": _encode_value(kind, value)}
            blob = json.dumps(payload, separators=(",", ":"),
                              allow_nan=False)
        except (TypeError, ValueError, AttributeError):
            return False
        path = self._entry_path(kind, canon)
        tmp = path.with_name(
            f"{path.name}.{os.getpid()}.{threading.get_ident()}.tmp")
        try:
            self.plans_path.mkdir(parents=True, exist_ok=True)
            tmp.write_text(blob)
            os.replace(tmp, path)
        except OSError:
            try:
                tmp.unlink()
            except OSError:
                pass
            return False
        _DISK_STATS["disk_writes"].inc()
        return True

    # ------------------------------------------------------------------
    # Serialized executables (the traced-program half)
    # ------------------------------------------------------------------
    def _exec_path(self, canon: str) -> Path:
        digest = hashlib.sha256(f"xprog\x00{canon}".encode()).hexdigest()[:32]
        return self.programs_path / f"program-{digest}.pkl"

    def get_executable(self, key: Any) -> Any:
        """Deserialize + load a persisted compiled program for ``key``, or
        :data:`MISSING`.  The loaded executable replays with zero trace and
        zero compile — the ACCL+ precompiled-plan restart.  Any failure
        (absent, torn, version-mismatched, device-mismatched, old-jax pickle
        drift) is a rebuildable miss."""
        import pickle
        try:
            canon = canonical_key(key)
        except TypeError:
            return MISSING
        path = self._exec_path(canon)
        if not path.exists():
            _DISK_STATS["disk_misses"].inc()
            return MISSING
        try:
            from jax.experimental import serialize_executable
            with path.open("rb") as f:
                entry = pickle.load(f)
            if (entry.get("schema") != SCHEMA_VERSION
                    or entry.get("key") != canon):
                raise ValueError("stale or mismatched program entry")
            compiled = serialize_executable.deserialize_and_load(
                *entry["payload"])
        except Exception:  # noqa: BLE001 — any bad program is a miss
            _DISK_STATS["disk_corrupt"].inc()
            _DISK_STATS["disk_misses"].inc()
            try:
                path.unlink()
            except OSError:
                pass
            return MISSING
        _DISK_STATS["disk_hits"].inc()
        return compiled

    def put_executable(self, key: Any, compiled: Any) -> bool:
        """Serialize an AOT-compiled program (``jax.jit(f).lower(...)
        .compile()`` result) atomically.  Returns False when the backend
        cannot serialize executables or the key is non-canonical."""
        import pickle
        try:
            canon = canonical_key(key)
            from jax.experimental import serialize_executable
            payload = serialize_executable.serialize(compiled)
            blob = pickle.dumps({"schema": SCHEMA_VERSION, "key": canon,
                                 "payload": payload})
        except Exception:  # noqa: BLE001 — persistence never raises
            return False
        path = self._exec_path(canon)
        tmp = path.with_name(
            f"{path.name}.{os.getpid()}.{threading.get_ident()}.tmp")
        try:
            self.programs_path.mkdir(parents=True, exist_ok=True)
            tmp.write_bytes(blob)
            os.replace(tmp, path)
        except OSError:
            try:
                tmp.unlink()
            except OSError:
                pass
            return False
        _DISK_STATS["disk_writes"].inc()
        return True

    def entry_count(self) -> int:
        try:
            return (sum(1 for _ in self.plans_path.glob("*.json"))
                    + sum(1 for _ in self.programs_path.glob("*.pkl")))
        except OSError:
            return 0

    def clear(self) -> None:
        """Delete every plan and program entry (the XLA compilation cache
        is left to jax)."""
        for pattern, root in (("*.json", self.plans_path),
                              ("*.pkl", self.programs_path)):
            try:
                for p in root.glob(pattern):
                    try:
                        p.unlink()
                    except OSError:
                        pass
            except OSError:
                pass
