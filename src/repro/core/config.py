"""Configuration for the ACCL-X communication layer.

Mirrors the configuration surface of the paper:

- ``mode``       — buffered vs. streaming communication (paper §3.1).
- ``scheduling`` — host-scheduled (one dispatch per comm op, l_k ≈ 30 µs) vs.
                   fused/device-scheduled (single compiled program, l_k ≈ sub-µs);
                   the TPU analogue of host vs. PL command scheduling.
                   ``overlapped`` additionally double-buffers the halo exchange
                   so interior-element compute proceeds while the exchange is
                   in flight (paper §5: fused scheduling + streaming delivery
                   composing with the consuming kernel).
- ``transport``  — ordered ("TCP"-like: chunks form a dependency chain with an
                   ack window) vs. unordered ("UDP"-like: chunks are independent,
                   maximally async, receiver must reorder).
- ``window``     — number of in-flight chunks before the next chunk waits on an
                   ack (TCP window scaling analogue).
- ``chunk_bytes``— chunk/segment size on the wire (jumbo-frame / MSS analogue).
- plugins        — compression (quantized wire format) and arithmetic
                   (reduction ops) can be compiled out ("ACCL minimal").
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class CommMode(str, enum.Enum):
    BUFFERED = "buffered"
    STREAMING = "streaming"


class Scheduling(str, enum.Enum):
    HOST = "host"    # one jit dispatch per communication op
    FUSED = "fused"  # collectives inlined into the step program
    # Fused + double-buffered delivery: the consuming kernel is split so
    # compute that does not need the in-flight data is issued against one
    # buffer while the other buffer's transfers land (paper §5 overlap).
    OVERLAPPED = "overlapped"


class Transport(str, enum.Enum):
    ORDERED = "ordered"      # TCP-like: chunk i+window depends on chunk i
    UNORDERED = "unordered"  # UDP-like: chunks independent, any-order arrival


class Compression(str, enum.Enum):
    NONE = "none"
    INT8 = "int8"    # per-block int8 wire format (4x fewer bytes vs f32)
    BF16 = "bf16"    # wire-cast to bf16 (2x fewer bytes vs f32)


class Reliability(str, enum.Enum):
    """The paper's network-stack axis: ACCL runs over TCP (guaranteed
    delivery, retransmits priced in) or UDP (best effort, lowest latency,
    loss is the application's problem)."""
    BEST_EFFORT = "best_effort"  # UDP-like: no seq/ack/retransmit machinery
    GUARANTEED = "guaranteed"    # TCP-like: seq stamps, acks, retransmission


@dataclasses.dataclass(frozen=True)
class CommConfig:
    mode: CommMode = CommMode.STREAMING
    scheduling: Scheduling = Scheduling.FUSED
    transport: Transport = Transport.UNORDERED
    window: int = 4                    # in-flight chunks (ordered transport)
    chunk_bytes: int = 1 << 20         # 1 MiB wire chunks ("jumbo")
    max_chunks: int = 16               # cap on chunks per message (compile size)
    compression: Compression = Compression.NONE
    # Plugin build flags — "ACCL minimal" removes both (paper Fig. 3).
    enable_compression_plugin: bool = True
    enable_arithmetic_plugin: bool = True
    # Collective algorithm: "native" = XLA built-in (psum/all_gather etc.),
    # "ring" = explicit ppermute ring algorithms (the CCLO analogue — required
    # for wire compression, which XLA built-ins cannot express).
    algorithm: str = "native"
    # Quantization block size for the int8 wire format.
    quant_block: int = 256
    # Reliable-wire protocol (repro.core.reliable).  BEST_EFFORT is the
    # UDP-like default: the chunk pipeline runs with zero protocol overhead
    # and injected wire faults are unrecoverable.  GUARANTEED adds sequence
    # stamps, receiver dedup/reassembly, ack-timeout detection and capped
    # exponential backoff retransmission — each recovery step is a real
    # extra permute round with a measurable latency price.
    reliability: Reliability = Reliability.BEST_EFFORT
    ack_timeout: int = 2       # slots without an ack before a retransmit
    max_retransmits: int = 4   # attempts per chunk before the wire "relents"
    backoff_base: int = 1      # hold slots before the 1st retransmit
    backoff_cap: int = 4       # backoff ceiling in hold slots

    def __post_init__(self):
        if self.compression != Compression.NONE and not self.enable_compression_plugin:
            raise ValueError(
                "compression requested but the compression plugin was compiled "
                "out (enable_compression_plugin=False); rebuild with the plugin "
                "enabled — mirrors an ACCL 'minimal' build lacking the feature.")
        if self.compression == Compression.INT8 and self.algorithm == "native":
            raise ValueError(
                "int8 wire compression requires algorithm='ring' (XLA native "
                "collectives cannot carry a quantized wire format).")
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if self.chunk_bytes < 512:
            raise ValueError("chunk_bytes must be >= 512")
        if self.ack_timeout < 1:
            raise ValueError("ack_timeout must be >= 1 slot")
        if self.max_retransmits < 1:
            raise ValueError("max_retransmits must be >= 1 (a transport that "
                             "never retransmits is BEST_EFFORT, not a "
                             "zero-retry GUARANTEED)")
        if self.backoff_base < 0:
            raise ValueError("backoff_base must be >= 0")
        if self.backoff_cap < self.backoff_base:
            raise ValueError("backoff_cap must be >= backoff_base (the cap "
                             "bounds the exponential schedule from above)")


# Paper-faithful baseline: buffered communication scheduled from the host —
# the MPI+PCIe-style configuration of HPCC FPGA (the paper's baseline).
BASELINE_CONFIG = CommConfig(
    mode=CommMode.BUFFERED,
    scheduling=Scheduling.HOST,
    transport=Transport.ORDERED,
    window=1,
    chunk_bytes=1 << 16,
    compression=Compression.NONE,
    algorithm="native",
)

# The paper's best configuration: streaming + PL(device/fused) scheduling +
# tuned transport (window scaling + jumbo frames).
OPTIMIZED_CONFIG = CommConfig(
    mode=CommMode.STREAMING,
    scheduling=Scheduling.FUSED,
    transport=Transport.UNORDERED,
    window=8,
    chunk_bytes=1 << 20,
    compression=Compression.NONE,
    algorithm="native",
)

# The §5 configuration that scales to 48 FPGAs: streaming delivery plus an
# overlapped, double-buffered halo exchange — interior-element compute is
# issued while the boundary data is still on the wire.
OVERLAPPED_CONFIG = CommConfig(
    mode=CommMode.STREAMING,
    scheduling=Scheduling.OVERLAPPED,
    transport=Transport.UNORDERED,
    window=8,
    chunk_bytes=1 << 20,
    compression=Compression.NONE,
    algorithm="native",
)

# ACCL "minimal" build: plugins compiled out.
MINIMAL_CONFIG = CommConfig(
    mode=CommMode.STREAMING,
    scheduling=Scheduling.FUSED,
    transport=Transport.UNORDERED,
    enable_compression_plugin=False,
    enable_arithmetic_plugin=False,
)


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target chip (TPU v5e defaults).

    The paper's equivalents: link peak 12.5 GB/s (100 Gb/s QSFP), global-memory
    copy bandwidth 14 GB/s, XRT kernel launch l_k = 30 µs.
    """
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per link
    ici_latency: float = 1e-6           # s per hop (direct link)
    ici_hop_latency: float = 0.5e-6     # extra per additional torus hop
    dcn_bw: float = 25e9                # B/s per host, cross-pod
    dcn_latency: float = 10e-6
    # Command scheduling costs (the paper's l_k):
    host_dispatch: float = 30e-6        # s per host-side program dispatch
    fused_dispatch: float = 0.5e-6      # s per in-program DMA issue
    vmem_bytes: int = 128 * 1024 * 1024  # v5e VMEM per core (for kernel tiling)
    hbm_bytes: int = 16 * 1024**3


V5E = HardwareSpec()
