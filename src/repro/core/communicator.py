"""Communicator abstraction — the MPI-like layer of ACCL-X.

A :class:`Communicator` names a (sub)set of mesh axes, exactly like an MPI
communicator names a process group.  All collectives in
:mod:`repro.core.collectives` take a communicator; inside ``shard_map`` the
communicator resolves ranks with ``lax.axis_index``.

The topology helpers mirror the paper's setups:

- ``ring_perm``            — the b_eff virtual ring (paper §3.3).
- ``neighbor_perms``       — arbitrary point-to-point neighbor lists, as used
                             by the shallow-water halo exchange (paper §4.1).
- ``torus_hops``           — hop distance on the physical 2-D ICI torus, which
                             feeds the latency model's switch/hop term.
- ``topo``                 — optional :class:`~repro.core.topology.TorusSpec`
                             virtual placement: hop distances follow the
                             spec's torus coordinates and every multi-hop
                             point-to-point edge is routed (store-and-forward
                             single-hop permutes) by the transport layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax import lax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class Communicator:
    """A process group over one or more mesh axes.

    ``axis_names`` is ordered major-to-minor; rank = row-major index over the
    axis sizes, matching ``lax.axis_index(tuple)`` semantics.  ``topo``
    attaches a virtual torus placement: it changes hop *accounting* and how
    the transport physically moves multi-hop messages, never their values.
    """
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    topo: Optional["TorusSpec"] = None

    def __post_init__(self):
        if self.topo is not None and self.topo.n_ranks != self.size:
            raise ValueError(
                f"torus spec {self.topo.name} places {self.topo.n_ranks} "
                f"ranks but the communicator has {self.size}")

    @classmethod
    def from_mesh(cls, mesh: Mesh, axis_names: Sequence[str] | str,
                  topo: Optional["TorusSpec"] = None) -> "Communicator":
        if isinstance(axis_names, str):
            axis_names = (axis_names,)
        axis_names = tuple(axis_names)
        sizes = tuple(mesh.shape[a] for a in axis_names)
        return cls(axis_names=axis_names, axis_sizes=sizes, topo=topo)

    def with_topology(self, topo: Optional["TorusSpec"]) -> "Communicator":
        """The same process group placed on (or lifted off) a virtual torus."""
        return dataclasses.replace(self, topo=topo)

    @property
    def size(self) -> int:
        return math.prod(self.axis_sizes)

    @property
    def single_axis(self) -> bool:
        return len(self.axis_names) == 1

    @property
    def axis(self) -> str:
        if not self.single_axis:
            raise ValueError(f"communicator spans axes {self.axis_names}")
        return self.axis_names[0]

    def rank(self):
        """Traced rank of the calling device (inside shard_map only)."""
        r = lax.axis_index(self.axis_names[0])
        for name in self.axis_names[1:]:
            r = r * lax.axis_size(name) + lax.axis_index(name)
        return r

    def split(self, axis_name: str) -> "Communicator":
        """Sub-communicator over a single axis (MPI_Comm_split analogue)."""
        if axis_name not in self.axis_names:
            raise ValueError(f"{axis_name} not in {self.axis_names}")
        i = self.axis_names.index(axis_name)
        return Communicator((axis_name,), (self.axis_sizes[i],))

    def auto_config(self, collective: str, msg_bytes: int, db_path=None,
                    hops: int | None = None, objective: str = "latency"):
        """Autotuned ``CommConfig`` for a collective this communicator will
        run (host-side; consults the persistent TuneDB keyed by THIS
        communicator's size — a 4-rank axis of an 8-device mesh looks up
        4-device results — ``OPTIMIZED_CONFIG`` on a cold cache).

        ``hops`` is the worst-case torus hop distance of the pattern the
        collective will run (defaults to this communicator's ring pattern —
        placement-aware when a :class:`TorusSpec` is attached, in which case
        measurements taken on the same virtual placement are preferred),
        so hop-matched measurements are preferred; ``objective="e2e"`` ranks
        by the measured consumer-loop time instead of bare latency."""
        from repro.tune import select_config, topology_key
        if hops is None:
            hops = self.max_hops(self.ring_perm())
        return select_config(collective, msg_bytes, path=db_path,
                             topo=topology_key(n_devices=self.size),
                             hops=hops, objective=objective,
                             torus=self.topo.name if self.topo else "")

    # ------------------------------------------------------------------
    # Topology helpers (static, host-side)
    # ------------------------------------------------------------------
    def ring_perm(self, step: int = 1) -> list[tuple[int, int]]:
        from repro.core import plans
        return list(plans.ring_perm(self.size, step))

    def reverse_ring_perm(self, step: int = 1) -> list[tuple[int, int]]:
        from repro.core import plans
        return list(plans.ring_perm(self.size, -step))

    def neighbor_perms(self, edges: Sequence[Tuple[int, int]]) -> list[tuple[int, int]]:
        """Validate an explicit point-to-point pattern (src, dst) pairs.

        ppermute requires each device to be the source of at most one pair per
        call; halo exchanges with several neighbors issue one ppermute per
        neighbor index (see collectives.halo_exchange).
        """
        srcs = [s for s, _ in edges]
        if len(set(srcs)) != len(srcs):
            raise ValueError("each rank may send at most once per ppermute")
        for s, d in edges:
            if not (0 <= s < self.size and 0 <= d < self.size):
                raise ValueError(f"edge ({s},{d}) outside communicator size {self.size}")
        return list(edges)

    def hop_perm(self, d: int) -> list[tuple[int, int]]:
        """Translation perm at exactly ``d`` torus hops (requires a
        :class:`~repro.core.topology.TorusSpec`) — the pattern the
        hop-distance sweep axis measures."""
        if self.topo is None:
            raise ValueError("hop_perm requires a torus spec "
                             "(Communicator(..., topo=TorusSpec(...)))")
        return self.topo.hop_perm(d)

    def torus_hops(self, src: int, dst: int, torus_shape: Tuple[int, int] | None = None
                   ) -> int:
        """Manhattan hop count between two ranks on the physical 2-D torus.

        With a :class:`~repro.core.topology.TorusSpec` attached the distance
        follows the spec's shape *and placement*; otherwise ranks are laid
        out row-major on ``torus_shape`` (defaults to the squarest
        factorization of the communicator size).  Feeds the per-hop latency
        term (the paper's direct-link vs Ethernet-switch comparison: each
        extra hop adds ~ici_hop_latency).
        """
        if self.topo is not None and torus_shape is None:
            return self.topo.hops(src, dst)
        n = self.size
        if torus_shape is None:
            a = int(math.isqrt(n))
            while n % a:
                a -= 1
            torus_shape = (a, n // a)
        rows, cols = torus_shape
        (sr, sc), (dr, dc) = divmod(src, cols), divmod(dst, cols)
        dy = min((sr - dr) % rows, (dr - sr) % rows)
        dx = min((sc - dc) % cols, (dc - sc) % cols)
        return dy + dx

    def max_hops(self, edges: Sequence[Tuple[int, int]]) -> int:
        return max((self.torus_hops(s, d) for s, d in edges), default=0)
