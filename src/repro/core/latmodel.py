"""Latency and throughput models — the paper's Equations 1–3 ported to TPU.

Paper (FPGA)                         Here (TPU)
----------------------------------   ------------------------------------------
l_k  XRT kernel invocation ~30 µs    host program dispatch (host scheduling) or
                                     in-program DMA issue (fused scheduling)
l_m  copy via global memory          HBM staging copy (buffered receive)
l_c  QSFP link latency + size/bw     ICI hop latency (+0.5 µs per extra torus
                                     hop — the Ethernet-switch analogue) +
                                     size/ici_bw

Eq. 1  buffered : L = 2·l_k + l_m + l_c
       streaming: L = l_k + l_c
Eq. 2  throughput = f · FLOP_total /
         (max(E_core + D_ext, L_comm·f) + E_send + E_recv + L_pipe)
Eq. 3  L_comm = (E_send + E_recv + 2·N_max·l_k·f + N_max·l_m·f)/f + L_pingping
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.config import (CommConfig, CommMode, Compression, HardwareSpec,
                               Reliability, Scheduling, V5E)


def wire_bytes(msg_bytes: int, cfg: CommConfig) -> float:
    """Bytes on the wire after the compression plugin (int8: payload/4 of f32
    + per-block f32 scales; bf16: /2)."""
    if cfg.compression == Compression.INT8:
        elems = msg_bytes / 4.0  # wire format defined relative to f32 payloads
        return elems * 1.0 + (elems / cfg.quant_block) * 4.0
    if cfg.compression == Compression.BF16:
        return msg_bytes / 2.0
    return float(msg_bytes)


def l_k(cfg: CommConfig, hw: HardwareSpec = V5E) -> float:
    """Command-scheduling latency: the paper's 30 µs (host) vs sub-µs (PL)."""
    return hw.host_dispatch if cfg.scheduling == Scheduling.HOST else hw.fused_dispatch


def n_commands(msg_bytes: int, cfg: CommConfig) -> float:
    """Scheduled commands per transfer — the Eq. 3 'one more scheduled
    command' term, applied at wire-chunk granularity.

    Buffered mode moves the whole message through the staging buffer: two
    commands (write + read-back), independent of segmentation.  Streaming
    mode issues one command per wire chunk (``num_chunks``), which is what
    prices small segments out at multi-MiB messages — the paper's
    segmentation/jumbo-frame trade-off."""
    if cfg.mode == CommMode.BUFFERED:
        return 2.0
    return float(max(1, min(cfg.max_chunks,
                            math.ceil(max(1, msg_bytes) / cfg.chunk_bytes))))


def l_m(msg_bytes: int, hw: HardwareSpec = V5E) -> float:
    """Staging copy through HBM (write + read back)."""
    return 2.0 * msg_bytes / hw.hbm_bw


def l_c(msg_bytes: int, cfg: CommConfig, hw: HardwareSpec = V5E,
        hops: int = 1) -> float:
    """Link latency: first-hop latency + per-extra-hop penalty + serialization."""
    lat = hw.ici_latency + max(0, hops - 1) * hw.ici_hop_latency
    return lat + wire_bytes(msg_bytes, cfg) / hw.ici_bw


def expected_retransmit_factor(cfg: CommConfig, loss: float) -> float:
    """Expected wire slots per chunk under per-transmission loss rate
    ``loss`` — the reliability layer's Eq. 1 term.

    A chunk that fails its first ``k`` transmissions costs, beyond the one
    lossless slot, ``k`` retransmission slots plus each retry's ack-timeout
    wait and capped-exponential backoff holds
    (:func:`repro.core.reliable.backoff_holds`).  Summing over the loss
    geometric series (truncated at ``max_retransmits`` — the emulated wire
    relents within the cap):

        E[slots] = 1 + sum_{k>=1} p^k (ack_timeout + backoff(k) + 1)

    BEST_EFFORT has no protocol, so loss never costs it slots (it costs it
    the delivery guarantee instead); the factor is 1.0.  This is what makes
    ``select_config`` answer "jumbo frames win clean links, small segments
    win lossy ones": the factor multiplies *per-chunk* serialization, and a
    buffered/jumbo transfer re-pays its whole message per retransmit while
    small segments only re-pay the lost chunk.
    """
    if loss <= 0.0 or cfg.reliability != Reliability.GUARANTEED:
        return 1.0
    if not 0.0 <= loss < 1.0:
        raise ValueError(f"loss rate must be in [0, 1), got {loss}")
    factor = 1.0
    for k in range(1, cfg.max_retransmits + 1):
        backoff = min(cfg.backoff_base * (2 ** (k - 1)), cfg.backoff_cap)
        factor += (loss ** k) * (cfg.ack_timeout + backoff + 1.0)
    return factor


def pingping_latency(msg_bytes: int, cfg: CommConfig, hw: HardwareSpec = V5E,
                     hops: int = 1, loss: float = 0.0) -> float:
    """Eq. 1 with the multi-hop route term.  At ``hops == 1`` this is the
    classic model; a routed ``h``-hop edge (the virtual torus transport's
    store-and-forward lowering) additionally pays:

    - buffered : the whole message re-serializes at every hop —
      ``h x wire/bw`` (each intermediate stages the full message before
      forwarding);
    - streaming: wire chunks *wormhole* through the route — chunk pipelining
      across hops occupies the wire for ``(n_chunks + h - 1)`` chunk slots,
      so small segments amortize the route depth while a single jumbo chunk
      pays ``h`` full serializations.

    This hop x segmentation interaction is what makes the per-edge winner
    hop-dependent (the paper's direct-link vs routed distinction): jumbo
    chunks win direct links (fewer scheduled commands), small chunks win
    long routes (pipelining) — and it mirrors what the emulated transport
    physically executes (one permute per chunk per hop).

    ``loss`` prices the GUARANTEED reliability protocol on a lossy wire:
    per-chunk serialization (and its scheduled command) is multiplied by
    :func:`expected_retransmit_factor` — buffered mode's single jumbo
    "chunk" re-pays the whole message per retransmit, streaming re-pays one
    segment, which flips the jumbo-vs-segment winner as loss grows.
    """
    h = max(1, hops)
    lat = hw.ici_latency + (h - 1) * hw.ici_hop_latency
    wire = wire_bytes(msg_bytes, cfg)
    rf = expected_retransmit_factor(cfg, loss)
    if cfg.mode == CommMode.BUFFERED:
        return (2.0 * l_k(cfg, hw) + l_m(msg_bytes, hw) + lat
                + rf * h * wire / hw.ici_bw)
    # Streaming: no staging copy; every chunk is one scheduled command
    # (n_commands — sub-µs fused on real hardware, dominant on host-CPU
    # substrates), and chunks pipeline across the route's hops.
    n = n_commands(msg_bytes, cfg)
    return (rf * n * l_k(cfg, hw) + lat
            + rf * (n + h - 1) * (wire / n) / hw.ici_bw)


def effective_bandwidth(msg_bytes: int, cfg: CommConfig,
                        hw: HardwareSpec = V5E, hops: int = 1,
                        loss: float = 0.0) -> float:
    """B/s delivered for a message of msg_bytes (the b_eff metric)."""
    return msg_bytes / pingping_latency(msg_bytes, cfg, hw, hops, loss=loss)


def buffered_peak_bw(hw: HardwareSpec = V5E) -> float:
    """Series-bandwidth cap of buffered mode: (1/bw_link + 1/bw_mem)^-1.

    Paper: (1/12.5 + 1/14)^-1 GB/s = 6.6 GB/s.  TPU: HBM staging (write+read
    = hbm_bw/2 effective) in series with the ICI link.
    """
    return 1.0 / (1.0 / hw.ici_bw + 2.0 / hw.hbm_bw)


# ----------------------------------------------------------------------
# Application model (shallow water, Eq. 2/3)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SWEWorkload:
    """Static description of one partition's work per timestep (in elements
    and cycles, as the paper counts them)."""
    e_total: int        # total mesh elements (global)
    e_core: int         # core elements on the critical partition
    e_send: int         # elements sent per step (largest sender)
    e_recv: int         # elements received per step
    d_ext: int          # extra pipeline cycles for external data projection
    l_pipe: int         # pipeline fill depth (cycles)
    n_max: int          # max neighbor count over partitions
    flop_per_element: float
    freq: float         # kernel clock f (element rate, elements/s)
    msg_bytes: int      # largest single halo message


def eq3_l_comm(w: SWEWorkload, cfg: CommConfig, hw: HardwareSpec = V5E,
               hops: int = 1) -> float:
    """Eq. 3 — seconds of communication latency on the critical partition."""
    per_element = (w.e_send + w.e_recv) / w.freq
    sched = 2.0 * w.n_max * l_k(cfg, hw)
    staging = w.n_max * (l_m(w.msg_bytes, hw) if cfg.mode == CommMode.BUFFERED else 0.0)
    return per_element + sched + staging + pingping_latency(w.msg_bytes, cfg, hw, hops)


def eq2_throughput(w: SWEWorkload, cfg: CommConfig, hw: HardwareSpec = V5E,
                   hops: int = 1) -> float:
    """Eq. 2 — modeled FLOP/s for the partitioned simulation."""
    l_comm_cycles = eq3_l_comm(w, cfg, hw, hops) * w.freq
    denom_cycles = (max(w.e_core + w.d_ext, l_comm_cycles)
                    + w.e_send + w.e_recv + w.l_pipe)
    flop_total = w.flop_per_element * w.e_total
    return w.freq * flop_total / denom_cycles


def overlap_fraction(cfg: CommConfig) -> float:
    """Fraction of L_comm the step *structure* can hide behind interior
    compute (the §5 overlap term).

    Eq. 2's ``max(E_core, L_comm)`` assumes perfect hiding; in practice the
    fused step fences the element update on the whole exchange, so only
    chunk-level pipelining overlaps.  The overlapped schedule's
    interior/boundary split makes the interior update independent of the
    exchange — full hiding.  Host scheduling serializes everything.
    """
    if cfg.scheduling == Scheduling.OVERLAPPED:
        return 1.0
    if cfg.scheduling == Scheduling.FUSED:
        # chunk pipelining inside the exchange, but the update still fences
        return 0.6 if cfg.mode == CommMode.STREAMING else 0.3
    return 0.0


def eq2_throughput_overlap(w: SWEWorkload, cfg: CommConfig,
                           hw: HardwareSpec = V5E, hops: int = 1) -> float:
    """Eq. 2 with the explicit overlap term: the exposed step time
    interpolates between fully serialized (compute + L_comm) and fully
    hidden (max(compute, L_comm)) by :func:`overlap_fraction`.

    This is the term that moves the strong-scaling knee: under the
    overlapped schedule the throughput stays compute-bound until L_comm
    itself exceeds the interior work, instead of degrading as soon as the
    exchange stops fitting under the chunk pipeline.
    """
    l_comm_cycles = eq3_l_comm(w, cfg, hw, hops) * w.freq
    compute_cycles = w.e_core + w.d_ext
    ov = overlap_fraction(cfg)
    exposed = (ov * max(compute_cycles, l_comm_cycles)
               + (1.0 - ov) * (compute_cycles + l_comm_cycles))
    denom_cycles = exposed + w.e_send + w.e_recv + w.l_pipe
    flop_total = w.flop_per_element * w.e_total
    return w.freq * flop_total / denom_cycles


def e2e_consumer_latency(msg_bytes: int, cfg: CommConfig, compute_s: float,
                         hw: HardwareSpec = V5E, hops: int = 1,
                         loss: float = 0.0) -> float:
    """Overlap-aware Eq. 2 applied to a consumer loop: predicted seconds per
    iteration of (hideable compute + collective) under ``cfg``.

    The exposed time interpolates between fully hidden —
    ``max(compute, comm)`` — and fully serialized — ``compute + comm`` — by
    :func:`overlap_fraction`: ``ov·max(comm, compute) + (1−ov)·(comm +
    compute)``.  This is the prediction behind the autotuner's ``e2e``
    objective (§5: the config that wins the bare-latency microbench is not
    the one that scales the consuming kernel), and what lets ``tune.prune``
    rank candidates end-to-end without measuring them.
    """
    comm_s = pingping_latency(msg_bytes, cfg, hw, hops, loss=loss)
    ov = overlap_fraction(cfg)
    return ov * max(compute_s, comm_s) + (1.0 - ov) * (compute_s + comm_s)


def stall_fraction(w: SWEWorkload, cfg: CommConfig, hw: HardwareSpec = V5E,
                   hops: int = 1) -> float:
    """Fraction of the step spent stalled on communication (paper: 75–80 %
    for the MPI+PCIe baseline at ~6000 elements/partition).

    Assumes the perfect-hiding ``max()`` of the plain Eq. 2; pair it with
    :func:`eq2_throughput`.  The overlap-aware counterpart (pair with
    :func:`eq2_throughput_overlap`) is :func:`stall_fraction_overlap`.
    """
    l_comm_cycles = eq3_l_comm(w, cfg, hw, hops) * w.freq
    compute_cycles = w.e_core + w.d_ext
    total = max(compute_cycles, l_comm_cycles) + w.e_send + w.e_recv + w.l_pipe
    return max(0.0, l_comm_cycles - compute_cycles) / total


def stall_fraction_overlap(w: SWEWorkload, cfg: CommConfig,
                           hw: HardwareSpec = V5E, hops: int = 1) -> float:
    """Stall fraction under the same exposed-time model as
    :func:`eq2_throughput_overlap`: the share of the step spent on
    communication the schedule could not hide behind interior compute."""
    l_comm_cycles = eq3_l_comm(w, cfg, hw, hops) * w.freq
    compute_cycles = w.e_core + w.d_ext
    ov = overlap_fraction(cfg)
    exposed = (ov * max(compute_cycles, l_comm_cycles)
               + (1.0 - ov) * (compute_cycles + l_comm_cycles))
    total = exposed + w.e_send + w.e_recv + w.l_pipe
    return (exposed - compute_cycles) / total


# ----------------------------------------------------------------------
# Roofline terms (EXPERIMENTS.md §Roofline)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """dominant / sum — 1.0 means perfectly bound by one resource
        (no wasted time on the others if fully overlapped)."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.bound_s / s if s > 0 else 0.0


def roofline_terms(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                   n_chips: int, hw: HardwareSpec = V5E) -> RooflineTerms:
    """The three-term roofline of the assignment.

    ``hlo_flops``/``hlo_bytes`` are totals from ``compiled.cost_analysis()``
    (already per-program = per-device for SPMD); ``collective_bytes`` is the
    summed operand size of collective ops in the lowered HLO.
    """
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * hw.peak_flops),
        memory_s=hlo_bytes / (n_chips * hw.hbm_bw),
        collective_s=collective_bytes / (n_chips * hw.ici_bw),
    )
