"""Virtual multi-hop torus topology — emulate 2-D torus placements on any mesh.

The paper's central result is *where* a message travels: on the 48-FPGA
installation the best ACCL configuration depends on the per-edge hop distance
(direct QSFP link vs routed path), and the sweeps must therefore measure the
same collective at several hop distances.  A CPU host mesh has no such
structure — every ppermute edge costs the same — so this module supplies a
**virtual torus transport**: a :class:`TorusSpec` places the communicator's
ranks on an ``R x C`` torus, and every explicit point-to-point transfer whose
edge spans more than one torus hop is *routed* — lowered to a sequence of
single-hop ``ppermute`` rounds through the intermediate ranks
(store-and-forward).  Each extra hop is then one extra physically executed
permute, so measured latency genuinely grows with hop distance, with the
calibrated per-hop cost of Eq. 1 (``per_hop_ns``) as the modeled counterpart.

Routing is value-preserving by construction: intermediate ranks only forward,
so the received message is bitwise-identical to a direct permute — enforced
across torus shapes x placements x transports x scheduling modes by
``tests/test_topology.py``.

Glossary:

- *cell*      — linear row-major index into the ``R x C`` torus.
- *placement* — rank -> cell map (default identity).  ``snake_placement``
  lays ranks boustrophedon so the rank ring ``i -> i+1`` is a hop-1 cycle.
- *route*     — dimension-ordered (rows first, minimal wrap direction)
  store-and-forward path; its length equals the Manhattan hop distance.
- *hop perm*  — a translation of the whole torus by a fixed displacement:
  every rank sends to the rank exactly ``d`` hops away, the pattern the
  ``--hop-distances`` sweep axis measures.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Sequence, Tuple

from repro.core.config import HardwareSpec, V5E


@dataclasses.dataclass(frozen=True)
class TorusSpec:
    """A virtual ``R x C`` torus placement with calibrated per-hop costs.

    ``shape``          — (rows, cols); ``rows * cols`` ranks are emulated.
    ``per_hop_ns``     — injected per-extra-hop latency for the Eq. 1 model
                         (the paper's direct-link vs Ethernet-switch delta).
    ``bisection_gbps`` — aggregate bisection bandwidth of the emulated torus;
                         the per-link share feeds the modeled wire bandwidth.
    ``placement``      — rank -> cell (row-major linear index); identity when
                         omitted.  ``snake_placement`` makes the rank ring
                         hop-1.
    """
    shape: Tuple[int, int]
    per_hop_ns: float = 500.0
    bisection_gbps: float = 400.0
    placement: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        rows, cols = self.shape
        if rows < 1 or cols < 1:
            raise ValueError(f"torus shape must be positive, got {self.shape}")
        object.__setattr__(self, "shape", (int(rows), int(cols)))
        if self.placement is not None:
            p = tuple(int(c) for c in self.placement)
            if sorted(p) != list(range(self.n_ranks)):
                raise ValueError(
                    f"placement must be a permutation of range({self.n_ranks})"
                    f", got {p}")
            object.__setattr__(self, "placement", p)

    # ------------------------------------------------------------------
    # Construction helpers
    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, text: str, **kw) -> "TorusSpec":
        """Parse the CLI spelling: ``"4x4"`` or ``"4x4:snake"``."""
        body, _, tag = text.partition(":")
        try:
            rows, cols = (int(v) for v in body.lower().split("x"))
        except ValueError:
            raise ValueError(f"torus spec must look like '4x4[:snake]', "
                             f"got {text!r}") from None
        if tag and tag != "snake":
            raise ValueError(f"unknown placement tag {tag!r} (only 'snake')")
        placement = snake_placement((rows, cols)) if tag == "snake" else None
        return cls(shape=(rows, cols), placement=placement, **kw)

    @property
    def n_ranks(self) -> int:
        return self.shape[0] * self.shape[1]

    @property
    def name(self) -> str:
        """Stable human-readable identity, used as the ``TuneEntry.torus``
        key and in sweep cache keys — distinct placements must never alias,
        so a custom placement carries a digest of its tuple."""
        if self.placement is None:
            tag = ""
        elif self.placement == snake_placement(self.shape):
            tag = ":snake"
        else:
            digest = zlib.crc32(repr(self.placement).encode()) & 0xFFFFFF
            tag = f":p{digest:06x}"
        return f"{self.shape[0]}x{self.shape[1]}{tag}"

    def key(self) -> tuple:
        """Value identity for plan-cache keying (placement included)."""
        return (self.shape, self.per_hop_ns, self.bisection_gbps,
                self.placement)

    # ------------------------------------------------------------------
    # Coordinates and distances
    # ------------------------------------------------------------------
    def cell(self, rank: int) -> int:
        return self.placement[rank] if self.placement is not None else rank

    def rank_at(self, cell: int) -> int:
        if self.placement is None:
            return cell
        return self.placement.index(cell)

    def coords(self, rank: int) -> Tuple[int, int]:
        c = self.cell(rank)
        return divmod(c, self.shape[1])

    def hops(self, src: int, dst: int) -> int:
        """Manhattan hop distance between two placed ranks."""
        rows, cols = self.shape
        (sr, sc), (dr, dc) = self.coords(src), self.coords(dst)
        dy = min((sr - dr) % rows, (dr - sr) % rows)
        dx = min((sc - dc) % cols, (dc - sc) % cols)
        return dy + dx

    def max_hops(self, edges: Sequence[Tuple[int, int]]) -> int:
        return max((self.hops(s, d) for s, d in edges), default=0)

    @property
    def diameter(self) -> int:
        """Worst-case hop distance on this torus."""
        rows, cols = self.shape
        return rows // 2 + cols // 2

    # ------------------------------------------------------------------
    # Patterns
    # ------------------------------------------------------------------
    def _displacement(self, d: int) -> Tuple[int, int]:
        """A minimal (dy, dx) with dy + dx == d (so every translated edge is
        exactly ``d`` hops)."""
        rows, cols = self.shape
        if not 0 <= d <= self.diameter:
            raise ValueError(f"hop distance {d} outside [0, {self.diameter}] "
                             f"for torus {self.shape}")
        dy = min(d, rows // 2)
        dx = d - dy
        if dx > cols // 2:
            dx = cols // 2
            dy = d - dx
        return dy, dx

    def hop_perm(self, d: int) -> list[tuple[int, int]]:
        """Translation perm at exactly ``d`` hops: every rank sends to the
        rank ``d`` hops away (dy down, dx right, torus wrap).  This is the
        pattern the ``--hop-distances`` sweep axis measures — a bijection, so
        each rank sends and receives exactly once."""
        rows, cols = self.shape
        dy, dx = self._displacement(d)
        perm = []
        for rank in range(self.n_ranks):
            r, c = self.coords(rank)
            dst_cell = ((r + dy) % rows) * cols + (c + dx) % cols
            perm.append((rank, self.rank_at(dst_cell)))
        return perm

    def reverse_hop_perm(self, d: int) -> list[tuple[int, int]]:
        return [(dst, src) for src, dst in self.hop_perm(d)]

    # ------------------------------------------------------------------
    # Modeled hardware
    # ------------------------------------------------------------------
    def hardware(self, base: HardwareSpec = V5E) -> HardwareSpec:
        """A :class:`HardwareSpec` carrying this torus's calibrated costs:
        ``per_hop_ns`` as the Eq. 1 per-extra-hop latency and the bisection
        bandwidth's per-link share (a ``2 x min(R, C)``-cut torus has
        ``4 * min(R, C)`` directed links across the bisection) as the wire
        bandwidth cap."""
        link_bw = self.bisection_gbps * 1e9 / (4 * min(self.shape))
        return dataclasses.replace(
            base, name=f"torus-{self.name}",
            ici_hop_latency=self.per_hop_ns * 1e-9,
            ici_bw=min(base.ici_bw, link_bw))


def snake_placement(shape: Tuple[int, int]) -> Tuple[int, ...]:
    """Boustrophedon placement: rank ``i`` and ``i+1`` are always torus
    neighbors, so the rank ring is a hop-1 cycle (the closing edge is hop-1
    too when ``rows`` is even)."""
    rows, cols = shape
    cells = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        cells.extend(r * cols + c for c in cs)
    return tuple(cells)


# ----------------------------------------------------------------------
# Store-and-forward routing
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RouteBatch:
    """One conflict-free store-and-forward schedule: ``rounds`` are valid
    single-hop ppermute perms (holds spelled as ``(r, r)`` self-edges);
    ``dests`` are the final destinations this batch delivers to."""
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    dests: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class RoutedPerm:
    """A multi-hop lowering of one edge list.

    The wire layer (:func:`repro.core.streaming.wire_permute`) executes each
    batch's rounds as sequential ppermutes; batches that could not share a
    conflict-free schedule run one after another and merge by destination
    mask (a pure select — bitwise-exact).
    """
    edges: Tuple[Tuple[int, int], ...]
    batches: Tuple[RouteBatch, ...]
    max_hops: int

    @property
    def n_rounds(self) -> int:
        return sum(len(b.rounds) for b in self.batches)


def route(spec: TorusSpec, src: int, dst: int) -> list[int]:
    """Dimension-ordered minimal route (ranks visited, incl. endpoints):
    rows first, then columns, each along the shorter wrap direction.  Length
    is exactly ``spec.hops(src, dst) + 1``."""
    rows, cols = spec.shape
    r, c = spec.coords(src)
    tr, tc = spec.coords(dst)
    cells = [r * cols + c]
    while r != tr:
        step = 1 if (tr - r) % rows <= (r - tr) % rows else -1
        r = (r + step) % rows
        cells.append(r * cols + c)
    while c != tc:
        step = 1 if (tc - c) % cols <= (c - tc) % cols else -1
        c = (c + step) % cols
        cells.append(r * cols + c)
    return [spec.rank_at(cell) for cell in cells]


def _lockstep_rounds(routes: Sequence[Sequence[int]]
                     ) -> Optional[list[list[tuple[int, int]]]]:
    """Schedule all routes advancing one hop per round (arrived messages hold
    via self-edges).  Returns None when two messages would ever occupy the
    same rank — the caller then splits the edge list into batches."""
    depth = max(len(r) for r in routes) - 1
    pos = [[r[min(t, len(r) - 1)] for r in routes] for t in range(depth + 1)]
    for col in pos:
        if len(set(col)) != len(col):
            return None
    return [[(pos[t][m], pos[t + 1][m]) for m in range(len(routes))]
            for t in range(depth)]


def route_rounds(spec: TorusSpec, edges: Sequence[Tuple[int, int]]
                 ) -> RoutedPerm:
    """Lower an edge list to conflict-free store-and-forward batches.

    Translation-invariant patterns (ring steps, :meth:`TorusSpec.hop_perm`)
    schedule in ONE batch — every message advances in lockstep, the faithful
    parallel-forwarding emulation.  Irregular patterns (the SWE partition's
    RCB edges) greedily group edges whose lockstep schedules don't collide;
    leftover edges open new batches (serialized forwarding — the emulated
    fabric's link contention).
    """
    edges = tuple((int(s), int(d)) for s, d in edges)
    routes = {e: route(spec, *e) for e in edges}
    batches: list[RouteBatch] = []
    pending = list(edges)
    while pending:
        batch: list[tuple[int, int]] = []
        sched: Optional[list] = None
        rest: list[tuple[int, int]] = []
        for e in pending:
            trial = _lockstep_rounds([routes[b] for b in batch] + [routes[e]])
            if trial is not None:
                batch.append(e)
                sched = trial
            else:
                rest.append(e)
        assert sched is not None  # a single route always schedules
        batches.append(RouteBatch(
            rounds=tuple(tuple(r) for r in sched),
            dests=tuple(d for _, d in batch)))
        pending = rest
    return RoutedPerm(edges=edges, batches=tuple(batches),
                      max_hops=spec.max_hops(edges))


def routed_perm(comm, perm: Sequence[Tuple[int, int]]):
    """The transport-facing entry point: return ``perm`` unchanged when the
    communicator has no torus spec (or every edge is a direct link), else the
    cached :class:`RoutedPerm` lowering.  Derivation is memoized through the
    :mod:`repro.core.plans` cache (``REPRO_PLAN_CACHE=0`` re-derives — values
    are identical either way)."""
    spec = getattr(comm, "topo", None)
    edges = tuple((int(s), int(d)) for s, d in perm)
    if spec is None or spec.max_hops(edges) <= 1:
        return edges
    from repro.core import plans
    return plans._memo("route", (spec.key(), edges),
                       lambda: route_rounds(spec, edges),
                       "plan_hits", "plan_misses")
