"""Virtual multi-hop torus topology — emulate 2-D torus placements on any mesh.

The paper's central result is *where* a message travels: on the 48-FPGA
installation the best ACCL configuration depends on the per-edge hop distance
(direct QSFP link vs routed path), and the sweeps must therefore measure the
same collective at several hop distances.  A CPU host mesh has no such
structure — every ppermute edge costs the same — so this module supplies a
**virtual torus transport**: a :class:`TorusSpec` places the communicator's
ranks on an ``R x C`` torus, and every explicit point-to-point transfer whose
edge spans more than one torus hop is *routed* — lowered to a sequence of
single-hop ``ppermute`` rounds through the intermediate ranks
(store-and-forward).  Each extra hop is then one extra physically executed
permute, so measured latency genuinely grows with hop distance, with the
calibrated per-hop cost of Eq. 1 (``per_hop_ns``) as the modeled counterpart.

Routing is value-preserving by construction: intermediate ranks only forward,
so the received message is bitwise-identical to a direct permute — enforced
across torus shapes x placements x transports x scheduling modes by
``tests/test_topology.py``.

Glossary:

- *cell*      — linear row-major index into the ``R x C`` torus.
- *placement* — rank -> cell map (default identity).  ``snake_placement``
  lays ranks boustrophedon so the rank ring ``i -> i+1`` is a hop-1 cycle.
- *route*     — dimension-ordered (rows first, minimal wrap direction)
  store-and-forward path; its length equals the Manhattan hop distance.
- *hop perm*  — a translation of the whole torus by a fixed displacement:
  every rank sends to the rank exactly ``d`` hops away, the pattern the
  ``--hop-distances`` sweep axis measures.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Sequence, Tuple

from repro.core.config import HardwareSpec, V5E


@dataclasses.dataclass(frozen=True)
class TorusSpec:
    """A virtual ``R x C`` torus placement with calibrated per-hop costs.

    ``shape``          — (rows, cols); ``rows * cols`` ranks are emulated.
    ``per_hop_ns``     — injected per-extra-hop latency for the Eq. 1 model
                         (the paper's direct-link vs Ethernet-switch delta).
    ``bisection_gbps`` — aggregate bisection bandwidth of the emulated torus;
                         the per-link share feeds the modeled wire bandwidth.
    ``placement``      — rank -> cell (row-major linear index); identity when
                         omitted.  ``snake_placement`` makes the rank ring
                         hop-1.
    ``link_slowdowns`` — degraded physical links, ``(((a, b), factor), ...)``
                         with ``a``/``b`` adjacent ranks and ``factor >= 1``:
                         the fault-injection ground truth.  A traversal of a
                         degraded hop is emulated by ``ceil(factor) - 1``
                         extra store-and-forward hold rounds, so measured
                         latency genuinely grows (values are unchanged).
    ``reroute``        — the runtime's *belief*: when True, routing picks the
                         cheaper dimension order around degraded links.  Off
                         by default — a freshly degraded fabric keeps its old
                         routes until the :class:`~repro.runtime.faults.
                         DegradationMonitor` notices and re-routes.
    """
    shape: Tuple[int, int]
    per_hop_ns: float = 500.0
    bisection_gbps: float = 400.0
    placement: Optional[Tuple[int, ...]] = None
    link_slowdowns: Optional[Tuple[Tuple[Tuple[int, int], float], ...]] = None
    reroute: bool = False

    def __post_init__(self):
        rows, cols = self.shape
        if rows < 1 or cols < 1:
            raise ValueError(f"torus shape must be positive, got {self.shape}")
        object.__setattr__(self, "shape", (int(rows), int(cols)))
        if self.placement is not None:
            p = tuple(int(c) for c in self.placement)
            if sorted(p) != list(range(self.n_ranks)):
                raise ValueError(
                    f"placement must be a permutation of range({self.n_ranks})"
                    f", got {p}")
            object.__setattr__(self, "placement", p)
        if self.link_slowdowns is not None:
            canon = {}
            for (a, b), f in self.link_slowdowns:
                a, b, f = int(a), int(b), float(f)
                if f < 1.0:
                    raise ValueError(f"link slowdown must be >= 1, got {f}")
                if self.hops(a, b) != 1:
                    raise ValueError(
                        f"({a},{b}) is not a single-hop link on {self.name} "
                        f"(hops={self.hops(a, b)}); degrade physical links "
                        f"only")
                key = (min(a, b), max(a, b))
                canon[key] = max(f, canon.get(key, 1.0))
            canon = {k: f for k, f in canon.items() if f > 1.0}
            object.__setattr__(
                self, "link_slowdowns",
                tuple(sorted(canon.items())) if canon else None)

    # ------------------------------------------------------------------
    # Construction helpers
    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, text: str, **kw) -> "TorusSpec":
        """Parse the CLI spelling: ``"4x4"`` or ``"4x4:snake"``."""
        body, _, tag = text.partition(":")
        try:
            rows, cols = (int(v) for v in body.lower().split("x"))
        except ValueError:
            raise ValueError(f"torus spec must look like '4x4[:snake]', "
                             f"got {text!r}") from None
        if tag and tag != "snake":
            raise ValueError(f"unknown placement tag {tag!r} (only 'snake')")
        placement = snake_placement((rows, cols)) if tag == "snake" else None
        return cls(shape=(rows, cols), placement=placement, **kw)

    @property
    def n_ranks(self) -> int:
        return self.shape[0] * self.shape[1]

    @property
    def name(self) -> str:
        """Stable human-readable identity, used as the ``TuneEntry.torus``
        key and in sweep cache keys — distinct placements must never alias,
        so a custom placement carries a digest of its tuple."""
        if self.placement is None:
            tag = ""
        elif self.placement == snake_placement(self.shape):
            tag = ":snake"
        else:
            digest = zlib.crc32(repr(self.placement).encode()) & 0xFFFFFF
            tag = f":p{digest:06x}"
        return f"{self.shape[0]}x{self.shape[1]}{tag}"

    def key(self) -> tuple:
        """Value identity for plan-cache keying (placement included).
        Degradation state is part of the identity — a degraded fabric must
        never reuse the healthy fabric's routed plans (hold rounds differ),
        while ``name`` stays stable so TuneDB entries remain addressable."""
        return (self.shape, self.per_hop_ns, self.bisection_gbps,
                self.placement, self.link_slowdowns, self.reroute)

    # ------------------------------------------------------------------
    # Degradation state
    # ------------------------------------------------------------------
    def link_slowdown(self, a: int, b: int) -> float:
        """Slowdown factor on the physical link ``{a, b}`` (1.0 = healthy)."""
        if not self.link_slowdowns:
            return 1.0
        key = (min(int(a), int(b)), max(int(a), int(b)))
        for k, f in self.link_slowdowns:
            if k == key:
                return f
        return 1.0

    @property
    def degraded_links(self) -> Tuple[Tuple[int, int], ...]:
        """Canonical (lo, hi) rank pairs currently degraded."""
        if not self.link_slowdowns:
            return ()
        return tuple(k for k, _ in self.link_slowdowns)

    def with_link_slowdown(self, a: int, b: int,
                           factor: float) -> "TorusSpec":
        """A copy with link ``{a, b}`` degraded by ``factor`` (>= 1;
        ``factor == 1`` heals the link).  Other degradations are kept."""
        if float(factor) < 1.0:
            raise ValueError(f"link slowdown must be >= 1, got {factor}")
        key = (min(int(a), int(b)), max(int(a), int(b)))
        kept = [(k, f) for k, f in (self.link_slowdowns or ()) if k != key]
        if float(factor) > 1.0:
            kept.append((key, float(factor)))
        return dataclasses.replace(
            self, link_slowdowns=tuple(sorted(kept)) or None)

    def with_reroute(self, reroute: bool = True) -> "TorusSpec":
        """A copy with cost-aware routing switched on/off (the monitor's
        lever after hysteresis confirms a degraded link)."""
        return dataclasses.replace(self, reroute=bool(reroute))

    def without_degradations(self) -> "TorusSpec":
        """The healthy twin: same placement/costs, no slowdowns, no reroute."""
        return dataclasses.replace(self, link_slowdowns=None, reroute=False)

    def path_cost(self, ranks: Sequence[int]) -> float:
        """Sum of per-hop slowdown factors along a rank path (hops cost 1.0
        when healthy) — the route comparator under ``reroute``."""
        return sum(self.link_slowdown(ranks[i], ranks[i + 1])
                   for i in range(len(ranks) - 1))

    def shrink(self, n_survivors: int) -> "TorusSpec":
        """The sub-torus the elastic runtime rebuilds on the survivors.

        The squarest ``R' x C'`` factorization of ``n_survivors`` (minimal
        diameter), with the bisection bandwidth scaled by the survivor
        fraction — fewer boards, fewer links.  Placement and degradation
        state are dropped: survivors are renumbered ``0..n-1`` on a fresh
        fabric, and the dead rank's links die with it.
        """
        n = int(n_survivors)
        if not 1 <= n <= self.n_ranks:
            raise ValueError(
                f"n_survivors must be in [1, {self.n_ranks}], got {n}")
        rows = max(r for r in range(1, int(math.isqrt(n)) + 1) if n % r == 0)
        return TorusSpec(
            shape=(rows, n // rows),
            per_hop_ns=self.per_hop_ns,
            bisection_gbps=self.bisection_gbps * n / self.n_ranks)

    # ------------------------------------------------------------------
    # Coordinates and distances
    # ------------------------------------------------------------------
    def cell(self, rank: int) -> int:
        return self.placement[rank] if self.placement is not None else rank

    def rank_at(self, cell: int) -> int:
        if self.placement is None:
            return cell
        return self.placement.index(cell)

    def coords(self, rank: int) -> Tuple[int, int]:
        c = self.cell(rank)
        return divmod(c, self.shape[1])

    def hops(self, src: int, dst: int) -> int:
        """Manhattan hop distance between two placed ranks."""
        rows, cols = self.shape
        (sr, sc), (dr, dc) = self.coords(src), self.coords(dst)
        dy = min((sr - dr) % rows, (dr - sr) % rows)
        dx = min((sc - dc) % cols, (dc - sc) % cols)
        return dy + dx

    def max_hops(self, edges: Sequence[Tuple[int, int]]) -> int:
        return max((self.hops(s, d) for s, d in edges), default=0)

    @property
    def diameter(self) -> int:
        """Worst-case hop distance on this torus."""
        rows, cols = self.shape
        return rows // 2 + cols // 2

    # ------------------------------------------------------------------
    # Patterns
    # ------------------------------------------------------------------
    def _displacement(self, d: int) -> Tuple[int, int]:
        """A minimal (dy, dx) with dy + dx == d (so every translated edge is
        exactly ``d`` hops)."""
        rows, cols = self.shape
        if not 0 <= d <= self.diameter:
            raise ValueError(f"hop distance {d} outside [0, {self.diameter}] "
                             f"for torus {self.shape}")
        dy = min(d, rows // 2)
        dx = d - dy
        if dx > cols // 2:
            dx = cols // 2
            dy = d - dx
        return dy, dx

    def hop_perm(self, d: int) -> list[tuple[int, int]]:
        """Translation perm at exactly ``d`` hops: every rank sends to the
        rank ``d`` hops away (dy down, dx right, torus wrap).  This is the
        pattern the ``--hop-distances`` sweep axis measures — a bijection, so
        each rank sends and receives exactly once."""
        rows, cols = self.shape
        dy, dx = self._displacement(d)
        perm = []
        for rank in range(self.n_ranks):
            r, c = self.coords(rank)
            dst_cell = ((r + dy) % rows) * cols + (c + dx) % cols
            perm.append((rank, self.rank_at(dst_cell)))
        return perm

    def reverse_hop_perm(self, d: int) -> list[tuple[int, int]]:
        return [(dst, src) for src, dst in self.hop_perm(d)]

    # ------------------------------------------------------------------
    # Modeled hardware
    # ------------------------------------------------------------------
    def hardware(self, base: HardwareSpec = V5E) -> HardwareSpec:
        """A :class:`HardwareSpec` carrying this torus's calibrated costs:
        ``per_hop_ns`` as the Eq. 1 per-extra-hop latency and the bisection
        bandwidth's per-link share (a ``2 x min(R, C)``-cut torus has
        ``4 * min(R, C)`` directed links across the bisection) as the wire
        bandwidth cap."""
        link_bw = self.bisection_gbps * 1e9 / (4 * min(self.shape))
        return dataclasses.replace(
            base, name=f"torus-{self.name}",
            ici_hop_latency=self.per_hop_ns * 1e-9,
            ici_bw=min(base.ici_bw, link_bw))


def snake_placement(shape: Tuple[int, int]) -> Tuple[int, ...]:
    """Boustrophedon placement: rank ``i`` and ``i+1`` are always torus
    neighbors, so the rank ring is a hop-1 cycle (the closing edge is hop-1
    too when ``rows`` is even)."""
    rows, cols = shape
    cells = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        cells.extend(r * cols + c for c in cs)
    return tuple(cells)


# ----------------------------------------------------------------------
# Store-and-forward routing
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RouteBatch:
    """One conflict-free store-and-forward schedule: ``rounds`` are valid
    single-hop ppermute perms (holds spelled as ``(r, r)`` self-edges);
    ``dests`` are the final destinations this batch delivers to."""
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    dests: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class RoutedPerm:
    """A multi-hop lowering of one edge list.

    The wire layer (:func:`repro.core.streaming.wire_permute`) executes each
    batch's rounds as sequential ppermutes; batches that could not share a
    conflict-free schedule run one after another and merge by destination
    mask (a pure select — bitwise-exact).
    """
    edges: Tuple[Tuple[int, int], ...]
    batches: Tuple[RouteBatch, ...]
    max_hops: int

    @property
    def n_rounds(self) -> int:
        return sum(len(b.rounds) for b in self.batches)


def _dim_route(spec: TorusSpec, src: int, dst: int,
               rows_first: bool) -> list[int]:
    """Minimal dimension-ordered route in the requested order (ranks
    visited, incl. endpoints), each dimension along the shorter wrap."""
    rows, cols = spec.shape
    r, c = spec.coords(src)
    tr, tc = spec.coords(dst)
    cells = [r * cols + c]

    def walk_rows():
        nonlocal r
        while r != tr:
            step = 1 if (tr - r) % rows <= (r - tr) % rows else -1
            r = (r + step) % rows
            cells.append(r * cols + c)

    def walk_cols():
        nonlocal c
        while c != tc:
            step = 1 if (tc - c) % cols <= (c - tc) % cols else -1
            c = (c + step) % cols
            cells.append(r * cols + c)

    if rows_first:
        walk_rows(), walk_cols()
    else:
        walk_cols(), walk_rows()
    return [spec.rank_at(cell) for cell in cells]


def route(spec: TorusSpec, src: int, dst: int) -> list[int]:
    """Dimension-ordered minimal route (ranks visited, incl. endpoints):
    rows first, then columns, each along the shorter wrap direction.  Length
    is exactly ``spec.hops(src, dst) + 1``.

    Under ``spec.reroute`` with degraded links, the column-first minimal
    route is also considered and the cheaper one (by summed link slowdown)
    wins; ties keep rows-first, so healthy fabrics route identically."""
    primary = _dim_route(spec, src, dst, rows_first=True)
    if not (spec.reroute and spec.link_slowdowns):
        return primary
    alt = _dim_route(spec, src, dst, rows_first=False)
    if spec.path_cost(alt) < spec.path_cost(primary):
        return alt
    return primary


def _lockstep_rounds(routes: Sequence[Sequence[int]]
                     ) -> Optional[list[list[tuple[int, int]]]]:
    """Schedule all routes advancing one hop per round (arrived messages hold
    via self-edges).  Returns None when two messages would ever occupy the
    same rank — the caller then splits the edge list into batches."""
    depth = max(len(r) for r in routes) - 1
    pos = [[r[min(t, len(r) - 1)] for r in routes] for t in range(depth + 1)]
    for col in pos:
        if len(set(col)) != len(col):
            return None
    return [[(pos[t][m], pos[t + 1][m]) for m in range(len(routes))]
            for t in range(depth)]


def route_rounds(spec: TorusSpec, edges: Sequence[Tuple[int, int]]
                 ) -> RoutedPerm:
    """Lower an edge list to conflict-free store-and-forward batches.

    Translation-invariant patterns (ring steps, :meth:`TorusSpec.hop_perm`)
    schedule in ONE batch — every message advances in lockstep, the faithful
    parallel-forwarding emulation.  Irregular patterns (the SWE partition's
    RCB edges) greedily group edges whose lockstep schedules don't collide;
    leftover edges open new batches (serialized forwarding — the emulated
    fabric's link contention).
    """
    edges = tuple((int(s), int(d)) for s, d in edges)
    routes = {e: route(spec, *e) for e in edges}
    batches: list[RouteBatch] = []
    pending = list(edges)
    while pending:
        batch: list[tuple[int, int]] = []
        sched: Optional[list] = None
        rest: list[tuple[int, int]] = []
        for e in pending:
            trial = _lockstep_rounds([routes[b] for b in batch] + [routes[e]])
            if trial is not None:
                batch.append(e)
                sched = trial
            else:
                rest.append(e)
        assert sched is not None  # a single route always schedules
        batches.append(RouteBatch(
            rounds=tuple(_degrade_rounds(spec, sched)),
            dests=tuple(d for _, d in batch)))
        pending = rest
    return RoutedPerm(edges=edges, batches=tuple(batches),
                      max_hops=spec.max_hops(edges))


def _degrade_rounds(spec: TorusSpec, sched: Sequence[Sequence[Tuple[int, int]]]
                    ) -> list[tuple[Tuple[int, int], ...]]:
    """Expand a lockstep schedule with hold rounds for degraded hops.

    A round whose worst traversed link is slowed by factor ``f`` is followed
    by ``ceil(f) - 1`` hold rounds (every in-flight message forwards to
    itself), so the batch physically executes ~``f`` ppermutes for that hop —
    measured latency grows with the injected degradation while the delivered
    values stay bitwise identical (a self-forward is value-preserving).
    """
    out: list[tuple[Tuple[int, int], ...]] = []
    for rnd in sched:
        rnd = tuple(rnd)
        out.append(rnd)
        if not spec.link_slowdowns:
            continue
        worst = max((spec.link_slowdown(s, d) for s, d in rnd if s != d),
                    default=1.0)
        hold = tuple((d, d) for _, d in rnd)
        out.extend(hold for _ in range(math.ceil(worst) - 1))
    return out


def routed_perm(comm, perm: Sequence[Tuple[int, int]]):
    """The transport-facing entry point: return ``perm`` unchanged when the
    communicator has no torus spec (or every edge is a direct link), else the
    cached :class:`RoutedPerm` lowering.  Derivation is memoized through the
    :mod:`repro.core.plans` cache (``REPRO_PLAN_CACHE=0`` re-derives — values
    are identical either way)."""
    spec = getattr(comm, "topo", None)
    edges = tuple((int(s), int(d)) for s, d in perm)
    if spec is None or (spec.max_hops(edges) <= 1 and not any(
            spec.link_slowdown(s, d) > 1.0 for s, d in edges if s != d)):
        return edges
    from repro.core import plans
    return plans._memo("route", (spec.key(), edges),
                       lambda: route_rounds(spec, edges),
                       "plan_hits", "plan_misses")
