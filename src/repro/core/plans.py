"""Persistent collective-plan cache — build a schedule once, replay it.

ACCL+ holds a precompiled *plan* in the collective engine that applications
replay call after call, instead of re-deriving chunk splits and round
schedules per invocation.  This module is that cache for ACCL-X: a
:class:`CommPlan` built once per ``(collective, communicator key, CommConfig,
shape/dtype)`` captures everything the comm layer derives at trace time —

- the :func:`~repro.core.streaming.aligned_chunks` wire-chunk layout,
- the greedy edge-coloring of a multi-neighbor exchange into ppermute rounds,
- ring/neighbor permutations (validated once, replayed as tuples),
- the ack-window dependency structure of ordered transport,

plus (for host-level entry points like the sweep engine) the **jitted
program** itself, so a repeated call pays zero rebuild *and* zero retrace.

Everything here is host-side Python: plans never hold traced values, only
static schedule data and compiled callables, so cached and uncached execution
are bitwise-identical by construction (enforced by ``tests/test_plans.py``).

Cache control:

- ``REPRO_PLAN_CACHE=0`` bypasses the cache entirely (every call re-derives);
- :func:`clear_cache` empties it (e.g. between benchmark phases);
- :func:`cache_stats` reports hit/miss counters, split by plan vs program —
  the sweep CLI surfaces these in its wall-clock summary;
- ``REPRO_PLAN_DIR=/path`` (or :func:`repro.core.planstore.configure`) adds
  the disk tier: plan entries persist as versioned JSON and traced programs
  through JAX's persistent compilation cache, so a *fresh process* starts
  warm — lookups go memory → disk → build, and every disk outcome lands on
  the ``plans.disk_hits`` / ``plans.disk_misses`` counters.

Keying/invalidation: a plan key is the full value tuple
``(kind, collective, comm_key, cfg_key, shape, dtype, extra)``.  Any change
to the config (``CommConfig`` is frozen), the communicator's axes/sizes, the
payload shape or dtype, or the pattern extras (edges, align, axis names)
produces a different key — there is no in-place mutation to invalidate, stale
entries are simply never looked up again.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import os
import threading
from typing import Any, Callable, Optional, Sequence

from repro.core import planstore
from repro.obs import metrics as obs_metrics

_LOCK = threading.RLock()
_CACHE: dict[tuple, Any] = {}
# Lookup sentinel: a cached value may legitimately be falsy or None (a build
# that derived "nothing to do"), so presence is tested against this object,
# never by truthiness.
_MISSING = object()
# Hit/miss counters live in the observability registry (repro.obs.metrics)
# under plans.<name>; cache_stats() below stays as a thin compatibility shim
# over them for existing callers/tests.
_STAT_NAMES = ("plan_hits", "plan_misses", "program_hits", "program_misses")
_STATS = {k: obs_metrics.registry().counter(f"plans.{k}")
          for k in _STAT_NAMES}


def cache_enabled() -> bool:
    """The cache is on unless ``REPRO_PLAN_CACHE=0`` (checked per call, so a
    test can toggle bypass at runtime)."""
    return os.environ.get("REPRO_PLAN_CACHE", "1") != "0"


def clear_cache() -> None:
    with _LOCK:
        _CACHE.clear()


def reset_stats() -> None:
    for c in _STATS.values():
        c.reset()
    planstore.reset_disk_stats()


def cache_stats() -> dict:
    """Compatibility shim over the :mod:`repro.obs.metrics` registry: the
    same ``{plan,program}_{hits,misses}`` + ``size`` dict this module always
    returned, now read from the shared counters, plus the disk-tier
    ``disk_{hits,misses,writes,corrupt}`` counts."""
    with _LOCK:
        out = {k: int(c.value) for k, c in _STATS.items()}
        out["size"] = len(_CACHE)
        out.update(planstore.disk_stats())
        return out


def _comm_key(comm) -> tuple:
    """Stable identity of a communicator: its axes, their sizes, and any
    virtual topology placed on it (a :class:`~repro.core.topology.TorusSpec`
    changes how multi-hop perms are lowered, so two communicators differing
    only in their spec must never alias a plan).  Accepts a Communicator, a
    plain axis-name tuple/str, or None."""
    if comm is None:
        return ()
    if hasattr(comm, "axis_names"):
        topo = getattr(comm, "topo", None)
        return (tuple(comm.axis_names), tuple(getattr(comm, "axis_sizes", ())),
                topo.key() if topo is not None else None)
    if isinstance(comm, str):
        return ((comm,), ())
    return (tuple(comm), ())


# Bump when the _cfg_key encoding changes shape: the stamp rides every
# persisted key, so old disk entries turn into misses instead of aliasing.
CFG_KEY_SCHEMA = "cfg-v2"


def _cfg_key(cfg) -> tuple:
    """Canonical, stably serializable identity of a CommConfig.

    ``dataclasses.astuple`` would yield enum *objects*, which JSON cannot
    carry and whose ordering is positional (a field reorder silently aliases
    old keys).  Instead each field becomes a ``(name, primitive)`` pair with
    enum members folded to their string values, stamped with
    :data:`CFG_KEY_SCHEMA` so any future encoding change invalidates every
    persisted key at once."""
    if cfg is None:
        return ()
    out: list = [CFG_KEY_SCHEMA]
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if isinstance(v, enum.Enum):
            v = v.value
        out.append((f.name, v))
    return tuple(out)


def _memo(kind: str, key: tuple, build: Callable[[], Any],
          hit_ctr: str, miss_ctr: str):
    if not cache_enabled():
        _STATS[miss_ctr].inc()
        return build()
    full = (kind,) + key
    # Hold the (reentrant) lock across lookup AND build: concurrent
    # same-key callers must not duplicate a multi-second jit compile or
    # double-count the miss.
    with _LOCK:
        cached = _CACHE.get(full, _MISSING)
        if cached is not _MISSING:
            _STATS[hit_ctr].inc()
            return cached
        store = planstore.active()
        persistable = store is not None and kind in planstore.DISK_KINDS
        if persistable:
            value = store.get(kind, key)
            if value is not planstore.MISSING:
                _STATS[hit_ctr].inc()
                _CACHE[full] = value
                return value
        value = build()
        _STATS[miss_ctr].inc()
        _CACHE[full] = value
        if persistable:
            store.put(kind, key, value)
        return value


# ----------------------------------------------------------------------
# Schedule fragments
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Wire-chunk layout of one streamed message: how many chunks, how many
    flat elements each, and which earlier chunk every chunk acks on
    (``-1`` = independent — unordered transport or inside the window)."""
    n_chunks: int
    chunk_elems: int
    ack_of: tuple[int, ...]

    @property
    def padded_elems(self) -> int:
        return self.n_chunks * self.chunk_elems


def _build_chunk_plan(size: int, itemsize: int, chunk_bytes: int,
                      max_chunks: int, ordered: bool, window: int,
                      align: int, equal_split: bool) -> ChunkPlan:
    nbytes = size * itemsize
    n = max(1, min(max_chunks, math.ceil(max(1, nbytes) / chunk_bytes)))
    per = max(1, math.ceil(size / n))
    if equal_split:
        # chunked_permute layout: exactly n equal chunks (zero-padded tail).
        chunk_elems = per
    else:
        # recv_slot-aligned layout: chunk boundaries land on `align`
        # multiples, so the chunk count may shrink below n.
        chunk_elems = max(align, math.ceil(per / align) * align)
        n = max(1, math.ceil(size / chunk_elems))
    ack = tuple((i - window) if (ordered and i >= window) else -1
                for i in range(n))
    return ChunkPlan(n_chunks=n, chunk_elems=chunk_elems, ack_of=ack)


def chunk_plan(shape: Sequence[int], dtype, cfg, align: int = 1,
               equal_split: bool = False) -> ChunkPlan:
    """Cached :func:`~repro.core.streaming.aligned_chunks` layout plus the
    ordered-transport ack structure for a message of ``shape``/``dtype``.

    ``equal_split=True`` reproduces the plain ``chunked_permute`` split
    (exactly ``num_chunks`` equal chunks); the default reproduces the
    ``align``-aware layout of ``aligned_chunks``."""
    import numpy as np
    dt = np.dtype(dtype)
    size = int(math.prod(shape)) if shape else 1
    from repro.core.config import Transport
    ordered = cfg.transport == Transport.ORDERED
    key = (size, dt.str, cfg.chunk_bytes, cfg.max_chunks, ordered,
           cfg.window, align, equal_split)
    return _memo("chunks", key,
                 lambda: _build_chunk_plan(size, dt.itemsize, cfg.chunk_bytes,
                                           cfg.max_chunks, ordered,
                                           cfg.window, align, equal_split),
                 "plan_hits", "plan_misses")


def _color_edges(edges: Sequence[tuple[int, int]]
                 ) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Greedy edge coloring into ppermute-able rounds (each round a valid
    permutation fragment).  The round count is Eq. 3's N_max."""
    rounds: list[list[tuple[int, int]]] = []
    for e in edges:
        placed = False
        for r in rounds:
            if all(e[0] != s and e[1] != d for s, d in r):
                r.append(tuple(e))
                placed = True
                break
        if not placed:
            rounds.append([tuple(e)])
    return tuple(tuple(r) for r in rounds)


def edge_rounds(edges: Sequence[tuple[int, int]]
                ) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Cached greedy edge-coloring of a neighbor list into rounds."""
    key = (tuple((int(s), int(d)) for s, d in edges),)
    return _memo("rounds", key, lambda: _color_edges(edges),
                 "plan_hits", "plan_misses")


def ring_perm(n: int, step: int = 1) -> tuple[tuple[int, int], ...]:
    """Cached ring permutation for an ``n``-rank communicator."""
    return _memo("ring", (n, step),
                 lambda: tuple((i, (i + step) % n) for i in range(n)),
                 "plan_hits", "plan_misses")


def validated_perm(comm, perm: Sequence[tuple[int, int]]
                   ) -> tuple[tuple[int, int], ...]:
    """Cached neighbor-perm validation: each rank sends at most once and all
    endpoints are inside the communicator.  Raises the same ``ValueError`` as
    ``Communicator.neighbor_perms`` on the first (and only) derivation."""
    edges = tuple((int(s), int(d)) for s, d in perm)
    ck = _comm_key(comm)

    def build():
        comm.neighbor_perms(edges)
        return edges

    return _memo("perm", (ck, edges), build, "plan_hits", "plan_misses")


# ----------------------------------------------------------------------
# The aggregate plan
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CommPlan:
    """One collective call's replayable schedule.

    Built once per ``(collective, communicator key, CommConfig, shape/dtype)``
    [+ pattern extras]; subsequent identical calls replay the derived
    structures without touching Python schedule code, and host-level callers
    can attach/reuse the jitted program via :meth:`program`.
    """
    collective: str
    comm_key: tuple
    cfg_key: tuple
    shape: tuple
    dtype: str
    chunks: Optional[ChunkPlan] = None
    rounds: tuple = ()                 # edge-color rounds (multi_neighbor)
    perms: tuple = ()                  # validated (src, dst) tuples per round
    ring: tuple = ()                   # ring permutation (ring collectives)
    extra: tuple = ()
    _program: Any = dataclasses.field(default=None, repr=False)

    def key(self) -> tuple:
        return (self.collective, self.comm_key, self.cfg_key, self.shape,
                self.dtype, self.extra)

    def program(self, build: Callable[[], Any] | None = None):
        """The plan's jitted program: built on first request, replayed after
        (the ACCL+ precompiled-plan replay).  ``build`` is only invoked on a
        miss; with the cache bypassed it runs every time."""
        if not cache_enabled():
            if build is None:
                return None
            _STATS["program_misses"].inc()
            prog = build()
            self._program = prog
            return prog
        # Hold the module lock across check AND build, same as _memo: two
        # threads racing a cold plan must not both pay a multi-second jit
        # build or double-count the miss.
        with _LOCK:
            if self._program is not None:
                _STATS["program_hits"].inc()
                return self._program
            if build is None:
                return None
            _STATS["program_misses"].inc()
            prog = build()
            self._program = prog
            return prog


def get_plan(collective: str, comm, cfg, shape: Sequence[int], dtype,
             align: int = 1, edges: Sequence[tuple[int, int]] | None = None,
             rounds: Sequence[Sequence[tuple[int, int]]] | None = None,
             extra: tuple = ()) -> CommPlan:
    """Fetch (or build) the :class:`CommPlan` for one collective call site.

    ``edges`` (multi-neighbor patterns) joins the key via the greedy round
    coloring; ``rounds`` keys a caller-supplied (already colored) round
    structure instead — each round is validated once against ``comm`` and
    replayed as ``plan.perms``; ``align`` keys the recv_slot-aligned chunk
    layout; ``extra`` carries collective-specific statics (e.g. split/concat
    axes)."""
    import numpy as np
    ck = _comm_key(comm)
    fk = _cfg_key(cfg)
    shape = tuple(int(s) for s in shape)
    dt = np.dtype(dtype).str
    ek = (tuple((int(s), int(d)) for s, d in edges)
          if edges is not None else None)
    rk = (tuple(tuple((int(s), int(d)) for s, d in r) for r in rounds)
          if rounds is not None else None)
    key = (collective, ck, fk, shape, dt, align, ek, rk, tuple(extra))

    def build() -> CommPlan:
        from repro.core.config import CommMode, Transport
        chunks = None
        if cfg is not None and cfg.mode == CommMode.STREAMING:
            chunks = _build_chunk_plan(
                int(math.prod(shape)) if shape else 1,
                np.dtype(dtype).itemsize, cfg.chunk_bytes, cfg.max_chunks,
                cfg.transport == Transport.ORDERED, cfg.window, align,
                equal_split=False)
        colored: tuple = rk if rk is not None else ()
        if ek is not None:
            colored = _color_edges(ek)
        if colored and comm is not None and hasattr(comm, "neighbor_perms"):
            for r in colored:
                comm.neighbor_perms(r)
        ring: tuple = ()
        # A ring is only well-defined over a single axis; a multi-axis
        # communicator's global rank order corresponds to no physical ring.
        if (comm is not None and getattr(comm, "axis_sizes", None)
                and len(comm.axis_sizes) == 1):
            n = comm.axis_sizes[0]
            ring = tuple((i, (i + 1) % n) for i in range(n))
        return CommPlan(collective=collective, comm_key=ck, cfg_key=fk,
                        shape=shape, dtype=dt, chunks=chunks, rounds=colored,
                        perms=colored, ring=ring, extra=tuple(extra))

    return _memo("plan", key, build, "plan_hits", "plan_misses")


# ----------------------------------------------------------------------
# Jitted-program cache (host-level entry points)
# ----------------------------------------------------------------------

def jitted_program(key: Sequence, build: Callable[[], Callable],
                   example_args: tuple | None = None) -> Callable:
    """Cache a compiled host-level program under a value key.

    The sweep engine routes every microbenchmark/consumer-loop program
    through this, so a warm sweep (same process, same collective/config/
    size/topology) replays the compiled program with zero rebuild and zero
    retrace — the plan-cache half of the warm-sweep wall-clock win.

    With ``example_args`` given AND a plan store active
    (``REPRO_PLAN_DIR``), the program additionally persists *across
    processes*: on a miss the jitted callable is AOT-compiled against the
    example arguments and the executable serialized to disk; a fresh
    process deserializes and replays it, paying neither trace nor XLA
    compile — the ACCL+ precompiled-plan restart.  Callers must then invoke
    the returned program with arguments matching ``example_args`` in shape,
    dtype, and sharding.  When AOT compile/serialization is unavailable the
    plain jitted callable is returned (memory-only, as before)."""
    full = tuple(key)
    store = planstore.active() if cache_enabled() else None
    if example_args is None or store is None:
        return _memo("program", full, build,
                     "program_hits", "program_misses")
    with _LOCK:
        cached = _CACHE.get(("program",) + full, _MISSING)
        if cached is not _MISSING:
            _STATS["program_hits"].inc()
            return cached
        value = store.get_executable(full)
        if value is not planstore.MISSING:
            _STATS["program_hits"].inc()
            _CACHE[("program",) + full] = value
            return value
        fn = build()
        _STATS["program_misses"].inc()
        compiled = None
        try:
            compiled = fn.lower(*example_args).compile()
        except Exception:  # noqa: BLE001 — AOT is an optimization only
            compiled = None
        if compiled is not None:
            store.put_executable(full, compiled)
            fn = compiled
        _CACHE[("program",) + full] = fn
        return fn
