"""Reliable wire transport — the ACCL TCP/UDP stack choice as a protocol layer.

The paper's central configuration axis is the network stack itself: ACCL's
UDP stack wins on latency but gives up delivery guarantees; TCP pays
sequence/ack/retransmit overhead for a lossless wire.  ACCL+ generalizes
this into a pluggable reliability protocol under the collectives.  This
module is that layer for the emulation:

- :class:`WireFaults` — deterministic, seeded chunk-level fault schedules
  (drop / duplicate / reorder), the wire-granularity extension of
  :mod:`repro.runtime.faults`' step-level schedules.  Activated with
  :func:`inject`; every traced message under the context draws its own
  reproducible outcome.
- :func:`simulate_delivery` — an honest host-side simulation of the
  sliding-window protocol: per-chunk sequence stamps, a bounded send
  window, receiver-side dedup + in-order reassembly flush, ack-timeout
  detection, and retransmission with capped exponential backoff.  The
  output is a static :class:`DeliveryPlan`: the exact slot schedule the
  wire will execute, plus protocol counters.
- :func:`plan_for` — the entry point :mod:`repro.core.streaming` calls per
  message.  Clean messages (or no active fault context) return ``None`` so
  the zero-fault fast path stays byte-identical to the unprotected
  pipeline; faulted messages return a memoized plan
  (:func:`repro.core.plans._memo` kind ``"wire"`` — retransmit schedules
  are plan-cacheable and persistable like chunk plans).

Every slot in a plan — original transmission, lost transmission, dropped
duplicate, backoff hold — is executed by the streaming layer as a real
permute round (value-preserving, like the topology layer's degraded-link
hold rounds), so recovery has a measurable latency price and the tuner can
learn that jumbo frames win clean links while small segments win lossy
ones.

This module is host-pure (no jax imports): the protocol properties are
directly testable with hypothesis, and the jax executor lives in
:mod:`repro.core.streaming`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
from typing import Iterable, Optional, Sequence

from repro.core.config import CommConfig, Reliability
from repro.obs import metrics as obs_metrics

# Slot actions.  Only DELIVER lands a chunk in the receiver's reassembly
# buffer; the other three are pure latency (their wire outputs are threaded
# through optimization barriers so XLA cannot dead-code them away).
DELIVER = "deliver"  # transmission arrives and is accepted (first copy)
LOST = "lost"        # transmission executed, receiver never sees it
DUP = "dup"          # duplicate copy, discarded by sequence-number dedup
HOLD = "hold"        # sender stalled: ack wait or retransmit backoff


@dataclasses.dataclass(frozen=True)
class Slot:
    """One wire round: which sequence number is on the wire and its fate."""
    seq: int
    action: str
    attempt: int = 0  # 0 = original transmission, k = k-th retransmit


@dataclasses.dataclass(frozen=True)
class DeliveryPlan:
    """Static schedule of wire rounds that delivers every chunk exactly once.

    ``slots`` is what the streaming layer executes; the counters are what
    the protocol did to get there (fed into the ``wire.*`` metrics).
    """
    n_chunks: int
    slots: tuple  # tuple[Slot, ...]
    retransmits: int
    dup_dropped: int
    timeouts: int
    backoff_holds: int

    @property
    def extra_slots(self) -> int:
        """Wire rounds beyond the lossless minimum — the latency price."""
        return len(self.slots) - self.n_chunks

    def delivered_seqs(self) -> list:
        return [s.seq for s in self.slots if s.action == DELIVER]


def backoff_holds(attempt: int, base: int, cap: int) -> int:
    """Hold slots before retransmit ``attempt`` (1-indexed): capped
    exponential ``min(base * 2**(attempt-1), cap)``.  Monotonically
    non-decreasing in ``attempt`` and bounded by ``cap`` (hypothesis-tested
    properties)."""
    if attempt < 1:
        raise ValueError(f"attempt is 1-indexed, got {attempt}")
    return min(base * (2 ** (attempt - 1)), cap)


def simulate_delivery(n_chunks: int, *,
                      window: int,
                      ack_timeout: int,
                      max_retransmits: int,
                      backoff_base: int,
                      backoff_cap: int,
                      drops: Iterable[tuple] = (),
                      dups: Iterable[int] = (),
                      order: Optional[Sequence[int]] = None) -> DeliveryPlan:
    """Simulate the sliding-window protocol over a faulty wire.

    ``drops`` is a set of ``(seq, attempt)`` transmissions the wire loses
    (attempt 0 = the original send); ``dups`` is a set of seqs whose
    original transmission is duplicated on the wire; ``order`` is the
    transmission order of the original sends (a permutation of
    ``range(n_chunks)`` — the wire-reorder fault).

    One transmission (or hold) occupies one slot; acks for delivered chunks
    arrive at the end of the same slot (the emulated wire is a synchronous
    sequence of permute rounds, so RTT is folded into ``ack_timeout``'s
    units).  A lost transmission is noticed ``ack_timeout`` slots after it
    was sent, then retransmitted after ``backoff_holds(attempt)`` hold
    slots.  Raises ``ValueError`` if a drop schedule exceeds
    ``max_retransmits`` for any chunk: a GUARANTEED transport must deliver,
    so the fault source (not the protocol) is required to relent within the
    cap — :meth:`WireFaults.outcomes` never drops the final permitted
    attempt.
    """
    if n_chunks < 1:
        raise ValueError("n_chunks must be >= 1")
    order = list(range(n_chunks)) if order is None else list(order)
    if sorted(order) != list(range(n_chunks)):
        raise ValueError(f"order must be a permutation of range({n_chunks}), "
                         f"got {order!r}")
    drops = frozenset((int(s), int(a)) for s, a in drops)
    dups = frozenset(int(s) for s in dups)
    for seq, attempt in drops:
        if attempt > max_retransmits:
            raise ValueError(
                f"drop schedule loses seq {seq} at attempt {attempt} > "
                f"max_retransmits={max_retransmits}: undeliverable under the "
                f"retransmit cap")

    pending = list(order)      # original sends not yet on the wire
    dup_queue: list[int] = []  # duplicate copies queued behind the original
    # seq -> state of an unacked (lost) transmission awaiting recovery:
    #   sent: slot index of the lost transmission
    #   attempt: attempts used so far (1 = original send failed)
    #   holds_left: backoff holds still owed once the timeout has fired
    #   timed_out: ack_timeout expired, timeout counted
    unacked: dict = {}
    delivered: set = set()
    slots: list[Slot] = []
    retransmits = dup_dropped = timeouts = holds = 0

    def transmit(seq: int, attempt: int) -> None:
        nonlocal retransmits, dup_dropped
        if (seq, attempt) in drops:
            slots.append(Slot(seq, LOST, attempt))
            # A lost transmission of an already-delivered chunk can only be
            # a wire-artifact duplicate trailing behind a successful
            # retransmit: the receiver has the chunk and its ack is on the
            # books, so the loss needs no recovery.  (Arming a retransmit
            # here would loop forever — every retry would be deduped
            # without ever clearing the unacked state.)
            if seq not in delivered:
                unacked[seq] = {"sent": len(slots) - 1,
                                "attempt": attempt + 1,
                                "holds_left": None, "timed_out": False}
        elif seq in delivered:
            slots.append(Slot(seq, DUP, attempt))
            dup_dropped += 1
            unacked.pop(seq, None)  # dup ack clears any stale recovery state
        else:
            slots.append(Slot(seq, DELIVER, attempt))
            delivered.add(seq)
            unacked.pop(seq, None)
        if attempt > 0:
            retransmits += 1

    while len(delivered) < n_chunks or dup_queue:
        now = len(slots)
        # 1) Service timed-out chunks first (retransmission is the priority
        #    traffic — the window is stalled on these seqs).
        ready = None
        for seq in sorted(unacked):
            st = unacked[seq]
            if not st["timed_out"]:
                if now - st["sent"] >= ack_timeout:
                    st["timed_out"] = True
                    st["holds_left"] = backoff_holds(
                        st["attempt"], backoff_base, backoff_cap)
                    timeouts += 1
                else:
                    continue
            if st["holds_left"] > 0:
                st["holds_left"] -= 1
                holds += 1
                slots.append(Slot(seq, HOLD, st["attempt"]))
                ready = "held"
                break
            ready = seq
            break
        if ready == "held":
            continue
        if ready is not None:
            transmit(ready, unacked[ready]["attempt"])
            continue
        # 2) Window permitting, the next original transmission.
        if pending and len(unacked) < window:
            seq = pending.pop(0)
            transmit(seq, 0)
            if seq in dups:
                dup_queue.append(seq)
            continue
        # 3) Wire artifacts: duplicate copies trailing the originals.
        if dup_queue:
            transmit(dup_queue.pop(0), 0)
            continue
        # 4) Nothing sendable: the window is full of unacked chunks whose
        #    timeouts have not fired yet — the sender stalls a slot.
        stall_seq = min(unacked)
        holds += 1
        slots.append(Slot(stall_seq, HOLD, unacked[stall_seq]["attempt"]))

    plan = DeliveryPlan(n_chunks=n_chunks, slots=tuple(slots),
                        retransmits=retransmits, dup_dropped=dup_dropped,
                        timeouts=timeouts, backoff_holds=holds)
    seqs = plan.delivered_seqs()
    if sorted(seqs) != list(range(n_chunks)) or len(seqs) != n_chunks:
        raise AssertionError(f"protocol bug: delivered {seqs!r}")  # pragma: no cover
    return plan


# ----------------------------------------------------------------------
# Fault schedules + the injection context
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireFaults:
    """A deterministic chunk-level fault schedule.

    Rates draw per-message outcomes from a string-seeded PRNG (stable
    across processes, like ``FaultInjector.edge_latency_samples``); the
    ``*_events`` sets pin exact outcomes for unit tests:

    - ``drop_events``: ``(msg, seq, attempt)`` transmissions the wire loses
    - ``dup_events``: ``(msg, seq)`` originals duplicated on the wire
    - ``order_events``: ``(msg, (s0, s1, ...))`` explicit tx order per msg

    ``msg`` is the trace-order message index within an :func:`inject`
    context (reset to 0 on entry, so two identical runs under the same
    schedule draw identical outcomes).
    """
    seed: int = 0
    drop: float = 0.0     # per-transmission loss probability
    dup: float = 0.0      # per-chunk duplicate probability
    reorder: float = 0.0  # per-adjacent-pair tx-order swap probability
    drop_events: frozenset = frozenset()
    dup_events: frozenset = frozenset()
    order_events: tuple = ()

    def __post_init__(self):
        for name in ("drop", "dup", "reorder"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} rate must be in [0, 1), got {v}")

    def lossy(self) -> bool:
        return bool(self.drop or self.dup or self.reorder
                    or self.drop_events or self.dup_events
                    or self.order_events)

    def outcomes(self, msg: int, n_chunks: int, max_retransmits: int
                 ) -> tuple:
        """``(drops, dups, order)`` for message ``msg`` — deterministic in
        (seed, msg).  Seeded drops never hit attempt ``max_retransmits``
        (the emulated wire relents within the retransmit cap, keeping
        GUARANTEED deliverable); explicit ``drop_events`` are taken as
        given and validated by :func:`simulate_delivery`."""
        rng = random.Random(f"wire:{self.seed}:{msg}")
        drops = {(s, a) for m, s, a in self.drop_events if m == msg}
        dups = {s for m, s in self.dup_events if m == msg}
        order = list(range(n_chunks))
        for m, o in self.order_events:
            if m == msg:
                order = list(o)
        if self.drop > 0.0:
            for seq in range(n_chunks):
                for attempt in range(max_retransmits):
                    if rng.random() < self.drop:
                        drops.add((seq, attempt))
                    else:
                        break  # this attempt succeeds; later ones unreachable
        if self.dup > 0.0:
            dups.update(s for s in range(n_chunks)
                        if rng.random() < self.dup)
        if self.reorder > 0.0:
            for i in range(n_chunks - 1):
                if rng.random() < self.reorder:
                    order[i], order[i + 1] = order[i + 1], order[i]
        return frozenset(drops), frozenset(dups), tuple(order)


_LOCK = threading.Lock()
_ACTIVE: Optional[WireFaults] = None
_MSG_COUNTER = 0


def active() -> Optional[WireFaults]:
    """The WireFaults schedule currently injected, or None (lossless)."""
    return _ACTIVE


@contextlib.contextmanager
def inject(faults: Optional[WireFaults]):
    """Activate a chunk-level fault schedule for every message traced under
    the context.  Resets the trace-order message counter on entry, so a run
    under the same schedule is bitwise reproducible.  ``None`` is a no-op
    (callers can thread an optional schedule unconditionally)."""
    global _ACTIVE, _MSG_COUNTER
    with _LOCK:
        prev, prev_ctr = _ACTIVE, _MSG_COUNTER
        _ACTIVE, _MSG_COUNTER = faults, 0
    try:
        yield faults
    finally:
        with _LOCK:
            _ACTIVE, _MSG_COUNTER = prev, prev_ctr


def _next_message_id() -> int:
    global _MSG_COUNTER
    with _LOCK:
        msg = _MSG_COUNTER
        _MSG_COUNTER += 1
    return msg


# ----------------------------------------------------------------------
# Plan cache + the streaming entry point
# ----------------------------------------------------------------------

def delivery_plan(n_chunks: int, cfg: CommConfig, drops: frozenset,
                  dups: frozenset, order: tuple) -> DeliveryPlan:
    """Memoized :func:`simulate_delivery` — retransmit schedules are static
    per (message geometry, reliability knobs, fault outcome), so they are
    plan-cacheable exactly like chunk plans (kind ``"wire"``)."""
    from repro.core import plans
    key = (int(n_chunks), int(cfg.window), int(cfg.ack_timeout),
           int(cfg.max_retransmits), int(cfg.backoff_base),
           int(cfg.backoff_cap), tuple(sorted(drops)), tuple(sorted(dups)),
           tuple(order))
    return plans._memo(
        "wire", key,
        lambda: simulate_delivery(
            n_chunks, window=cfg.window, ack_timeout=cfg.ack_timeout,
            max_retransmits=cfg.max_retransmits,
            backoff_base=cfg.backoff_base, backoff_cap=cfg.backoff_cap,
            drops=drops, dups=dups, order=order),
        "plan_hits", "plan_misses")


def plan_for(cfg: CommConfig, n_chunks: int) -> Optional[DeliveryPlan]:
    """Per-message protocol decision, called by the streaming layer at trace
    time.  Returns ``None`` on the fast path (no active fault context, or a
    clean message) — the caller then runs the existing unprotected pipeline
    byte-for-byte.  Raises for BEST_EFFORT under injected faults: the
    UDP-like stack has no recovery machinery, so a lossy wire breaks its
    delivery contract (the paper's reason TCP exists)."""
    faults = active()
    if faults is None or not faults.lossy():
        return None
    if cfg.reliability != Reliability.GUARANTEED:
        raise ValueError(
            "wire faults are injected but cfg.reliability is BEST_EFFORT: "
            "the UDP-like stack cannot recover lost chunks. Select "
            "Reliability.GUARANTEED (or remove the fault injection).")
    msg = _next_message_id()
    drops, dups, order = faults.outcomes(msg, n_chunks, cfg.max_retransmits)
    if not drops and not dups and order == tuple(range(n_chunks)):
        return None  # clean message under a lossy context: fast path
    return delivery_plan(n_chunks, cfg, drops, dups, order)


def record(plan: DeliveryPlan, cfg: CommConfig, hw=None) -> None:
    """Feed one applied plan into the ``wire.*`` metrics.  Counters track
    protocol events; ``wire.backoff_ms`` observes the *modeled* stall time
    (hold slots x the Eq. 1 per-chunk wire time — the emulation's slot
    clock), so the histogram is comparable to the latency model's
    retransmit pricing."""
    reg = obs_metrics.registry()
    reg.counter("wire.messages_recovered").inc()
    if plan.retransmits:
        reg.counter("wire.retransmits").inc(plan.retransmits)
    if plan.dup_dropped:
        reg.counter("wire.dup_dropped").inc(plan.dup_dropped)
    if plan.timeouts:
        reg.counter("wire.timeouts").inc(plan.timeouts)
    if plan.backoff_holds:
        from repro.core import latmodel
        from repro.core.config import V5E
        hw = hw or V5E
        slot_s = latmodel.l_k(cfg, hw) + cfg.chunk_bytes / hw.ici_bw
        reg.histogram("wire.backoff_ms").observe(
            plan.backoff_holds * slot_s * 1e3)


def wire_counters() -> dict:
    """Snapshot of the wire protocol counters (0 when never incremented)."""
    reg = obs_metrics.registry()
    return {name: int(reg.counter(f"wire.{name}").value)
            for name in ("retransmits", "dup_dropped", "timeouts",
                         "messages_recovered")}
