"""ACCL-X plugins — compression and arithmetic.

The paper's ACCL ships compression and arithmetic plugins that can be compiled
out to save resources ("ACCL minimal", Fig. 3).  Here:

- **compression plugin** — a per-block int8 (or bf16-cast) wire format for
  collectives.  Used by the explicit ring collectives to shrink bytes-on-wire
  4x (int8) or 2x (bf16); the Pallas kernel twin lives in
  ``repro.kernels.quant`` (this module is the jnp reference used on CPU).
- **arithmetic plugin** — the reduction-operator table used by reduce-style
  collectives (sum/max/min/mean with fp32 accumulation for low-precision
  inputs).

Disabling a plugin in :class:`~repro.core.config.CommConfig` removes the
corresponding ops from the compiled program — the TPU analogue of the LUT/DSP
savings in the paper.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core.config import CommConfig, Compression

# ----------------------------------------------------------------------
# Compression plugin: per-block symmetric int8 quantization
# ----------------------------------------------------------------------

def _pad_to(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % multiple
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_int8(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization. Returns (q, scales).

    q: int8 of shape (nblocks, block); scales: f32 (nblocks, 1).
    """
    flat, _ = _pad_to(x, block)
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def wire_encode(x: jnp.ndarray, cfg: CommConfig):
    """Encode a message for the wire per the comm config.

    Returns (payload_pytree, decode_fn). With compression disabled this is an
    identity (and emits zero extra ops — the 'minimal build' property).
    """
    if cfg.compression == Compression.NONE:
        return x, lambda p: p
    if not cfg.enable_compression_plugin:  # defensive; CommConfig validates too
        raise ValueError("compression plugin not built")
    if cfg.compression == Compression.BF16:
        orig = x.dtype
        return x.astype(jnp.bfloat16), lambda p: p.astype(orig)
    if cfg.compression == Compression.INT8:
        q, s = quantize_int8(x, cfg.quant_block)
        shape, dtype = x.shape, x.dtype
        return (q, s), lambda p: dequantize_int8(p[0], p[1], shape, dtype)
    raise ValueError(f"unknown compression {cfg.compression}")


# ----------------------------------------------------------------------
# Arithmetic plugin: reduction-operator table
# ----------------------------------------------------------------------

def _acc_sum(a, b):
    # fp32 accumulation for low-precision inputs (MXU-style accumulate).
    if a.dtype in (jnp.bfloat16, jnp.float16):
        return (a.astype(jnp.float32) + b.astype(jnp.float32)).astype(a.dtype)
    return a + b


_REDUCE_OPS: dict[str, Callable] = {
    "sum": _acc_sum,
    "max": lax.max,
    "min": lax.min,
    "prod": lax.mul,
}


def reduce_op(name: str, cfg: CommConfig) -> Callable:
    if not cfg.enable_arithmetic_plugin:
        raise ValueError(
            f"reduction '{name}' requires the arithmetic plugin, which was "
            "compiled out (enable_arithmetic_plugin=False)")
    try:
        return _REDUCE_OPS[name]
    except KeyError:
        raise ValueError(f"unknown reduction '{name}'") from None
