"""Communication scheduling — host-scheduled vs. fused (device) execution.

The paper's central latency lever: scheduling a communication command from the
host costs a kernel invocation (~30 µs through XRT), while a control kernel in
PL issues it in sub-µs.  On TPU the same dichotomy exists between

- **host scheduling**: each phase of a step (compute / comm / compute) is its
  own jitted program; the host re-dispatches between phases.  Every dispatch
  pays host-runtime latency and, worse, serializes the device.
- **fused scheduling**: the entire step is ONE jitted program; the TPU's
  sequencer issues collective DMAs directly (the "custom control kernel" of
  Fig. 1b).

Both runners execute the same phase list and produce identical numerics — the
difference is dispatch count, which the latency model converts to time.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Sequence

import jax

from repro.core.config import CommConfig, HardwareSpec, Scheduling, V5E
from repro.core import latmodel


@dataclasses.dataclass
class Phase:
    """One schedulable unit: a pure function carry -> carry."""
    name: str
    fn: Callable[[Any], Any]
    is_comm: bool = False


class HostScheduledRunner:
    """One jit (= one host dispatch) per phase — the MPI+PCIe-style baseline.

    ``dispatch_count`` feeds the model: step latency includes
    n_dispatches · l_k on top of device time.
    """

    def __init__(self, phases: Sequence[Phase], hw: HardwareSpec = V5E):
        self.phases = list(phases)
        self.hw = hw
        self._jitted = [jax.jit(p.fn) for p in self.phases]
        self.dispatch_count = 0

    def run_step(self, carry):
        for f in self._jitted:
            carry = f(carry)
            jax.block_until_ready(carry)  # host waits between phases
            self.dispatch_count += 1
        return carry

    def modeled_dispatch_overhead(self) -> float:
        return len(self.phases) * self.hw.host_dispatch


class FusedRunner:
    """All phases fused into a single program — PL-scheduled analogue."""

    def __init__(self, phases: Sequence[Phase], hw: HardwareSpec = V5E):
        self.phases = list(phases)
        self.hw = hw

        def fused(carry):
            for p in self.phases:
                carry = p.fn(carry)
            return carry

        self._jitted = jax.jit(fused)
        self.dispatch_count = 0

    def run_step(self, carry):
        carry = self._jitted(carry)
        self.dispatch_count += 1
        return carry

    def modeled_dispatch_overhead(self) -> float:
        n_comm = sum(1 for p in self.phases if p.is_comm)
        return self.hw.host_dispatch + n_comm * self.hw.fused_dispatch

    def lower(self, carry):
        return self._jitted.lower(carry)


def make_runner(phases: Sequence[Phase], cfg: CommConfig,
                hw: HardwareSpec = V5E):
    if cfg.scheduling == Scheduling.HOST:
        return HostScheduledRunner(phases, hw)
    return FusedRunner(phases, hw)


def measure_dispatch_overhead(n: int = 200) -> float:
    """Calibrate this host's per-dispatch cost (the l_k measurement of §3.4)."""
    f = jax.jit(lambda x: x + 1)
    x = jax.numpy.zeros((8,), jax.numpy.float32)
    x = jax.block_until_ready(f(x))  # warm up / compile
    t0 = time.perf_counter()
    for _ in range(n):
        x = f(x)
    jax.block_until_ready(x)
    return (time.perf_counter() - t0) / n
