"""Serving entry points: shard_map'd prefill and decode_step builders.

Used by the dry-run (abstract lowering) and by examples/serve_lm.py
(concrete batched serving with greedy sampling).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.config import CommConfig
from repro.launch import input_specs as isp
from repro.models import decode as dec
from repro.models import sharding, transformer
from repro.models.common import MeshContext, ModelConfig, Runtime


def cache_len(cfg: ModelConfig, shape: isp.ShapeSpec) -> int:
    if cfg.family == "vlm":
        return shape.seq_len + cfg.num_patches
    return shape.seq_len


def serve_runtime(cfg: ModelConfig, mesh, comm: CommConfig,
                  shape: isp.ShapeSpec, attn_tiling: str = "auto") -> Runtime:
    mesh_ctx = MeshContext.from_mesh(mesh)
    return Runtime(cfg=cfg, mesh=mesh_ctx, comm=comm,
                   attn_tiling=attn_tiling,
                   seq_axes=isp.decode_seq_axes(shape, mesh))


def build_serve_fn(cfg: ModelConfig, mesh, comm: CommConfig,
                   shape: isp.ShapeSpec, attn_tiling: str = "auto"):
    """Returns (rt, jitted_fn, abstract_args) for the dry-run / serving.

    prefill kind: fn(params, batch) -> ServeState
    decode kind:  fn(params, token, state) -> ServeState
    """
    rt = serve_runtime(cfg, mesh, comm, shape, attn_tiling)
    mesh_ctx = rt.mesh
    abstract_params = jax.eval_shape(
        lambda k: transformer.init_model(k, cfg, mesh.shape["model"]),
        jax.random.PRNGKey(0))
    pspec = sharding.param_specs(abstract_params, cfg, mesh_ctx, fsdp=False)

    caches_abs, cache_spec = isp.decode_caches_abstract(cfg, shape, mesh)
    bx_axes = isp.decode_batch_axes(shape, mesh)
    bx = bx_axes if bx_axes else None
    tp = mesh.shape["model"]
    vocab_sharded = cfg.vocab_size % tp == 0 and tp > 1
    logits_spec = P(bx, "model") if vocab_sharded else P(bx, None)
    state_spec = dec.ServeState(caches=cache_spec, last_logits=logits_spec,
                                length=P())

    if shape.kind == "prefill":
        batch, bspec = isp.prefill_inputs(cfg, shape, mesh)
        max_len = cache_len(cfg, shape)

        def fn(params, batch):
            return dec.prefill(params, batch, rt, max_len)

        sm = compat.shard_map(fn, mesh=mesh, in_specs=(pspec, bspec),
                           out_specs=state_spec, check_vma=False)
        return rt, jax.jit(sm), (abstract_params, batch)

    # decode
    (token, state_abs0), (token_spec, state_spec_in) = isp.decode_inputs(
        cfg, shape, mesh)
    state_abs = dec.ServeState(caches=caches_abs,
                               last_logits=state_abs0.last_logits,
                               length=state_abs0.length)

    def fn(params, token, state):
        return dec.decode_step(params, token, state, rt)

    sm = compat.shard_map(fn, mesh=mesh, in_specs=(pspec, token_spec, state_spec),
                       out_specs=state_spec, check_vma=False)
    return rt, jax.jit(sm), (abstract_params, token, state_abs)
