"""Serving entry points: shard_map'd prefill and decode_step builders.

Used by the dry-run (abstract lowering), examples/serve_lm.py (continuous-
batching serving with greedy sampling), and tests/test_serving.py.

``comm="auto"`` resolves a *per-phase* CommConfig from the TuneDB: prefill
and decode are distinct tuned consumers (``sweep.CONSUMERS['all_reduce']``)
with opposite cost structures — decode's tiny latency-bound per-token
combines vs prefill's throughput-bound bulk reduces — so the two phases
select different configs from the same measurements
(``select_config(consumer=..., objective="e2e")``).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.config import CommConfig
from repro.launch import input_specs as isp
from repro.models import decode as dec
from repro.models import sharding, transformer
from repro.models.common import MeshContext, ModelConfig, Runtime

# Which sweep consumer loop stands in for each serving phase when
# ``comm="auto"`` resolves a config (the per-phase half of the tuned path).
PHASE_CONSUMERS = {"prefill": "prefill", "decode": "decode_step"}


def cache_len(cfg: ModelConfig, shape: isp.ShapeSpec) -> int:
    if cfg.family == "vlm":
        return shape.seq_len + cfg.num_patches
    return shape.seq_len


def serve_msg_bytes(cfg: ModelConfig, shape: isp.ShapeSpec) -> int:
    """Dominant TP-collective message size of a serving phase (bytes).

    Both phases' per-layer combine carries (tokens, d_model) f32 partials:
    decode moves one token per sequence, prefill the whole prompt — the
    message-size axis along which the TuneDB answers diverge.
    """
    tokens = shape.global_batch
    if shape.kind == "prefill":
        tokens *= shape.seq_len
    return 4 * cfg.d_model * tokens


def resolve_serve_comm(cfg: ModelConfig, mesh, comm,
                       shape: isp.ShapeSpec,
                       tune_db_path=None,
                       objective: str = "e2e") -> CommConfig:
    """Per-phase ``comm="auto"`` resolution for the serving path.

    A concrete ``CommConfig`` passes through untouched.  ``"auto"`` asks
    the autotuner for this phase's consumer loop (``PHASE_CONSUMERS``) at
    this phase's message size, ranking by the measured consumer-loop time
    (``objective="e2e"`` — a decode step is exactly the consumer whose
    fixed per-op cost the bare microbench cannot see).
    """
    if isinstance(comm, CommConfig):
        return comm
    from repro.core.collectives import resolve_config
    consumer = PHASE_CONSUMERS.get(shape.kind, "decode_step")
    return resolve_config(comm, "all_reduce", serve_msg_bytes(cfg, shape),
                          mesh=mesh, db_path=tune_db_path,
                          objective=objective, consumer=consumer)


def serve_runtime(cfg: ModelConfig, mesh, comm,
                  shape: isp.ShapeSpec, attn_tiling: str = "auto",
                  tune_db_path=None, objective: str = "e2e") -> Runtime:
    comm = resolve_serve_comm(cfg, mesh, comm, shape,
                              tune_db_path=tune_db_path, objective=objective)
    mesh_ctx = MeshContext.from_mesh(mesh)
    return Runtime(cfg=cfg, mesh=mesh_ctx, comm=comm,
                   attn_tiling=attn_tiling,
                   seq_axes=isp.decode_seq_axes(shape, mesh))


def build_serve_fn(cfg: ModelConfig, mesh, comm,
                   shape: isp.ShapeSpec, attn_tiling: str = "auto",
                   tune_db_path=None, objective: str = "e2e",
                   cache_capacity: int | None = None):
    """Returns (rt, jitted_fn, abstract_args) for the dry-run / serving.

    prefill kind: fn(params, batch) -> ServeState
    decode kind:  fn(params, token, state) -> ServeState

    ``comm`` may be a concrete ``CommConfig`` or ``"auto"`` (per-phase
    TuneDB selection; the resolved config is ``rt.comm``).

    ``cache_capacity`` (prefill only) decouples the KV-cache capacity from
    the prompt length: build the prefill spec at the prompt's own sequence
    length while the caches it returns cover ``cache_capacity`` positions
    (prompt + planned generation).  Defaults to ``cache_len(cfg, shape)``
    — a cache exactly as long as the prompt.
    """
    rt = serve_runtime(cfg, mesh, comm, shape, attn_tiling,
                       tune_db_path=tune_db_path, objective=objective)
    abstract_params = jax.eval_shape(
        lambda k: transformer.init_model(k, cfg, mesh.shape["model"]),
        jax.random.PRNGKey(0))
    pspec = sharding.param_specs(abstract_params, cfg, rt.mesh, fsdp=False)

    # One spec source for both phases: decode_inputs' ServeState spec tree
    # (cache layout, vocab-sharded logits, scalar length) is structural —
    # it does not depend on the fed sequence length — so prefill's
    # out_specs and decode's in/out_specs can never drift.
    (token, state_abs), (token_spec, state_spec) = isp.decode_inputs(
        cfg, shape, mesh)

    if shape.kind == "prefill":
        min_len = cache_len(cfg, shape)
        max_len = cache_capacity if cache_capacity is not None else min_len
        if max_len < min_len:
            raise ValueError(
                f"cache_capacity={max_len} is smaller than the prefill "
                f"shape needs ({min_len}: prompt"
                + (" + patch prefix" if cfg.family == "vlm" else "") + ")")
        batch, bspec = isp.prefill_inputs(cfg, shape, mesh)

        def fn(params, batch):
            return dec.prefill(params, batch, rt, max_len)

        sm = compat.shard_map(fn, mesh=mesh, in_specs=(pspec, bspec),
                              out_specs=state_spec, check_vma=False)
        return rt, jax.jit(sm), (abstract_params, batch)

    # decode
    if cache_capacity is not None:
        raise ValueError("cache_capacity applies to the prefill builder; "
                         "a decode ShapeSpec's seq_len IS the capacity")

    def fn(params, token, state):
        return dec.decode_step(params, token, state, rt)

    sm = compat.shard_map(fn, mesh=mesh,
                          in_specs=(pspec, token_spec, state_spec),
                          out_specs=state_spec, check_vma=False)
    return rt, jax.jit(sm), (abstract_params, token, state_abs)
