"""Production training loop: data prefetch + async checkpoints + watchdog +
preemption drain, over the shard_map'd train step."""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import AsyncCheckpointer
from repro.data.pipeline import DataConfig, PrefetchLoader, SyntheticLM
from repro.launch import setup as setup_mod
from repro.obs import trace as obs_trace
from repro.runtime.fault_tolerance import PreemptionGuard, StepWatchdog


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    accum_steps: int = 1


def train(sess: setup_mod.Session, data_cfg: DataConfig, loop: LoopConfig,
          log: Callable[[str], None] = print,
          guard: Optional[PreemptionGuard] = None,
          faults=None):
    """Run the training loop.

    ``guard`` lets a caller share one :class:`PreemptionGuard` across
    loops (or pre-arm a software drain via ``guard.request()``); by default
    the loop installs its own.  ``faults`` (a
    :class:`repro.runtime.faults.FaultInjector`) is polled at every step
    boundary: stragglers inject host delay, ``Preempt`` events request the
    drain, and ``RankLost`` raises
    :class:`~repro.runtime.faults.RankLostError` out of the loop — after an
    emergency checkpoint at the last completed step, so the elastic restart
    (``elastic_restore``) resumes from exactly where the rank died.

    A preemption drain persists the optimizer state alongside the params
    (``emergency_save(..., opt_state=...)``): a same-mesh resume via
    :func:`repro.runtime.fault_tolerance.resume_session` then continues
    with identical Adam moments, making the post-resume loss stream
    bitwise-identical to an uninterrupted run.
    """
    mesh = sess.mesh
    daxes = tuple(a for a in mesh.axis_names if a != "model")
    bspec = {"tokens": P(daxes), "labels": P(daxes)}
    step_fn = setup_mod.make_sharded_train_step(
        sess, accum_steps=loop.accum_steps, donate=True)(bspec)

    # Record which comm path this run takes (fused psum vs chunk-overlapped
    # TP reduce / MoE a2a) — the session may have resolved comm_cfg="auto".
    cc = sess.rt.comm
    log(f"[comm] mode={cc.mode.value} scheduling={cc.scheduling.value} "
        f"transport={cc.transport.value} algorithm={cc.algorithm}")

    source = SyntheticLM(data_cfg)
    start_step = int(np.asarray(jax.device_get(sess.opt_state["step"])))
    loader = PrefetchLoader(source, start_step=start_step)
    ckpt = AsyncCheckpointer(loop.ckpt_dir) if loop.ckpt_dir else None
    watchdog = StepWatchdog()
    params, opt_state = sess.params, sess.opt_state
    history = []

    def put(batch):
        sharding = {k: NamedSharding(mesh, bspec[k]) for k in bspec}
        return {k: jax.device_put(jnp.asarray(batch[k]), sharding[k])
                for k in bspec}

    own_guard = guard is None
    if own_guard:
        guard = PreemptionGuard()
        guard.__enter__()
    try:
        for i in range(start_step, start_step + loop.n_steps):
            if faults is not None:
                try:
                    faults.poll(i, guard=guard)
                except Exception:
                    # Rank death: checkpoint the last completed step so the
                    # elastic restart loses at most the in-flight step, then
                    # let the error unwind to the recovery driver.
                    if loop.ckpt_dir:
                        from repro.checkpoint.checkpointer import \
                            emergency_save
                        emergency_save(loop.ckpt_dir, i, params,
                                       opt_state=opt_state)
                    sess.params, sess.opt_state = params, opt_state
                    raise
            if guard.preempted:
                log(f"[preempt] draining at step {i}")
                if loop.ckpt_dir:
                    from repro.checkpoint.checkpointer import emergency_save
                    emergency_save(loop.ckpt_dir, i, params,
                                   opt_state=opt_state)
                break
            batch = next(loader)
            watchdog.start_step(i)
            with obs_trace.span("train.step", cat="train", step=i):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     put(batch))
                jax.block_until_ready(metrics["loss"])
            ev = watchdog.end_step()
            if ev is not None:
                log(f"[straggler] step {ev.step}: {ev.duration*1e3:.1f}ms "
                    f"(threshold {ev.threshold*1e3:.1f}ms)")
            history.append(float(metrics["loss"]))
            if i % loop.log_every == 0:
                log(f"step {i}: loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"lr={float(metrics['lr']):.2e}")
            if ckpt and (i + 1) % loop.ckpt_every == 0:
                ckpt.save(i + 1, params)
            if guard.preempted:
                log(f"[preempt] draining at step {i}")
                if loop.ckpt_dir:
                    from repro.checkpoint.checkpointer import emergency_save
                    emergency_save(loop.ckpt_dir, i + 1, params,
                                   opt_state=opt_state)
                break
    finally:
        if own_guard:
            guard.__exit__(None, None, None)
    if ckpt:
        ckpt.wait()
    loader.close()
    sess.params, sess.opt_state = params, opt_state
    return history
