"""Training step — manual SPMD, all communication via ACCL-X.

``make_train_step`` builds a function (params, opt_state, batch) -> (params,
opt_state, metrics) intended to run inside ``shard_map`` over the production
mesh.  Communication structure per step:

  forward/backward   TP combines + f-operator sums   (streaming or buffered)
  grad model-sum     psum over 'model' for replicated-storage/sharded-use
                     leaves (sharding.grad_model_sum_mask)
  grad data-sync     ZeRO-1 flat ring reduce-scatter over 'data'
                     (+ all-reduce over 'pod'), optional int8 wire compression
  param update       Adam on owned slice, ring all-gather of the delta

Microbatching: ``accum_steps`` > 1 splits the local batch and accumulates
grads with a lax.scan (sequential — the standard gradient-accumulation
trade: HBM for step size).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import collectives
from repro.core.config import CommConfig
from repro.models import sharding, transformer
from repro.models.common import ModelConfig, Runtime
from repro.optim import adamw


def grad_model_sync(grads, mask, rt: Runtime):
    """psum over the model axis where the mask says so."""
    if rt.mesh.tp == 1:
        return grads
    comm = rt.tp_comm()
    return jax.tree.map(
        lambda g, m: collectives.all_reduce(g.astype(jnp.float32), comm,
                                            rt.comm).astype(g.dtype)
        if m else g, grads, mask)


def make_loss_and_grad(rt: Runtime, accum_steps: int = 1):
    def single(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            transformer.loss_fn, has_aux=True)(params, batch, rt)
        return loss, parts, grads

    if accum_steps == 1:
        return single

    def accumulated(params, batch):
        def micro(carry, mb):
            loss_acc, grads_acc = carry
            loss, parts, grads = single(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss, grads_acc), parts

        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        (loss_sum, grads), parts = jax.lax.scan(
            micro, (jnp.zeros(()), zero_g), mbs)
        grads = jax.tree.map(lambda g: g / accum_steps, grads)
        parts = jax.tree.map(lambda x: x[-1], parts)
        return loss_sum / accum_steps, parts, grads

    return accumulated


def make_train_step(rt: Runtime, oc: adamw.OptConfig, mask,
                    accum_steps: int = 1, ms_mask=None):
    """mask = sharding.grad_model_sum_mask(...); ms_mask =
    sharding.model_sharded_mask(param_specs) (both static trees)."""
    loss_and_grad = make_loss_and_grad(rt, accum_steps)

    def train_step(params, opt_state, batch):
        loss, parts, grads = loss_and_grad(params, batch)
        grads = grad_model_sync(grads, mask, rt)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, oc, rt, rt.fsdp_plan, ms_mask)
        # Cross-replica reductions for logging (metrics leave shard_map
        # replicated, so they must be identical on every device).
        ce, aux = parts["ce"], parts["aux"]
        if rt.mesh.dp > 1:
            loss = collectives.all_reduce(loss, rt.dp_comm(), rt.comm) / rt.mesh.dp
            ce = collectives.all_reduce(ce, rt.dp_comm(), rt.comm) / rt.mesh.dp
            aux = collectives.all_reduce(aux, rt.dp_comm(), rt.comm) / rt.mesh.dp
        metrics = {"loss": loss, "ce": ce, "aux": aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_eval_step(rt: Runtime):
    def eval_step(params, batch):
        loss, parts = transformer.loss_fn(params, batch, rt)
        out = {"loss": loss, **parts}
        if rt.mesh.dp > 1:
            out = jax.tree.map(
                lambda x: collectives.all_reduce(x, rt.dp_comm(), rt.comm)
                / rt.mesh.dp, out)
        return out
    return eval_step
