"""Launcher glue: build params/runtime/train-step for a (config, mesh) pair.

Two paths:
- ``setup_concrete`` — materializes parameters (smoke tests, examples,
  real training).
- ``setup_abstract``  — ShapeDtypeStructs only (the multi-pod dry-run; no
  device allocation ever happens).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import CommConfig
from repro.models import sharding, transformer
from repro.models.common import MeshContext, ModelConfig, Runtime
from repro.optim import adamw
from repro.train import train_step as ts


@dataclasses.dataclass
class Session:
    cfg: ModelConfig
    mesh: Mesh
    rt: Runtime
    param_spec: Any
    opt_spec: Any
    mask: Any
    oc: adamw.OptConfig
    params: Any = None
    opt_state: Any = None
    ms_mask: Any = None


def build_session(cfg: ModelConfig, mesh: Mesh, comm: CommConfig | str,
                  oc: Optional[adamw.OptConfig] = None, fsdp: bool = False,
                  seed: int = 0, concrete: bool = True,
                  attn_tiling: str = "auto",
                  seq_parallel: bool = False,
                  tune_db_path=None,
                  objective: str = "latency") -> Session:
    """Build a training session.

    ``comm="auto"`` asks the autotuner for the fastest measured config for
    the LM path's dominant collective — the per-layer row-parallel TP
    combine, an (tokens, d_model) f32 partial sum — falling back to
    ``OPTIMIZED_CONFIG`` on a cold TuneDB.  The lookup size is a nominal
    1K-token microbatch; TuneDB answers by log-space-nearest message size,
    so the estimate only needs the right order of magnitude.
    ``objective="e2e"`` ranks by the measured row_parallel consumer-loop
    time instead of the bare combine latency — the per-layer matmul is
    exactly the hideable compute of the paper's §5 argument.
    """
    mesh_ctx = MeshContext.from_mesh(mesh)
    tp = mesh_ctx.model_size
    oc = oc or adamw.OptConfig()
    if not isinstance(comm, CommConfig):
        from repro.core.collectives import resolve_config
        msg_bytes = 4 * cfg.d_model * 1024
        comm = resolve_config(comm, "all_reduce", msg_bytes, mesh=mesh,
                              db_path=tune_db_path, objective=objective,
                              consumer="row_parallel")

    init_fn = functools.partial(transformer.init_model, cfg=cfg, tp=tp)
    key = jax.random.PRNGKey(seed)
    shapes = jax.eval_shape(init_fn, key)
    pspec = sharding.param_specs(shapes, cfg, mesh_ctx, fsdp=fsdp)
    plan = sharding.build_fsdp_plan(shapes, cfg, mesh_ctx) if fsdp else None
    rt = Runtime(cfg=cfg, mesh=mesh_ctx, comm=comm, fsdp_plan=plan,
                 attn_tiling=attn_tiling, seq_parallel=seq_parallel)
    mask = sharding.grad_model_sum_mask(shapes, cfg, tp,
                                        seq_parallel=seq_parallel)
    ospec = adamw.state_specs(pspec, oc, rt, plan)

    sess = Session(cfg=cfg, mesh=mesh, rt=rt, param_spec=pspec,
                   opt_spec=ospec, mask=mask, oc=oc)
    sess.ms_mask = sharding.model_sharded_mask(pspec)
    if concrete:
        out_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
        with jax.default_device(jax.devices()[0]):
            pass
        sess.params = jax.jit(init_fn, out_shardings=out_shardings)(key)
        sess.opt_state = init_opt_state(sess)
    return sess


def init_opt_state(sess: Session):
    """Initialize optimizer state with the right shardings (via shard_map so
    the ZeRO slice sizing sees local shards)."""
    mesh = sess.mesh
    rt = sess.rt

    def _init(params):
        return adamw.init_state(params, sess.oc, rt, rt.fsdp_plan)

    fn = jax.jit(compat.shard_map(
        _init, mesh=mesh, in_specs=(sess.param_spec,),
        out_specs=sess.opt_spec, check_vma=False))
    return fn(sess.params)


def batch_spec(cfg: ModelConfig, mesh: Mesh, batch: dict) -> dict:
    """Shard every batch leaf's dim0 over the data axes (pod included)."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return jax.tree.map(lambda _: P(axes), batch)


def make_sharded_train_step(sess: Session, accum_steps: int = 1,
                            donate: bool = True):
    rt = sess.rt
    fn = ts.make_train_step(rt, sess.oc, sess.mask, accum_steps,
                            ms_mask=sess.ms_mask)
    metric_spec = {k: P() for k in
                   ("loss", "ce", "aux", "lr", "grad_norm")}

    def wrapped(params, opt_state, batch):
        return fn(params, opt_state, batch)

    bspec = jax.tree.map(
        lambda _: P(tuple(a for a in sess.mesh.axis_names if a != "model")),
        {"tokens": 0, "labels": 0})

    def build(batch_tree_spec):
        sm = compat.shard_map(
            wrapped, mesh=sess.mesh,
            in_specs=(sess.param_spec, sess.opt_spec, batch_tree_spec),
            out_specs=(sess.param_spec, sess.opt_spec, metric_spec),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(0, 1) if donate else ())

    return build


def make_sharded_eval_step(sess: Session):
    rt = sess.rt
    fn = ts.make_eval_step(rt)
    metric_spec = {"loss": P(), "ce": P(), "aux": P()}

    def build(batch_tree_spec):
        sm = compat.shard_map(
            fn, mesh=sess.mesh,
            in_specs=(sess.param_spec, batch_tree_spec),
            out_specs=metric_spec,
            check_vma=False)
        return jax.jit(sm)

    return build
