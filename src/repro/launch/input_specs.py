"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch × shape) cell.

Shapes (assigned):
    train_4k     seq=4096   global_batch=256   -> train_step
    prefill_32k  seq=32768  global_batch=32    -> prefill (serve)
    decode_32k   seq=32768  global_batch=128   -> decode_step (serve)
    long_500k    seq=524288 global_batch=1     -> decode_step, KV timeline
                 sharded over (data × model) = the whole mesh

``long_500k`` runs only for sub-quadratic archs (SSM / hybrid / sliding-
window); pure full-attention archs are skipped per the assignment (see
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, mla, ssm
from repro.models.common import ModelConfig, Runtime


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Sub-quadratic attention only (SSM / hybrid / SWA-dominant).
LONG_CONTEXT_ARCHS = {"zamba2-7b", "mamba2-130m", "gemma3-1b", "mixtral-8x22b"}

SKIP_REASONS = {
    ("qwen3-8b", "long_500k"): "pure full attention (quadratic) — skipped per assignment",
    ("command-r-plus-104b", "long_500k"): "pure full attention — skipped per assignment",
    ("deepseek-coder-33b", "long_500k"): "pure full attention — skipped per assignment",
    ("deepseek-v3-671b", "long_500k"): "MLA is full attention — skipped per assignment",
    ("phi-3-vision-4.2b", "long_500k"): "pure full attention — skipped per assignment",
    ("seamless-m4t-large-v2", "long_500k"): "enc-dec full attention — skipped per assignment",
}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, SKIP_REASONS.get((arch, shape), "full attention")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


# ----------------------------------------------------------------------
# Train inputs
# ----------------------------------------------------------------------

def train_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(abstract batch, batch spec tree) for train_step."""
    B, S = shape.global_batch, shape.seq_len
    daxes = _data_axes(mesh)
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    spec = {"tokens": P(daxes), "labels": P(daxes)}
    if cfg.family == "vlm":
        batch["patches"] = _sds((B, cfg.num_patches, cfg.frontend_dim),
                                jnp.bfloat16)
        spec["patches"] = P(daxes)
    if cfg.family == "audio":
        # frame embeddings = encoder input; decoder sees `tokens`
        batch["frames"] = _sds((B, S, cfg.frontend_dim), jnp.bfloat16)
        spec["frames"] = P(daxes)
    return batch, spec


# ----------------------------------------------------------------------
# Serve inputs (prefill / decode)
# ----------------------------------------------------------------------

def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    B, S = shape.global_batch, shape.seq_len
    daxes = _data_axes(mesh)
    batch = {"tokens": _sds((B, S), jnp.int32)}
    spec = {"tokens": P(daxes)}
    if cfg.family == "vlm":
        batch["patches"] = _sds((B, cfg.num_patches, cfg.frontend_dim),
                                jnp.bfloat16)
        spec["patches"] = P(daxes)
    if cfg.family == "audio":
        batch["frames"] = _sds((B, S, cfg.frontend_dim), jnp.bfloat16)
        spec["frames"] = P(daxes)
    return batch, spec


def decode_seq_axes(shape: ShapeSpec, mesh) -> tuple:
    """KV-timeline shard axes: model only, unless batch < dp (long context)."""
    daxes = _data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    if shape.global_batch % dp == 0 and shape.global_batch >= dp:
        return ("model",)
    return daxes + ("model",)


def decode_batch_axes(shape: ShapeSpec, mesh) -> tuple:
    daxes = _data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    if shape.global_batch % dp == 0 and shape.global_batch >= dp:
        return daxes
    return ()     # batch replicated; the timeline shards over data instead


def _kv_cache_abstract(cfg, B, max_len, n_shards, n_layers, bx, sx):
    hd = cfg.resolved_head_dim
    L = max(1, -(-max_len // n_shards))
    lead = (n_layers,) if n_layers else ()
    pl = (None,) * len(lead)
    # global seq dim = L * n_shards (padded to a shard multiple)
    k = _sds(lead + (B, L * n_shards, cfg.n_kv_heads, hd), cfg.dtype)
    v = _sds(lead + (B, L * n_shards, cfg.n_kv_heads, hd), cfg.dtype)
    length = _sds(lead, jnp.int32) if lead else _sds((), jnp.int32)
    spec = attention.KVCache(
        k=P(*(pl + (bx, sx, None, None))),
        v=P(*(pl + (bx, sx, None, None))),
        length=P(*pl) if lead else P())
    return attention.KVCache(k=k, v=v, length=length), spec


def _mla_cache_abstract(cfg, B, max_len, n_shards, n_layers, bx, sx):
    L = max(1, -(-max_len // n_shards))
    lead = (n_layers,) if n_layers else ()
    pl = (None,) * len(lead)
    val = mla.MLACache(
        ckv=_sds(lead + (B, L * n_shards, cfg.kv_lora_rank), cfg.dtype),
        k_rope=_sds(lead + (B, L * n_shards, cfg.qk_rope_dim), cfg.dtype),
        length=_sds(lead, jnp.int32) if lead else _sds((), jnp.int32))
    spec = mla.MLACache(
        ckv=P(*(pl + (bx, sx, None))),
        k_rope=P(*(pl + (bx, sx, None))),
        length=P(*pl) if lead else P())
    return val, spec


def _ssm_state_abstract(cfg, B, tp, n_layers, bx):
    hl, sharded = ssm.ssm_dims(cfg, tp)
    lead = (n_layers,) if n_layers else ()
    pl = (None,) * len(lead)
    hx = "model" if sharded else None
    val = ssm.SSMState(
        conv=_sds(lead + (B, cfg.conv_width - 1, cfg.ssm_heads * cfg.ssm_head_dim
                          ), cfg.dtype),
        h=_sds(lead + (B, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
               jnp.float32))
    spec = ssm.SSMState(
        conv=P(*(pl + (bx, None, hx))),
        h=P(*(pl + (bx, hx, None, None))))
    return val, spec


def decode_caches_abstract(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(abstract ServeState caches, cache spec tree) matching decode.prefill
    output structure for this family."""
    daxes = _data_axes(mesh)
    tp = mesh.shape["model"]
    sx_axes = decode_seq_axes(shape, mesh)
    bx_axes = decode_batch_axes(shape, mesh)
    n_shards = 1
    for a in sx_axes:
        n_shards *= mesh.shape[a]
    bx = bx_axes if bx_axes else None
    sx = sx_axes
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    B = shape.global_batch
    S = shape.seq_len
    if cfg.family == "vlm":
        S = S + cfg.num_patches   # cache covers the patch prefix too

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio
            nb = cfg.n_layers // (r + 1)
            trailing = cfg.n_layers - nb * (r + 1)
            loc_v, loc_s = _kv_cache_abstract(cfg, B, S, n_shards, None, bx, sx)
            loc_v = jax.tree.map(lambda l: _sds((nb, r) + l.shape, l.dtype), loc_v)
            loc_s = jax.tree.map(lambda s: P(*((None, None) + tuple(s))), loc_s,
                                 is_leaf=lambda x: isinstance(x, P))
            g_v, g_s = _kv_cache_abstract(cfg, B, S, n_shards, nb, bx, sx)
            caches = {"blocks": {"local": loc_v, "global": g_v},
                      "trailing": None}
            specs = {"blocks": {"local": loc_s, "global": g_s},
                     "trailing": None}
            if trailing:
                t_v, t_s = _kv_cache_abstract(cfg, B, S, n_shards, trailing,
                                              bx, sx)
                caches["trailing"] = t_v
                specs["trailing"] = t_s
            return caches, specs
        return _kv_cache_abstract(cfg, B, S, n_shards, cfg.n_layers, bx, sx)
    if cfg.family == "moe":
        n_moe = cfg.n_layers - cfg.n_dense_layers
        mk = _mla_cache_abstract if cfg.use_mla else _kv_cache_abstract
        m_v, m_s = mk(cfg, B, S, n_shards, n_moe, bx, sx)
        caches = {"moe": m_v, "dense": None}
        specs = {"moe": m_s, "dense": None}
        if cfg.n_dense_layers:
            d_v, d_s = mk(cfg, B, S, n_shards, cfg.n_dense_layers, bx, sx)
            caches["dense"] = d_v
            specs["dense"] = d_s
        return caches, specs
    if cfg.family == "ssm":
        return _ssm_state_abstract(cfg, B, tp, cfg.n_layers, bx)
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        ng = cfg.n_layers // k
        trailing = cfg.n_layers - ng * k
        s_v, s_s = _ssm_state_abstract(cfg, B, tp, None, bx)
        s_v = jax.tree.map(lambda l: _sds((ng, k) + l.shape, l.dtype), s_v)
        s_s = jax.tree.map(lambda s: P(*((None, None) + tuple(s))), s_s,
                           is_leaf=lambda x: isinstance(x, P))
        a_v, a_s = _kv_cache_abstract(cfg, B, S, n_shards, ng, bx, sx)
        caches = {"groups": {"ssm": s_v, "attn": a_v}, "trailing": None}
        specs = {"groups": {"ssm": s_s, "attn": a_s}, "trailing": None}
        if trailing:
            t_v, t_s = _ssm_state_abstract(cfg, B, tp, trailing, bx)
            caches["trailing"] = t_v
            specs["trailing"] = t_s
        return caches, specs
    if cfg.family == "audio":
        self_v, self_s = _kv_cache_abstract(cfg, B, S, n_shards, cfg.n_layers,
                                            bx, sx)
        # cross cache: encoder length (= S frames here)
        x_v, x_s = _kv_cache_abstract(cfg, B, S, n_shards, cfg.n_layers, bx, sx)
        return ({"self": self_v, "cross": x_v}, {"self": self_s, "cross": x_s})
    raise ValueError(cfg.family)


def vocab_is_sharded(cfg: ModelConfig, tp: int) -> bool:
    """Whether the vocab dim (embedding rows / logits columns) shards over
    the model axis.  The single source of the divisibility rule — serving
    specs and the logits combine must agree on it or the decode
    in_specs/out_specs drift from the program's actual layout."""
    return cfg.vocab_size % tp == 0 and tp > 1


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(abstract (token, ServeState), spec tree) for decode_step."""
    from repro.models import decode as dec
    daxes = _data_axes(mesh)
    bx_axes = decode_batch_axes(shape, mesh)
    bx = bx_axes if bx_axes else None
    B = shape.global_batch
    tp = mesh.shape["model"]
    caches, cache_spec = decode_caches_abstract(cfg, shape, mesh)
    vshard = (cfg.vocab_size // tp if vocab_is_sharded(cfg, tp)
              else cfg.vocab_size)
    state = dec.ServeState(
        caches=caches,
        last_logits=_sds((B, vshard * (tp if vshard < cfg.vocab_size else 1)),
                         jnp.float32),
        length=_sds((), jnp.int32))
    state_spec = dec.ServeState(
        caches=cache_spec,
        last_logits=P(bx, "model") if vshard < cfg.vocab_size else P(bx, None),
        length=P())
    token = _sds((B,), jnp.int32)
    token_spec = P(bx)
    return (token, state), (token_spec, state_spec)
