"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE —
useless for scanned-layer transformers.  This module parses the optimized
HLO text, builds the computation call graph (entry → while bodies × trip
count → fusions), and accumulates:

- **flops**: 2 · prod(result dims) · prod(contracting dims) for every
  ``dot`` (dots are ≳95 % of model FLOPs; elementwise ignored), scaled by the
  enclosing computation's execution multiplier;
- **hbm bytes**: operand + result bytes of every *top-level* op in non-fusion
  computations (fusion internals stay on-chip; the fusion call site's own
  operands/results are the HBM traffic), scaled likewise;
- **collective bytes**: per collective type, scaled likewise.

Trip counts come from the loop condition's ``compare(iv, constant(N))``
pattern that lax.scan emits.  CPU-backend fusion boundaries differ from TPU
ones — recorded as an approximation in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy-start", "copy-done", "after-all", "partition-id")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def split_computations(text: str):
    """name -> list of op lines; also returns entry name."""
    comps = {}
    entry = None
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", s)
        if m and not s.startswith("ROOT"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and "=" in s:
            comps[cur].append(s)
    return comps, entry


_LHS_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")


def build_symtab(comps) -> dict:
    """%var -> (dtype, dims) from definition lines (non-tuple results only)."""
    sym = {}
    for lines in comps.values():
        for line in lines:
            m = _LHS_RE.match(line)
            if m:
                sym[m.group(1)] = (m.group(2), m.group(3))
    return sym


def _operand_names(line: str):
    rhs = line.split("=", 1)[1]
    if "(" not in rhs:
        return []
    call = rhs[rhs.index("("):]
    # cut at the closing paren of the call (operands only, not attributes)
    depth = 0
    end = len(call)
    for i, ch in enumerate(call):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", call[:end])


def _called(line: str):
    """(kind, [computation names]) referenced by this op line."""
    out = []
    m = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", line)
    if m:
        return "while", [m.group(1), m.group(2)]
    m = re.search(r"calls=%?([\w.\-]+)", line)
    if m:
        return "fusion", [m.group(1)]
    m = re.search(r"to_apply=%?([\w.\-]+)", line)
    if m:
        return "call", [m.group(1)]
    m = re.search(r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+)",
                  line)
    if m:
        return "cond", [m.group(1), m.group(2)]
    return None, []


def _trip_count(cond_lines) -> int:
    """lax.scan cond: compare(iv, constant(N)) LT — take that N."""
    consts = []
    for line in cond_lines:
        if "compare(" in line and "direction=LT" in line:
            for c in re.findall(r"constant\((\d+)\)", line):
                consts.append(int(c))
    if consts:
        return max(consts)
    # fall back: any s32 constant in cond
    for line in cond_lines:
        for c in re.findall(r"constant\((\d+)\)", line):
            consts.append(int(c))
    return max(consts) if consts else 1


def _line_types(line: str, sym: dict):
    """(result_type, operand_types) resolved through the symbol table."""
    m = _LHS_RE.match(line)
    result = (m.group(2), m.group(3)) if m else None
    otypes = []
    for name in _operand_names(line):
        if name in sym:
            otypes.append(sym[name])
    return result, otypes


def _dot_flops(line: str, sym: dict) -> float:
    result, otypes = _line_types(line, sym)
    if result is None:
        return 0.0
    res_elems = _shape_elems(result[1])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not m or not otypes:
        return 2.0 * res_elems  # unknown; minimal
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs_dims = [int(d) for d in otypes[0][1].split(",") if d]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * res_elems * k


def analyze_hlo(text: str) -> dict:
    comps, entry = split_computations(text)
    if entry is None:
        entry = next(iter(comps), None)
    sym = build_symtab(comps)
    # which computations are fusion bodies (called via calls=)
    fusion_comps = set()
    for lines in comps.values():
        for line in lines:
            kind, names = _called(line)
            if kind == "fusion":
                fusion_comps.update(names)

    # Build call edges (caller, callee, per-call multiplier), then propagate
    # in topological order — shared (deduped) fusion computations may be
    # reached from several bodies with different multipliers.
    edges = []
    for c, lines in comps.items():
        for line in lines:
            kind, names = _called(line)
            if not names:
                continue
            if kind == "while":
                trips = _trip_count(comps.get(names[0], []))
                for n in names:
                    edges.append((c, n, float(trips)))
            else:
                for n in names:
                    edges.append((c, n, 1.0))
    indeg = defaultdict(int)
    out_edges = defaultdict(list)
    for a, b, t in edges:
        indeg[b] += 1
        out_edges[a].append((b, t))
    mult = defaultdict(float)
    mult[entry] = 1.0
    queue = [c for c in comps if indeg[c] == 0]
    topo_seen = 0
    while queue:
        c = queue.pop()
        topo_seen += 1
        for b, t in out_edges[c]:
            mult[b] += mult[c] * t
            indeg[b] -= 1
            if indeg[b] == 0:
                queue.append(b)

    flops = 0.0
    hbm = 0.0
    dot_bytes = 0.0     # operands+results of dots only (TPU-fusion-friendly
                        # lower-bound HBM traffic; raw `hbm` is the upper
                        # bound — CPU fusion boundaries overcount)
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_comps
        for line in lines:
            opm = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[^=]*?\s([a-z][\w\-]*)\(",
                           line)
            opname = opm.group(1) if opm else ""
            if opname in ("dot", "convolution"):
                flops += m * _dot_flops(line, sym)
                r, o = _line_types(line, sym)
                db = sum(_shape_bytes(dt, dims) for dt, dims in o)
                if r:
                    db += _shape_bytes(r[0], r[1])
                dot_bytes += m * db
            if in_fusion:
                continue
            if not opname or opname in _SKIP_OPS or opname in (
                    "while", "conditional", "call"):
                continue
            result, otypes = _line_types(line, sym)
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in otypes)
            if result:
                nbytes += _shape_bytes(result[0], result[1])
            hbm += m * nbytes
            for ck in _COLLECTIVES:
                if opname == ck or opname == ck + "-start":
                    ob = sum(_shape_bytes(dt, dims) for dt, dims in otypes)
                    if ob == 0 and result:
                        ob = _shape_bytes(result[0], result[1])
                    coll_bytes[ck] += m * ob
                    coll_counts[ck] += m
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "dot_bytes": dot_bytes,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total": sum(coll_bytes.values()),
    }


# ----------------------------------------------------------------------
# Compute/communication overlap analysis
# ----------------------------------------------------------------------

_COMPUTE_OPS = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "sqrt",
    "rsqrt", "abs", "negate", "exponential", "tanh", "power", "select",
    "dot", "convolution", "reduce", "fusion", "scatter", "gather", "sine"))

_NAME_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_OPNAME_RE = re.compile(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[^=]*?\s([a-z][\w\-]*)\(")


def permute_overlap_stats(text: str,
                          ops: tuple = ("collective-permute",)) -> dict:
    """How much compute can run concurrently with the tracked collectives.

    ``ops`` names the collective op families to track (HLO opcode prefixes:
    ``collective-permute`` by default; pass e.g. ``("all-reduce",)`` or
    ``("all-to-all",)`` for the LM paths' combines).  Three complementary
    signals, so the check works on any backend:

    - **async pairs** (TPU/GPU backends split collectives into
      ``<op>-start``/``<op>-done``): for every pair, the number of compute
      ops scheduled between start and done — nonzero gaps mean the
      latency-hiding scheduler actually placed work inside the transfer.
    - **dependency classes** (all backends, incl. CPU's synchronous
      collectives): every op in a collective-bearing computation is
      *upstream* (feeds a collective), *downstream* (consumes one), or
      *overlappable* (neither — free to execute while the wire is busy).
      The overlapped halo schedule exists precisely to maximize that third
      class; the fused step funnels nearly all element work downstream.
    - **independent pairs**: the number of unordered pairs of tracked
      collectives with no dependency path between them — the signal for
      chunk-level decoupling (the fused TP reduce is ONE all-reduce, hence
      zero pairs; the chunk-overlapped one is N mutually independent
      reduces, hence N·(N−1)/2 pairs the scheduler may run concurrently).
    """
    comps, _ = split_computations(text)
    stats = {"sync_permutes": 0, "async_pairs": 0, "pair_gaps": [],
             "overlappable_compute": 0, "upstream_compute": 0,
             "downstream_compute": 0, "n_collectives": 0,
             "independent_pairs": 0}
    for lines in comps.values():
        op_rows = []   # (name, opname, operands)
        for line in lines:
            nm = _NAME_RE.match(line)
            opm = _OPNAME_RE.match(line)
            if not nm or not opm:
                continue
            op_rows.append((nm.group(1), opm.group(1), _operand_names(line)))
        permutes = [i for i, (_, op, _o) in enumerate(op_rows)
                    if any(op == p or op == p + "-start" or op == p + "-done"
                           for p in ops)]
        if not permutes:
            continue
        stats["sync_permutes"] += sum(
            1 for i in permutes if op_rows[i][1] in ops)
        # async start/done pairs and the compute scheduled between them
        starts = {op_rows[i][0]: i for i in permutes
                  if op_rows[i][1].endswith("-start")}
        for i in permutes:
            if not op_rows[i][1].endswith("-done"):
                continue
            for operand in op_rows[i][2]:
                if operand in starts:
                    j = starts[operand]
                    gap = sum(1 for k in range(j + 1, i)
                              if op_rows[k][1] in _COMPUTE_OPS)
                    stats["async_pairs"] += 1
                    stats["pair_gaps"].append(gap)
                    break
        # dependency classes (SSA def order makes single passes sufficient)
        defs = {name: k for k, (name, _, _) in enumerate(op_rows)}
        downstream = {op_rows[i][0] for i in permutes}
        for name, _op, operands in op_rows:
            if any(o in downstream for o in operands):
                downstream.add(name)
        upstream = set()
        frontier = [o for i in permutes for o in op_rows[i][2]]
        while frontier:
            n = frontier.pop()
            if n in upstream or n not in defs:
                continue
            upstream.add(n)
            frontier.extend(op_rows[defs[n]][2])
        for name, op, _operands in op_rows:
            if op not in _COMPUTE_OPS:
                continue
            if name in downstream:
                stats["downstream_compute"] += 1
            elif name in upstream:
                stats["upstream_compute"] += 1
            else:
                stats["overlappable_compute"] += 1
        # independent collective pairs: one logical collective per sync op
        # or -start op (the matching -done is the same logical transfer).
        coll_idx = [i for i in permutes
                    if not op_rows[i][1].endswith("-done")]
        ids = {i: b for b, i in enumerate(coll_idx)}
        masks: dict[str, int] = {}   # name -> bitmask of ancestor collectives
        anc = {}                     # collective bit -> ancestor mask
        for k, (name, _op, operands) in enumerate(op_rows):
            m = 0
            for o in operands:
                m |= masks.get(o, 0)
            if k in ids:
                anc[ids[k]] = m
                m |= 1 << ids[k]
            masks[name] = m
        n_coll = len(coll_idx)
        stats["n_collectives"] += n_coll
        for a in range(n_coll):
            for b in range(a + 1, n_coll):
                if not (anc[b] >> a) & 1 and not (anc[a] >> b) & 1:
                    stats["independent_pairs"] += 1
    return stats
