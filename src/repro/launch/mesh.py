"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small host-device mesh for CPU multi-device tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
