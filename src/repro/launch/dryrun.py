import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs abstract (ShapeDtypeStruct) params/optimizer/batch or caches,
  3. ``jit(step).lower(...)`` then ``.compile()`` — proving the sharding
     configuration is coherent end to end (no allocation ever happens),
  4. records ``memory_analysis()``, ``cost_analysis()`` and the per-type
     collective byte counts parsed from the optimized HLO,
into ``artifacts/dryrun/{arch}__{shape}__{mesh}.json`` for EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--shapes train_4k,...]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs.registry import get_config, list_archs
from repro.core.config import CommConfig, CommMode, Scheduling, Transport, Compression
from repro.launch import input_specs as isp
from repro.launch.mesh import make_production_mesh

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (optimized) HLO.

    Operand types appear inline in HLO text: ``all-reduce(f32[4096]{0} %x)``.
    Falls back to the result type when operands carry no inline types.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*([a-z0-9_\[\],{}()\s]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        op = m.group(2)
        if m.group(3) == "-start" or "-done(" in stripped:
            pass
        if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)-done\(", stripped):
            continue
        # operand types inside the call parens
        call = stripped[stripped.index(m.group(2)):]
        operand_types = re.findall(r"([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s*%",
                                   call)
        nbytes = sum(_shape_bytes(t) for t in operand_types)
        if nbytes == 0:
            result_types = re.findall(r"([a-z0-9]+\[[0-9,]*\])", m.group(1))
            nbytes = sum(_shape_bytes(t) for t in result_types)
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def build_comm(args) -> CommConfig:
    return CommConfig(
        mode=CommMode(args.mode),
        scheduling=Scheduling.FUSED,
        transport=Transport(args.transport),
        compression=Compression(args.compression),
        algorithm=args.algorithm,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, comm: CommConfig,
             fsdp: str = "auto", attn_tiling: str = "auto",
             moment_dtype: str = "float32", seq_parallel: bool = False,
             shard_attn: str = "", grad_comm: "CommConfig|None" = None,
             padded_heads: int = 0, remat_policy: str = "") -> dict:
    import jax.numpy as jnp
    from repro.launch import setup
    from repro.models import decode as dec
    from repro.optim import adamw

    t0 = time.time()
    cfg = get_config(arch)
    if shard_attn:
        cfg = dataclasses.replace(cfg, shard_attn=shard_attn)
    if padded_heads:
        cfg = dataclasses.replace(cfg, padded_heads=padded_heads)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    shape = isp.SHAPES[shape_name]
    ok, reason = isp.applicable(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "comm": dataclasses.asdict(comm),
           "status": "skip", "reason": reason}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for s in mesh.shape.values():
        n_chips *= s

    # FSDP for ≥30B-param training cells (weights would not fit TP-only).
    use_fsdp = (shape.kind == "train" and
                (fsdp == "on" or (fsdp == "auto"
                                  and cfg.param_count() > 2e10)))
    oc = adamw.OptConfig(zero1=True,
                         moment_dtype=getattr(jnp, moment_dtype),
                         grad_comm=grad_comm)

    if shape.kind == "train":
        sess = setup.build_session(cfg, mesh, comm, oc=oc, fsdp=use_fsdp,
                                   concrete=False, attn_tiling=attn_tiling,
                                   seq_parallel=seq_parallel)
        batch, bspec = isp.train_inputs(cfg, shape, mesh)
        abstract_params = jax.eval_shape(
            lambda k: __import__("repro.models.transformer",
                                 fromlist=["init_model"]).init_model(
                k, cfg, mesh.shape["model"]), jax.random.PRNGKey(0))
        opt_abs = jax.eval_shape(
            lambda p: adamw.init_state(p, oc, sess.rt, sess.rt.fsdp_plan),
            jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                _local_shape(s.shape, sess.param_spec, mesh, path=None),
                s.dtype), abstract_params))
        # Build opt abstract with GLOBAL shapes instead:
        opt_abs = _globalize_opt(opt_abs, sess, mesh)
        step_builder = setup.make_sharded_train_step(sess, donate=False)
        fn = step_builder(bspec)
        lowered = fn.lower(abstract_params, opt_abs, batch)
    else:
        from repro.train import serve as serve_mod
        sess_rt, fn, args_abs = serve_mod.build_serve_fn(
            cfg, mesh, comm, shape, attn_tiling=attn_tiling)
        lowered = fn.lower(*args_abs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.launch.hlo_analysis import analyze_hlo
    scaled = analyze_hlo(hlo)

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "fsdp": use_fsdp,
        "opts": {"seq_parallel": seq_parallel, "attn_tiling": attn_tiling,
                 "shard_attn": shard_attn, "padded_heads": padded_heads,
                 "moment_dtype": moment_dtype,
                 "grad_compression": (grad_comm.compression.value
                                      if grad_comm else "none")},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "cost": {
            # raw XLA numbers (loop bodies counted ONCE — see hlo_analysis)
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        # trip-count-aware totals (the roofline source of truth)
        "scaled": {
            "flops": scaled["flops"],
            "hbm_bytes": scaled["hbm_bytes"],
            "dot_bytes": scaled["dot_bytes"],
            "collective_bytes": scaled["collective_bytes"],
            "collective_counts": scaled["collective_counts"],
            "collective_total": scaled["collective_total"],
        },
        "collectives": coll,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "tokens": shape.global_batch * (1 if shape.kind == "decode"
                                        else shape.seq_len),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    })
    return rec


def _local_shape(shape, spec_tree, mesh, path):
    return shape  # placeholder (abstract opt init uses global shapes)


def _globalize_opt(opt_abs, sess, mesh):
    """Adjust ZeRO slice leaves to their global (tp, dp, k) shapes."""
    import jax.numpy as jnp
    if "m_slice" not in opt_abs:
        return opt_abs
    tp = mesh.shape["model"]
    data_axis = [a for a in mesh.axis_names if a != "model"][-1]
    dp = mesh.shape[data_axis]
    k = opt_abs["m_slice"].shape[-1]
    # init_state sized k from GLOBAL param shapes (eval_shape saw global
    # arrays); the true local flat size uses local shards. Recompute exactly:
    from repro.optim import adamw as _a
    reg, fs = _a.partition_params(
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     _local_params_abstract(sess, mesh)), sess.rt.fsdp_plan)
    n = sum(int(l.size if hasattr(l, "size") else 0)
            for l in jax.tree.leaves(reg))
    pad = (-n) % dp
    k_local = (n + pad) // dp
    def fix(leaf, path_is_slice):
        return jax.ShapeDtypeStruct((tp, dp, k_local), leaf.dtype)
    out = dict(opt_abs)
    out["m_slice"] = jax.ShapeDtypeStruct((tp, dp, k_local),
                                          opt_abs["m_slice"].dtype)
    out["v_slice"] = jax.ShapeDtypeStruct((tp, dp, k_local),
                                          opt_abs["v_slice"].dtype)
    return out


def _local_params_abstract(sess, mesh):
    """Per-device param shapes under the session's param spec."""
    import numpy as np
    from repro.models import transformer
    global_abs = jax.eval_shape(
        lambda k: transformer.init_model(k, sess.cfg, mesh.shape["model"]),
        jax.random.PRNGKey(0))

    def localize(s, spec):
        shape = list(s.shape)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shape[i] //= mesh.shape[a]
        return jax.ShapeDtypeStruct(tuple(shape), s.dtype)

    return jax.tree.map(localize, global_abs, sess.param_spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--shapes", default=None, help="comma list")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="streaming")
    ap.add_argument("--transport", default="unordered")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--algorithm", default="native")
    ap.add_argument("--attn-tiling", default="auto")
    ap.add_argument("--fsdp", default="auto")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--shard-attn", default="")
    ap.add_argument("--grad-compression", default="",
                    help="int8|bf16: ring-compressed ZeRO grad RS/AG")
    ap.add_argument("--padded-heads", type=int, default=0)
    ap.add_argument("--remat-policy", default="")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = (args.shapes.split(",") if args.shapes
              else ([args.shape] if args.shape else list(isp.SHAPES)))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    comm = build_comm(args)

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"__{args.tag}" if args.tag else ""
                out = ARTIFACTS / f"{arch}__{shape}__{mesh_name}{tag}.json"
                gcomm = None
                if args.grad_compression:
                    gcomm = CommConfig(algorithm="ring",
                                       compression=Compression(
                                           args.grad_compression))
                try:
                    rec = run_cell(arch, shape, mp, comm, fsdp=args.fsdp,
                                   attn_tiling=args.attn_tiling,
                                   moment_dtype=args.moment_dtype,
                                   seq_parallel=args.seq_parallel,
                                   shard_attn=args.shard_attn,
                                   grad_comm=gcomm,
                                   padded_heads=args.padded_heads,
                                   remat_policy=args.remat_policy)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e)[-2000:],
                           "traceback": traceback.format_exc()[-4000:]}
                out.write_text(json.dumps(rec, indent=1, default=str))
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    mem_gb = (rec["memory"]["argument_bytes"]
                              + rec["memory"]["temp_bytes"]) / 2**30
                    extra = (f" flops={rec['scaled']['flops']:.3e}"
                             f" mem/dev={mem_gb:.2f}GiB"
                             f" coll={rec['scaled']['collective_total']:.3e}B"
                             f" compile={rec['compile_s']}s")
                print(f"[dryrun] {arch} {shape} {mesh_name}: {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
