"""End-to-end LM training driver: ~100M-param model, a few hundred steps.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/train_lm.py --steps 200

Uses the full production stack — shard_map train step over a (data, model)
mesh, ACCL-X collectives (streaming TP + ZeRO-1 ring reduce-scatter), the
synthetic data pipeline, async checkpointing, the straggler watchdog and
preemption drain — on a mamba2-130m-family model scaled to fit the CPU run.
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.core.config import CommConfig, OVERLAPPED_CONFIG
from repro.data.pipeline import DataConfig
from repro.launch import setup
from repro.optim import adamw
from repro.train import loop as loop_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full-size", action="store_true",
                    help="use the real config (defaults to a ~100M-scale "
                    "reduction that trains quickly on CPU)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--comm", default="fused",
                    choices=("fused", "overlapped", "auto"),
                    help="TP/MoE comm path: fused (one psum per combine), "
                    "overlapped (chunked double-buffered TP reduce + chunked "
                    "MoE all-to-all), or auto (fastest measured TuneDB config)")
    args = ap.parse_args()

    if args.full_size:
        cfg = get_config(args.arch)
    else:
        cfg = get_config(args.arch)
        # ~100M-param variant of the same family, CPU-trainable
        cfg = dataclasses.replace(
            cfg, n_layers=min(cfg.n_layers, 6),
            d_model=min(cfg.d_model, 512),
            d_ff=min(cfg.d_ff, 1024) if cfg.d_ff else 0,
            vocab_size=min(cfg.vocab_size, 8192),
            ssm_chunk=min(cfg.ssm_chunk, 32) if cfg.ssm_chunk else 0,
            dtype=jnp.float32, remat=False)

    n = jax.device_count()
    model_axis = 2 if n >= 4 else 1
    mesh = jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.0f}M "
          f"mesh=({n//model_axis}x{model_axis})")

    oc = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                         zero1=True)
    comm = {"fused": CommConfig(), "overlapped": OVERLAPPED_CONFIG,
            "auto": "auto"}[args.comm]
    sess = setup.build_session(cfg, mesh, comm, oc=oc)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    history = loop_mod.train(
        sess, data_cfg,
        loop_mod.LoopConfig(n_steps=args.steps, ckpt_every=max(args.steps // 2, 1),
                            ckpt_dir=ckpt_dir, log_every=10))
    print(f"\nloss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"({len(history)} steps); checkpoints in {ckpt_dir}")
    assert history[-1] < history[0], "loss should decrease"


if __name__ == "__main__":
    main()
