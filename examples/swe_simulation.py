"""End-to-end shallow-water simulation (the paper's application, §4).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/swe_simulation.py [--elements 2000]

Simulates tidal flow in a synthetic bight over 8 partitions with ACCL-X
streaming halo exchange, reports mass conservation and step rate, and prints
the Eq. 2/3 scalability model for the paper's configurations.
"""
import argparse
import time

import jax
import numpy as np

from repro.core import latmodel
from repro.core.config import (BASELINE_CONFIG, OVERLAPPED_CONFIG, CommConfig,
                               V5E)
from repro.obs import trace as obs_trace
from repro.runtime.fault_tolerance import StepWatchdog
from repro.swe import driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, default=2000)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--comm", default="streaming",
                    choices=("streaming", "overlapped", "baseline", "auto"),
                    help="halo-exchange config: the paper's streaming/baseline"
                         " constants, 'overlapped' = double-buffered exchange"
                         " with the interior/boundary split, or 'auto' = pick"
                         " from the TuneDB sweep (python -m repro.tune.sweep)")
    ap.add_argument("--objective", default="latency",
                    choices=("latency", "e2e"),
                    help="with --comm auto: rank TuneDB entries by bare "
                         "exchange latency or by the measured halo-fold "
                         "consumer loop (sweep with --objective e2e first)")
    ap.add_argument("--topology", default=None,
                    help="place the partitions on a virtual torus, e.g. "
                         "'2x4' or '2x4:snake' (rows x cols = partition "
                         "count); multi-hop halo edges route through "
                         "intermediate partitions and --comm auto selects "
                         "a config per exchange round at its hop distance")
    ap.add_argument("--plan-dir", default=None,
                    help="persist CommPlans and compiled programs to this "
                         "directory (or set REPRO_PLAN_DIR): a rerun of the "
                         "same simulation starts warm — schedules replay "
                         "from disk and XLA compiles come from the wired "
                         "compilation cache")
    args = ap.parse_args()

    from repro.core import planstore
    if args.plan_dir is not None:
        planstore.configure(args.plan_dir)
    store = planstore.active()
    if store is not None:
        print(f"plan store: {store.root} "
              f"({store.entry_count()} entries on disk)")

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("data",))
    cfg = {"streaming": CommConfig(), "overlapped": OVERLAPPED_CONFIG,
           "baseline": BASELINE_CONFIG, "auto": "auto"}[args.comm]
    topology = None
    if args.topology:
        from repro.core.topology import TorusSpec
        topology = TorusSpec.parse(args.topology)
    sim = driver.build_simulation(args.elements, mesh, cfg,
                                  objective=args.objective,
                                  topology=topology)
    print(f"comm config ({args.comm}): {sim.comm_cfg}")
    if sim.round_cfgs is not None:
        print("per-edge round configs: "
              + ", ".join(f"r{i}:{c.chunk_bytes >> 10}KiB/{c.transport.value}"
                          for i, c in enumerate(sim.round_cfgs)))
    print(f"mesh: {sim.mesh.n_elements} elements over {n} partitions "
          f"(N_max={sim.pm.n_max}, rounds={sim.pm.n_rounds}"
          + (f", torus={topology.name}" if topology else "") + ")")

    run = driver.make_sim_runner(sim, n_inner=20)
    state = sim.state
    m0 = float(np.sum(np.asarray(state)[..., 0] * sim.pm.area * sim.pm.valid))
    state = jax.block_until_ready(run(state, 0.0))   # compile
    # Segment-level watchdog: each 20-step dispatch is one "step" — a slow
    # segment (straggling host, recompile) shows up as a watchdog.straggler
    # instant in the trace and on the watchdog.stragglers counter.
    watchdog = StepWatchdog(warmup=2, window=16)
    t0 = time.perf_counter()
    t = 20 * 1e-4
    for i in range(args.steps // 20 - 1):
        watchdog.start_step(i)
        state = run(state, t)
        jax.block_until_ready(state)
        watchdog.end_step()
        t += 20 * 1e-4
    jax.block_until_ready(state)
    dt = (time.perf_counter() - t0) / max(args.steps - 20, 1)
    m1 = float(np.sum(np.asarray(state)[..., 0] * sim.pm.area * sim.pm.valid))
    print(f"ran {args.steps} steps, {dt*1e6:.0f} us/step on CPU devices")
    print(f"mass conservation: {m0:.6f} -> {m1:.6f} "
          f"(drift {(m1-m0)/m0:.2e})")
    print(f"watchdog: median segment {watchdog.median_step*1e3:.1f}ms, "
          f"{len(watchdog.events)} straggler(s)")
    if store is not None:
        from repro.core import plans
        st = plans.cache_stats()
        print(f"plan store: {st['disk_hits']} disk hits / "
              f"{st['disk_misses']} misses / {st['disk_writes']} writes "
              f"-> {store.root}")
    if obs_trace.enabled():
        print(f"tracing: {len(obs_trace.events())} events buffered "
              f"(REPRO_TRACE={obs_trace.mode()!r})")

    # Eq. 2/3 model (with the overlap term) at the paper's scales
    w = driver.build_workload(sim)
    print("\nEq.2/3 model + overlap term (this partitioning, v5e constants):")
    for name, cfg in (("MPI+PCIe baseline", BASELINE_CONFIG),
                      ("ACCL-X streaming", CommConfig()),
                      ("ACCL-X overlapped", OVERLAPPED_CONFIG)):
        thr = latmodel.eq2_throughput_overlap(w, cfg, V5E) * n
        stall = latmodel.stall_fraction_overlap(w, cfg, V5E)
        print(f"  {name:20s}: {thr/1e9:8.2f} GFLOP/s "
              f"(pipeline stall {stall*100:.0f}%, "
              f"overlap {latmodel.overlap_fraction(cfg)*100:.0f}%)")


if __name__ == "__main__":
    main()
