"""Continuous-batching serving demo: per-phase tuned comm, waves of
requests arriving mid-flight, greedy decode on the sequence-sharded KV cache.

Requests arrive on a seeded schedule while earlier waves are still
decoding.  Waiting requests are admitted in fixed-shape waves (so no serving
step ever recompiles); each wave is prefilled at the *prompt length* —
the KV caches it builds cover prompt + generation via ``cache_capacity`` —
and active waves then decode round-robin, one token per step, retiring as
their (per-request, variable) generation targets complete.

``--comm auto`` resolves a different CommConfig per phase from the TuneDB:
prefill and decode are distinct tuned consumers (latency-bound per-token
combines vs throughput-bound bulk reduces) and select different winners
from the same measurements.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b --comm auto
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core import plans
from repro.core.config import CommConfig
from repro.launch import input_specs as isp, setup
from repro.train import serve as serve_mod


def _cfg_str(c: CommConfig) -> str:
    return (f"{c.mode.value}/{c.scheduling.value}/{c.transport.value}"
            f"/chunk{c.chunk_bytes}/{c.algorithm}")


@dataclasses.dataclass
class Request:
    rid: int
    arrival: int              # decode-step tick the request arrives at
    prompt: np.ndarray        # (prompt_len,) int32
    gen_target: int           # tokens to generate (variable per request)


@dataclasses.dataclass
class Wave:
    wid: int
    requests: list            # Request per slot (tail slots may repeat)
    valid: list               # bool per slot (False = tail padding)
    state: object = None
    steps: int = 0
    tokens: list = dataclasses.field(default_factory=list)  # (B,) per step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4,
                    help="wave size (fixed serving shape)")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens per request (each request draws a "
                    "target in [gen/2, gen])")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-every", type=int, default=3,
                    help="a new request arrives every N decode steps")
    ap.add_argument("--max-active", type=int, default=2,
                    help="concurrent waves in flight")
    ap.add_argument("--comm", default="static",
                    help="'static' (paper default CommConfig) or 'auto' "
                    "(per-phase TuneDB selection)")
    ap.add_argument("--tune-db", default=None,
                    help="TuneDB path for --comm auto")
    ap.add_argument("--objective", default="e2e",
                    choices=("latency", "e2e"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--expect-phase-distinct", action="store_true",
                    help="exit non-zero unless prefill and decode resolved "
                    "DIFFERENT CommConfigs (CI guard for per-phase auto)")
    ap.add_argument("--expect-plan-hits", action="store_true",
                    help="exit non-zero unless the CommPlan cache recorded "
                    "hits > 0 while serving (plan-cached comm path guard)")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config(args.arch), dtype=jnp.float32)
    n = jax.device_count()
    model_axis = 4 if n >= 4 else 1
    mesh = jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
    comm = "auto" if args.comm == "auto" else CommConfig()
    sess = setup.build_session(cfg, mesh, CommConfig(), concrete=True)

    max_len = args.prompt_len + args.gen
    # Prefill spec at PROMPT length; cache capacity covers generation too.
    shape_p = isp.ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    shape_d = isp.ShapeSpec("serve", max_len, args.batch, "decode")
    rt_p, prefill_fn, pre_abs = serve_mod.build_serve_fn(
        cfg, mesh, comm, shape_p, tune_db_path=args.tune_db,
        objective=args.objective,
        cache_capacity=serve_mod.cache_len(cfg, shape_d))
    rt_d, decode_fn, _ = serve_mod.build_serve_fn(
        cfg, mesh, comm, shape_d, tune_db_path=args.tune_db,
        objective=args.objective)
    print(f"[prefill] comm: {_cfg_str(rt_p.comm)}")
    print(f"[decode]  comm: {_cfg_str(rt_d.comm)}")
    distinct = rt_p.comm != rt_d.comm
    if distinct:
        print("phase-distinct configs selected")

    # The traced prefill program is built for exactly the prompt shape —
    # assert the fed batch matches the spec (the silent-mismatch bug this
    # demo used to carry: a max_len spec fed prompt_len tokens).
    abs_tokens = pre_abs[1]["tokens"]
    assert abs_tokens.shape == (args.batch, args.prompt_len), (
        abs_tokens.shape, (args.batch, args.prompt_len))

    rng = np.random.RandomState(args.seed)
    reqs = [Request(rid=r, arrival=r * args.arrival_every,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       args.prompt_len).astype(np.int32),
                    gen_target=int(rng.randint(max(1, args.gen // 2),
                                               args.gen + 1)))
            for r in range(args.requests)]
    pending = list(reqs)          # not yet arrived
    waiting: list = []            # arrived, not yet admitted to a wave
    active: list = []             # waves in flight
    finished: dict = {}           # rid -> list of generated token ids
    ttft: dict = {}               # rid -> seconds from arrival to 1st logits
    arrival_wall: dict = {}

    def pick(logits):
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)

    tick = 0                      # global decode-step clock
    wid = 0
    rr = 0                        # round-robin cursor over active waves
    decode_steps = 0
    decode_wall = 0.0
    t_run = time.perf_counter()
    while pending or waiting or active:
        while pending and pending[0].arrival <= tick:
            r = pending.pop(0)
            arrival_wall[r.rid] = time.perf_counter()
            waiting.append(r)
        can_admit = len(active) < args.max_active and waiting and (
            len(waiting) >= args.batch or not pending)
        if can_admit:
            members = waiting[:args.batch]
            del waiting[:len(members)]
            valid = [True] * len(members)
            while len(members) < args.batch:     # tail wave: pad + mask
                members.append(members[-1])
                valid.append(False)
            wave = Wave(wid=wid, requests=members, valid=valid)
            wid += 1
            toks = jnp.asarray(np.stack([r.prompt for r in members]))
            t0 = time.perf_counter()
            wave.state = jax.block_until_ready(
                prefill_fn(sess.params, {"tokens": toks}))
            dt = time.perf_counter() - t0
            for r, v in zip(members, valid):
                if v:
                    ttft[r.rid] = time.perf_counter() - arrival_wall[r.rid]
            print(f"[prefill] wave {wave.wid}: "
                  f"{sum(valid)} reqs x {args.prompt_len} tok, "
                  f"{dt * 1e3:.1f} ms ({len(active) + 1} wave(s) in flight)")
            active.append(wave)
            continue
        if not active:
            tick += 1             # idle: nothing admitted, wait for arrivals
            continue
        wave = active[rr % len(active)]
        tok = pick(wave.state.last_logits)
        t0 = time.perf_counter()
        wave.state = decode_fn(sess.params, jnp.asarray(tok), wave.state)
        jax.block_until_ready(wave.state.last_logits)
        decode_wall += time.perf_counter() - t0
        wave.tokens.append(tok)
        wave.steps += 1
        decode_steps += 1
        tick += 1
        need = max(r.gen_target for r, v in zip(wave.requests, wave.valid)
                   if v)
        if wave.steps >= need:
            gen = np.stack(wave.tokens, 1)       # (B, steps)
            done = 0
            for i, (r, v) in enumerate(zip(wave.requests, wave.valid)):
                if v and r.rid not in finished:
                    finished[r.rid] = gen[i, :r.gen_target].tolist()
                    done += 1
            active.remove(wave)
            print(f"[decode]  wave {wave.wid}: retired after {wave.steps} "
                  f"steps ({done} reqs complete, "
                  f"{len(active)} wave(s) remain)")
        rr += 1

    wall = time.perf_counter() - t_run
    gen_tokens = sum(len(v) for v in finished.values())
    ms_tok = decode_wall / max(1, decode_steps) * 1e3
    print(f"served {len(finished)}/{args.requests} requests, "
          f"{gen_tokens} tokens in {wall:.2f} s")
    print(f"[decode]  {decode_steps} steps, {ms_tok:.1f} ms/token/wave, "
          f"{gen_tokens / max(decode_wall, 1e-9) / n:.1f} tokens/s/rank "
          f"({n} ranks)")
    if ttft:
        p50 = float(np.median(list(ttft.values())))
        print(f"[prefill] TTFT p50 {p50 * 1e3:.1f} ms over {len(ttft)} reqs")
    stats = plans.cache_stats()
    hits = stats.get("plan_hits", 0) + stats.get("program_hits", 0)
    print(f"plans cache: {stats.get('plan_hits', 0)} plan hits / "
          f"{stats.get('plan_misses', 0)} misses, "
          f"{stats.get('program_hits', 0)} program hits")
    for rid in sorted(finished)[:2]:
        print(f"  req{rid}: {finished[rid][:12]}")

    if args.expect_phase_distinct and not distinct:
        print("EXPECT-PHASE-DISTINCT FAILED: prefill and decode resolved "
              "the same CommConfig", file=sys.stderr)
        return 2
    if args.expect_plan_hits and hits <= 0:
        print("EXPECT-PLAN-HITS FAILED: the serving run recorded zero "
              "CommPlan cache hits", file=sys.stderr)
        return 3
    assert sorted(finished) == [r.rid for r in reqs], "dropped requests"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
