"""Batched serving demo: prefill + greedy decode with the sequence-sharded
KV cache.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import input_specs as isp, setup
from repro.models import layers
from repro.train import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config(args.arch), dtype=jnp.float32)
    n = jax.device_count()
    model_axis = 4 if n >= 4 else 1
    mesh = jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
    comm = CommConfig()
    sess = setup.build_session(cfg, mesh, comm, concrete=True)

    max_len = args.prompt_len + args.gen
    shape_p = isp.ShapeSpec("demo", max_len, args.batch, "prefill")
    shape_d = isp.ShapeSpec("demo", max_len, args.batch, "decode")
    rt, prefill_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_p)
    _, decode_fn, _ = serve_mod.build_serve_fn(cfg, mesh, comm, shape_d)

    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len))
    pad = max_len - args.prompt_len
    # prefill at prompt length (cache capacity covers generation too)
    batch = {"tokens": jnp.asarray(
        np.pad(tokens, ((0, 0), (0, 0))), jnp.int32)}

    t0 = time.perf_counter()
    state = jax.block_until_ready(prefill_fn(sess.params, batch))
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms")

    # greedy decode via vocab-sharded argmax on the host side
    def pick(logits):
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)

    out_tokens = []
    tok = pick(state.last_logits)
    t0 = time.perf_counter()
    for i in range(args.gen):
        out_tokens.append(tok)
        state = decode_fn(sess.params, jnp.asarray(tok), state)
        tok = pick(state.last_logits)
    jax.block_until_ready(state.last_logits)
    dt = (time.perf_counter() - t0) / args.gen
    gen = np.stack(out_tokens, 1)
    print(f"decoded {args.gen} tokens/seq x {args.batch} seqs, "
          f"{dt*1e3:.1f} ms/token")
    print("sample generations (token ids):")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {gen[b][:16].tolist()}")


if __name__ == "__main__":
    main()
