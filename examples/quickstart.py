"""Quickstart: ACCL-X collectives in 60 seconds.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's configuration surface on an 8-device mesh:
streaming vs buffered point-to-point, ring all-reduce with int8 wire
compression, and the modeled latency difference (Eq. 1).
"""
import functools

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (CommConfig, CommMode, Compression, Communicator,
                        Scheduling, V5E, collectives, latmodel)


def main():
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("x",))
    comm = Communicator.from_mesh(mesh, "x")
    print(f"mesh: {n} devices")

    x = np.random.RandomState(0).randn(n, 1024).astype(np.float32)

    # --- streaming vs buffered sendrecv --------------------------------
    for mode in (CommMode.STREAMING, CommMode.BUFFERED):
        cfg = CommConfig(mode=mode)

        @functools.partial(compat.shard_map, mesh=mesh, in_specs=P("x"),
                           out_specs=P("x"))
        def ring(xs):
            return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]

        out = np.asarray(ring(x))
        ok = np.allclose(out, np.roll(x, 1, axis=0))
        lat = latmodel.pingping_latency(x[0].nbytes, cfg, V5E)
        print(f"{mode.value:10s} ring sendrecv ok={ok} "
              f"modeled latency {lat*1e6:.2f} us")

    # --- ring all-reduce with the compression plugin --------------------
    for compression in (Compression.NONE, Compression.INT8):
        cfg = CommConfig(algorithm="ring", compression=compression)

        @functools.partial(compat.shard_map, mesh=mesh, in_specs=P("x"),
                           out_specs=P("x"))
        def allreduce(xs):
            return collectives.all_reduce(xs[0], comm, cfg)[None]

        out = np.asarray(allreduce(x))
        err = np.abs(out[0] - x.sum(0)).max() / np.abs(x.sum(0)).max()
        wire = latmodel.wire_bytes(x[0].nbytes, cfg)
        print(f"ring all-reduce compression={compression.value:5s} "
              f"rel_err={err:.2e} wire_bytes/msg={wire:.0f}")

    # --- host vs fused ("PL") scheduling (the paper's l_k) --------------
    from repro.core import scheduler
    lk = scheduler.measure_dispatch_overhead()
    print(f"measured host dispatch l_k = {lk*1e6:.1f} us "
          f"(paper: ~30 us through XRT; fused/PL: sub-us)")


if __name__ == "__main__":
    main()
