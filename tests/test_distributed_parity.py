"""Distributed-vs-single-device equivalence (the core SPMD correctness tests).

Gradient parity is asserted strictly (the forward/backward including all
ACCL-X collectives and the f-operator scheme must be numerically exact).
Post-optimizer parity over multiple steps is asserted only for non-MoE,
non-SSM archs: discrete MoE routing and the SSD exp-path amplify fp32
round-off into macroscopic (but benign) divergence.
"""
import pytest

from helpers import run_multidevice

GRAD_TOL = {  # relative, per max|grad| of the leaf
    "qwen3-8b": 1e-4, "gemma3-1b": 1e-4, "phi-3-vision-4.2b": 1e-4,
    "command-r-plus-104b": 1e-4, "deepseek-coder-33b": 1e-4,
    "seamless-m4t-large-v2": 1e-4, "deepseek-v3-671b": 1e-4,
    "mixtral-8x22b": 1e-3,       # capacity-gather ties
    # SSD exp-path fp32 noise; zamba2's bound is draw-dependent (the
    # partitionable-threefry draw lands at ~5e-2 on the embed table).
    "mamba2-130m": 2e-3, "zamba2-7b": 8e-2,
}

_TEMPLATE = """
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup
from repro.train import train_step as ts

ARCH = {arch!r}
TOL = {tol}
cfg = dataclasses.replace(get_smoke_config(ARCH), dtype=jnp.float32)
comm = CommConfig()
rng = np.random.RandomState(0)
B, S = 4, 32
batch = {{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}}
if cfg.family == "vlm":
    batch["patches"] = jnp.asarray(
        rng.randn(B, cfg.num_patches, cfg.frontend_dim), jnp.float32)
if cfg.family == "audio":
    batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.frontend_dim), jnp.float32)

def grads_for(mesh, fsdp=False):
    sess = setup.build_session(cfg, mesh, comm, concrete=True, fsdp=fsdp)
    rt = sess.rt
    lg = ts.make_loss_and_grad(rt)
    def f(params, batch):
        loss, parts, grads = lg(params, batch)
        grads = ts.grad_model_sync(grads, sess.mask, rt)
        if fsdp:
            # normalize FSDP leaves (pre-summed over data) for comparison
            from repro.optim import adamw
            reg, fs = adamw.partition_params(grads, rt.fsdp_plan)
            fs = jax.tree.map(lambda g: None if g is None else g / rt.mesh.dp,
                              fs, is_leaf=lambda x: x is None)
            grads = adamw._merge(reg, fs)
        return loss, grads
    bspec = jax.tree.map(
        lambda _: P(tuple(a for a in mesh.axis_names if a != "model")), batch)
    sm = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(sess.param_spec, bspec),
                               out_specs=(P(), sess.param_spec),
                               check_vma=False))
    loss, grads = sm(sess.params, batch)
    return float(loss), jax.tree.map(
        lambda x: np.asarray(jax.device_get(x)), grads)

def trim(a, b):
    if a.shape == b.shape:
        return a, b
    sl = tuple(slice(0, min(x, y)) for x, y in zip(a.shape, b.shape))
    return a[sl], b[sl]

l1, g1 = grads_for(jax.make_mesh((1, 1), ("data", "model")))
l4, g4 = grads_for(jax.make_mesh((1, 4), ("data", "model")))
assert abs(l1 - l4) < 1e-4, ("loss fwd parity", l1, l4)
flat1, _ = jax.tree_util.tree_flatten_with_path(g1)
flat4 = jax.tree.leaves(g4)
for (path, a), b in zip(flat1, flat4):
    if a.size != b.size:   # moe layout (tp,e_loc) permutes — compare sorted
        assert np.allclose(np.sort(a.ravel()), np.sort(b.ravel()),
                           atol=TOL * (np.abs(a).max() + 1e-9)), \
            (jax.tree_util.keystr(path), "layout")
        continue
    a2, b2 = trim(a, b)
    err = np.max(np.abs(a2 - b2)) / (np.max(np.abs(a2)) + 1e-9)
    assert err < TOL, (jax.tree_util.keystr(path), float(err))
print("GRAD PARITY OK", ARCH)
"""


@pytest.mark.parametrize("arch", sorted(GRAD_TOL))
def test_grad_parity_tp4(arch):
    out = run_multidevice(_TEMPLATE.format(arch=arch, tol=GRAD_TOL[arch]))
    assert "GRAD PARITY OK" in out


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-1b"])
def test_train_steps_parity_dense(arch):
    """Full 3-step training parity (optimizer included) for dense archs."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import mesh as meshlib, setup
from repro.optim import adamw

cfg = dataclasses.replace(get_smoke_config({arch!r}), dtype=jnp.float32)
comm = CommConfig()
oc = adamw.OptConfig(lr=1e-2, warmup_steps=1, total_steps=100, zero1=True)
rng = np.random.RandomState(0)
B, S = 4, 32
batch = {{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
          "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}}

def run(mesh, fsdp=False, steps=3):
    sess = setup.build_session(cfg, mesh, comm, oc=oc, fsdp=fsdp, seed=0)
    bspec = jax.tree.map(
        lambda _: P(tuple(a for a in mesh.axis_names if a != "model")), batch)
    step = setup.make_sharded_train_step(sess, donate=False)(bspec)
    p, o = sess.params, sess.opt_state
    for i in range(steps):
        p, o, m = step(p, o, batch)
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), p), m

ref, mref = run(jax.make_mesh((1, 1), ("data", "model")))
for fsdp in (False, True):
    got, mgot = run(meshlib.make_test_mesh(data=2, model=4), fsdp=fsdp)
    assert abs(float(mref["loss"]) - float(mgot["loss"])) < 5e-4, \
        (fsdp, float(mref["loss"]), float(mgot["loss"]))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        assert np.max(np.abs(a - b)) / (np.abs(a).max() + 1e-9) < 8e-3
print("TRAIN PARITY OK")
""".format(arch=arch))
    assert "TRAIN PARITY OK" in out


def test_multipod_mesh_train_runs():
    """3-axis (pod, data, model) mesh: one train step runs and is finite."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup
from repro.optim import adamw

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
oc = adamw.OptConfig(lr=1e-3, zero1=True)
sess = setup.build_session(cfg, mesh, CommConfig(), oc=oc)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)))}
bspec = jax.tree.map(lambda _: P(("pod", "data")), batch)
step = setup.make_sharded_train_step(sess, donate=False)(bspec)
p, o, m = step(sess.params, sess.opt_state, batch)
assert np.isfinite(float(m["loss"]))
print("MULTIPOD OK", float(m["loss"]))
""")
    assert "MULTIPOD OK" in out
