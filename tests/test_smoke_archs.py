"""Per-architecture smoke tests (single device): reduced config of the same
family, one forward + one train step, asserting output shapes and finite
values — as required by the assignment."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core.config import CommConfig
from repro.models import transformer
from repro.models.common import MeshContext, Runtime

ARCHS = list_archs()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.num_patches, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.frontend_dim), jnp.float32)
    return batch


def test_registry_has_all_assigned_archs():
    assert set(ARCHS) == {
        "zamba2-7b", "qwen3-8b", "command-r-plus-104b", "gemma3-1b",
        "deepseek-coder-33b", "mixtral-8x22b", "deepseek-v3-671b",
        "phi-3-vision-4.2b", "mamba2-130m", "seamless-m4t-large-v2"}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    rt = Runtime(cfg=cfg, mesh=MeshContext(), comm=CommConfig())
    params = transformer.init_model(jax.random.PRNGKey(0), cfg, tp=1)
    batch = _batch(cfg)
    out = jax.jit(lambda p, b: transformer.forward(p, b, rt, train=False)
                  )(params, batch)
    B, S = batch["tokens"].shape
    exp_s = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert out.logits.shape == (B, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    from repro.optim import adamw
    from repro.train import train_step as ts
    cfg = get_smoke_config(arch)
    rt = Runtime(cfg=cfg, mesh=MeshContext(), comm=CommConfig())
    params = transformer.init_model(jax.random.PRNGKey(0), cfg, tp=1)
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10, zero1=False)
    state = adamw.init_state(params, oc, rt)
    fn = ts.make_train_step(rt, oc, jax.tree.map(lambda _: 0, params))
    batch = _batch(cfg)
    p2, s2, metrics = jax.jit(fn)(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_values(arch):
    """The FULL configs carry the exact published hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, (got, expected)


def test_param_counts_sane():
    """Analytic param counts in the right ballpark for the headline sizes."""
    approx = {
        "qwen3-8b": (8e9, 0.35),
        "command-r-plus-104b": (104e9, 0.35),
        "deepseek-coder-33b": (33e9, 0.35),
        "mixtral-8x22b": (141e9, 0.35),
        "deepseek-v3-671b": (671e9, 0.35),
        "mamba2-130m": (130e6, 0.45),
        "zamba2-7b": (7e9, 0.45),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)
