"""Disk-backed plan store: roundtrips, canonical keys, corrupt/stale entry
recovery, schema versioning, env/CLI activation, concurrent writers, and the
plans._memo disk tier (a cleared in-memory cache warm-starts from disk)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from helpers import REPO


def _planstore():
    from repro.core import planstore
    return planstore


def _unwire_jax():
    """Detach the JAX compilation cache from any tmp dir a test wired."""
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", None)
    except Exception:
        pass


@pytest.fixture
def disk_store(tmp_path, monkeypatch):
    """plans cache + planstore activated on a fresh tmp dir, fully undone."""
    planstore = _planstore()
    from repro.core import plans
    monkeypatch.delenv(planstore.ENV_VAR, raising=False)
    planstore.configure(str(tmp_path), wire_jax=False)
    plans.clear_cache()
    plans.reset_stats()
    yield planstore.active(wire_jax=False)
    planstore.configure(None, wire_jax=False)
    plans.clear_cache()
    plans.reset_stats()
    _unwire_jax()


# ----------------------------------------------------------------------
# Key canonicalization
# ----------------------------------------------------------------------

def test_cfg_key_is_stable_json_primitives():
    """_cfg_key must never leak enum objects (the old dataclasses.astuple
    encoding did) and must carry the schema stamp that versions the disk
    format."""
    from repro.core import plans
    from repro.core.config import CommConfig, Transport
    planstore = _planstore()

    key = plans._cfg_key(CommConfig())
    assert key[0] == plans.CFG_KEY_SCHEMA
    for name, value in key[1:]:
        assert isinstance(name, str)
        assert value is None or isinstance(value, (bool, int, float, str))
    # deterministic + JSON-roundtrippable
    assert plans._cfg_key(CommConfig()) == key
    canon = planstore.canonical_key(key)
    assert planstore.canonical_key(key) == canon
    json.loads(canon)
    # a config change produces a different key
    other = plans._cfg_key(CommConfig(transport=Transport.ORDERED))
    assert other != key
    assert plans._cfg_key(None) == ()


def test_canonical_key_rejects_non_primitives():
    planstore = _planstore()

    class Weird:
        pass

    with pytest.raises(TypeError):
        planstore.canonical_key(("a", Weird()))
    # nested tuples of primitives are fine and order-sensitive
    a = planstore.canonical_key((1, ("x", 2.5), None, True))
    b = planstore.canonical_key((1, ("x", 2.5), True, None))
    assert a != b


def test_non_serializable_keys_stay_memory_only(tmp_path):
    """put never raises: a non-canonical key (or unencodable value) returns
    False and writes nothing."""
    planstore = _planstore()
    store = planstore.PlanStore(tmp_path)

    class Weird:
        pass

    assert store.put("ring", ("a", Weird()), (1, 2)) is False
    assert store.get("ring", ("a", Weird())) is planstore.MISSING
    assert store.put("plan", ("k",), object()) is False   # unencodable value
    assert store.entry_count() == 0


# ----------------------------------------------------------------------
# Roundtrips
# ----------------------------------------------------------------------

def test_plain_kind_roundtrips(tmp_path):
    """rounds / ring / perm values come back as the same nested int tuples
    the in-memory cache stores."""
    planstore = _planstore()
    planstore.reset_disk_stats()
    store = planstore.PlanStore(tmp_path)
    values = {
        "rounds": (((0, 1), (2, 3)), ((1, 2),)),
        "ring": tuple((i, (i + 1) % 8) for i in range(8)),
        "perm": ((0, 1), (1, 0)),
    }
    for kind, value in values.items():
        key = ("t", kind, 8)
        assert store.get(kind, key) is planstore.MISSING
        assert store.put(kind, key, value)
        got = store.get(kind, key)
        assert got == value and isinstance(got, tuple)
    st = planstore.disk_stats()
    assert st == {"disk_hits": 3, "disk_misses": 3,
                  "disk_writes": 3, "disk_corrupt": 0}


def test_chunk_and_comm_plan_roundtrip_through_memo(disk_store):
    """The real path: plans.* builders persist on miss; a cleared in-memory
    cache (a "fresh process") rebuilds the identical value from disk and the
    disk hit counts as a plan hit."""
    from repro.core import plans
    from repro.core.communicator import Communicator
    from repro.core.config import CommConfig, Transport
    planstore = _planstore()

    cfg = CommConfig(chunk_bytes=2048, transport=Transport.ORDERED, window=2)
    comm = Communicator(("x",), (8,))
    c1 = plans.chunk_plan((1024,), np.float32, cfg)
    p1 = plans.get_plan("sendrecv", comm, cfg, (1024,), np.float32)
    st = plans.cache_stats()
    assert st["disk_writes"] >= 2 and st["disk_hits"] == 0

    plans.clear_cache()                  # memory gone, disk survives
    hits_before = st["plan_hits"]
    c2 = plans.chunk_plan((1024,), np.float32, cfg)
    p2 = plans.get_plan("sendrecv", comm, cfg, (1024,), np.float32)
    st = plans.cache_stats()
    assert c2 == c1 and c2 is not c1     # rebuilt from disk, value-identical
    assert p2 == p1 and p2 is not p1
    assert st["disk_hits"] >= 2
    assert st["plan_hits"] > hits_before   # disk hits count as plan hits
    assert st["disk_corrupt"] == 0


def test_executable_roundtrip(tmp_path):
    """AOT-compiled programs serialize whole and replay bit-identically."""
    import jax
    import jax.numpy as jnp
    planstore = _planstore()
    store = planstore.PlanStore(tmp_path)

    x = jnp.arange(8.0)
    compiled = jax.jit(lambda v: v * 2.0 + 1.0).lower(x).compile()
    assert store.get_executable(("aot", 8)) is planstore.MISSING
    assert store.put_executable(("aot", 8), compiled)
    loaded = store.get_executable(("aot", 8))
    assert loaded is not planstore.MISSING
    assert (np.asarray(loaded(x)).tobytes()
            == np.asarray(compiled(x)).tobytes())


# ----------------------------------------------------------------------
# Corrupt / stale / mismatched entries: always a rebuildable miss
# ----------------------------------------------------------------------

def _single_entry(tmp_path):
    return next((tmp_path / "plans").glob("*.json"))


def test_truncated_entry_recovers_by_rebuild(tmp_path):
    planstore = _planstore()
    planstore.reset_disk_stats()
    store = planstore.PlanStore(tmp_path)
    key, value = ("k", 1), ((0, 1), (1, 2))
    assert store.put("rounds", key, value)
    path = _single_entry(tmp_path)
    path.write_text(path.read_text()[:11])        # torn write simulation
    assert store.get("rounds", key) is planstore.MISSING
    st = planstore.disk_stats()
    assert st["disk_corrupt"] == 1 and st["disk_misses"] == 1
    assert not path.exists()                      # bad file removed
    # the caller's contract: rebuild and overwrite, then it hits again
    assert store.put("rounds", key, value)
    assert store.get("rounds", key) == value


def test_schema_version_mismatch_is_miss(tmp_path):
    planstore = _planstore()
    planstore.reset_disk_stats()
    store = planstore.PlanStore(tmp_path)
    assert store.put("ring", ("r",), ((0, 1),))
    path = _single_entry(tmp_path)
    entry = json.loads(path.read_text())
    entry["schema"] = planstore.SCHEMA_VERSION + 1
    path.write_text(json.dumps(entry))
    assert store.get("ring", ("r",)) is planstore.MISSING
    assert planstore.disk_stats()["disk_corrupt"] == 1


def test_key_mismatch_never_answers_wrong_lookup(tmp_path):
    """The full key stored in the entry guards against hash collisions and
    recycled files: a tampered key field is a miss, not a wrong answer."""
    planstore = _planstore()
    store = planstore.PlanStore(tmp_path)
    assert store.put("perm", ("p", 8), ((0, 1),))
    path = _single_entry(tmp_path)
    entry = json.loads(path.read_text())
    entry["key"] = ["p", 9]
    path.write_text(json.dumps(entry))
    assert store.get("perm", ("p", 8)) is planstore.MISSING


def test_corrupt_program_entry_is_miss(tmp_path):
    planstore = _planstore()
    planstore.reset_disk_stats()
    store = planstore.PlanStore(tmp_path)
    path = store._exec_path(planstore.canonical_key(("prog", 1)))
    path.parent.mkdir(parents=True)
    path.write_bytes(b"not a pickle")
    assert store.get_executable(("prog", 1)) is planstore.MISSING
    st = planstore.disk_stats()
    assert st["disk_corrupt"] == 1 and st["disk_misses"] == 1
    assert not path.exists()


# ----------------------------------------------------------------------
# Activation: env var, --plan-dir override, disabled
# ----------------------------------------------------------------------

def test_env_and_configure_control(tmp_path, monkeypatch):
    planstore = _planstore()
    monkeypatch.delenv(planstore.ENV_VAR, raising=False)
    planstore.configure(None, wire_jax=False)
    assert planstore.active(wire_jax=False) is None

    monkeypatch.setenv(planstore.ENV_VAR, str(tmp_path / "via-env"))
    st = planstore.active(wire_jax=False)
    assert st is not None and st.root == tmp_path / "via-env"

    # explicit empty string disables even with the env var set
    assert planstore.configure("", wire_jax=False) is None
    assert planstore.active(wire_jax=False) is None

    # clearing the override hands control back to the env, then to nothing
    planstore.configure(None, wire_jax=False)
    assert planstore.active(wire_jax=False) is not None
    monkeypatch.delenv(planstore.ENV_VAR)
    assert planstore.active(wire_jax=False) is None


def test_inert_without_directory(monkeypatch):
    """No dir configured -> plans cache is memory-only and touches no disk
    counters."""
    planstore = _planstore()
    from repro.core import plans
    monkeypatch.delenv(planstore.ENV_VAR, raising=False)
    planstore.configure(None, wire_jax=False)
    plans.clear_cache()
    plans.reset_stats()
    from repro.core.config import CommConfig
    plans.chunk_plan((64,), np.float32, CommConfig())
    st = plans.cache_stats()
    assert st["disk_hits"] == 0 and st["disk_misses"] == 0
    assert st["disk_writes"] == 0


# ----------------------------------------------------------------------
# Concurrent writers
# ----------------------------------------------------------------------

def test_two_process_concurrent_writes_leave_valid_store(tmp_path):
    """Two processes hammering the same keys must both exit cleanly and
    leave every entry readable (atomic replace: last writer wins, readers
    never see a torn file)."""
    code = """
import sys
from repro.core import planstore
store = planstore.PlanStore(sys.argv[1])
ring = tuple((j, (j + 1) % 8) for j in range(8))
for rep in range(3):
    for i in range(20):
        assert store.put("ring", ("race", i), ring)
        got = store.get("ring", ("race", i))
        assert got is planstore.MISSING or got == ring
print("WRITER OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    procs = [subprocess.Popen([sys.executable, "-c", code, str(tmp_path)],
                              env=env, cwd=str(REPO),
                              stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                              text=True)
             for _ in range(2)]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, f"writer failed\n{out}\n{err}"
        assert "WRITER OK" in out

    planstore = _planstore()
    store = planstore.PlanStore(tmp_path)
    ring = tuple((j, (j + 1) % 8) for j in range(8))
    for i in range(20):
        assert store.get("ring", ("race", i)) == ring
    # no temp-file litter left behind
    assert not list((tmp_path / "plans").glob("*.tmp"))
