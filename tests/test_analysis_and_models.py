"""HLO analyzer, latency-model properties, scheduler equivalence, streaming
engine pieces — the measurement infrastructure must itself be correct."""
import numpy as np
import pytest

from helpers import run_multidevice


def test_hlo_analysis_scan_trip_counts():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert a["flops"] == 10 * 2 * 128 ** 3

    def g(x, w):                      # nested scans: 3 × 5 iterations
        def outer(c, _):
            def inner(cc, _):
                return cc @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    a2 = analyze_hlo(jax.jit(g).lower(x, w).compile().as_text())
    assert a2["flops"] == 15 * 2 * 128 ** 3


def test_hlo_analysis_counts_collectives_in_scans():
    out = run_multidevice("""
import jax, jax.numpy as jnp
from repro import compat
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((8,), ("x",))

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
         check_vma=False)
def f(xs):
    def body(c, _):
        return (jax.lax.psum(c, "x") * jnp.float32(0.1)).astype(c.dtype), None
    out, _ = jax.lax.scan(body, xs, None, length=7)
    return out

x = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
a = analyze_hlo(jax.jit(f).lower(x).compile().as_text())
# 7 all-reduces of a (1, 1024) f32 shard
assert a["collective_counts"]["all-reduce"] == 7, a["collective_counts"]
assert a["collective_bytes"]["all-reduce"] == 7 * 1024 * 4
print("HLO COLLECTIVES OK")
""")
    assert "HLO COLLECTIVES OK" in out


def test_latency_model_eq1_properties():
    """Eq. 1 invariants from the paper, under the hypothesis strategy."""
    from helpers import require_hypothesis
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from repro.core import latmodel
    from repro.core.config import (CommConfig, CommMode, Scheduling, V5E)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(64, 1 << 22))
    def check(msg):
        buf_host = CommConfig(mode=CommMode.BUFFERED, scheduling=Scheduling.HOST)
        buf_pl = CommConfig(mode=CommMode.BUFFERED, scheduling=Scheduling.FUSED)
        str_pl = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.FUSED)
        l_bh = latmodel.pingping_latency(msg, buf_host, V5E)
        l_bp = latmodel.pingping_latency(msg, buf_pl, V5E)
        l_sp = latmodel.pingping_latency(msg, str_pl, V5E)
        # strict ordering: streaming-PL < buffered-PL < buffered-host
        assert l_sp < l_bp < l_bh
        # host-scheduling penalty == 2*(l_k_host - l_k_fused)
        assert abs((l_bh - l_bp) - 2 * (V5E.host_dispatch - V5E.fused_dispatch)) < 1e-12
        # effective bw below link peak, monotone in message size
        assert latmodel.effective_bandwidth(msg, str_pl, V5E) < V5E.ici_bw

    check()


def test_scheduler_runners_equivalent():
    """Host-scheduled and fused runners must produce identical numerics; the
    host runner pays one dispatch per phase (the paper's l_k accounting)."""
    import jax.numpy as jnp
    from repro import compat
    from repro.core import scheduler

    phases = [
        scheduler.Phase("a", lambda c: c * 2.0),
        scheduler.Phase("comm", lambda c: c + 1.0, is_comm=True),
        scheduler.Phase("b", lambda c: c ** 2),
    ]
    x = jnp.arange(8.0)
    host = scheduler.HostScheduledRunner(phases)
    fused = scheduler.FusedRunner(phases)
    out_h = host.run_step(x)
    out_f = fused.run_step(x)
    np.testing.assert_allclose(np.asarray(out_h), np.asarray(out_f))
    assert host.dispatch_count == 3
    assert fused.dispatch_count == 1
    assert host.modeled_dispatch_overhead() > fused.modeled_dispatch_overhead()


def test_streaming_pipelined_consume():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from repro import compat
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.core import CommConfig, Communicator, streaming

mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
cfg = CommConfig(chunk_bytes=512)
x = np.random.RandomState(0).randn(8, 256).astype(np.float32)

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")))
def f(xs):
    total, received = streaming.pipelined_consume(
        xs[0], comm.ring_perm(), "x", cfg,
        consume=lambda acc, i, chunk: acc + jnp.sum(chunk),
        init=jnp.zeros(()))
    return total[None], received[None]

total, received = f(x)
ref = np.roll(x, 1, axis=0)
assert np.allclose(np.asarray(received), ref)
assert np.allclose(np.asarray(total), ref.sum(1), rtol=1e-5)
print("PIPELINED CONSUME OK")
""")
    assert "PIPELINED CONSUME OK" in out


def test_wire_bytes_model():
    from repro.core import latmodel
    from repro.core.config import CommConfig, Compression
    msg = 1 << 20
    none = latmodel.wire_bytes(msg, CommConfig())
    bf16 = latmodel.wire_bytes(msg, CommConfig(compression=Compression.BF16))
    int8 = latmodel.wire_bytes(
        msg, CommConfig(algorithm="ring", compression=Compression.INT8))
    assert none == msg
    assert bf16 == msg / 2
    assert msg / 4 < int8 < msg / 3   # payload/4 + scales overhead
