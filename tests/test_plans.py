"""CommPlan cache: keying, hit/miss accounting, no-retrace replay, and
bitwise parity of cached vs uncached execution across the scheduling x
transport matrix."""
import numpy as np
import pytest

from helpers import require_hypothesis, run_multidevice


# ----------------------------------------------------------------------
# Keying: what hits and what misses
# ----------------------------------------------------------------------

def _fresh_plans():
    from repro.core import plans
    plans.clear_cache()
    plans.reset_stats()
    return plans


def test_plan_keying_hits_and_misses():
    """Identical call hits; config, shape, dtype, and communicator changes
    each miss."""
    import dataclasses
    plans = _fresh_plans()
    from repro.core.communicator import Communicator
    from repro.core.config import CommConfig, Transport

    cfg = CommConfig(chunk_bytes=1 << 12)
    comm = Communicator(("x",), (8,))

    p1 = plans.get_plan("sendrecv", comm, cfg, (1024,), np.float32)
    assert plans.cache_stats()["plan_misses"] == 1
    p2 = plans.get_plan("sendrecv", comm, cfg, (1024,), np.float32)
    assert p2 is p1                          # identical call -> hit
    assert plans.cache_stats()["plan_hits"] == 1

    # a fresh-but-equal communicator still hits (value keying, not identity)
    p2b = plans.get_plan("sendrecv", Communicator(("x",), (8,)), cfg,
                         (1024,), np.float32)
    assert p2b is p1

    # each of these must MISS
    before = plans.cache_stats()["plan_misses"]
    plans.get_plan("sendrecv", comm,
                   dataclasses.replace(cfg, transport=Transport.ORDERED),
                   (1024,), np.float32)                       # config change
    plans.get_plan("sendrecv", comm, cfg, (2048,), np.float32)  # shape change
    plans.get_plan("sendrecv", comm, cfg, (1024,), np.int8)     # dtype change
    plans.get_plan("sendrecv", Communicator(("y",), (4,)), cfg,
                   (1024,), np.float32)                       # comm change
    plans.get_plan("all_reduce", comm, cfg, (1024,), np.float32)  # collective
    assert plans.cache_stats()["plan_misses"] == before + 5


def test_plan_keying_distinct_inputs_never_alias():
    """Hypothesis property: two get_plan calls differing in ANY component —
    collective, communicator axes/sizes, **topology spec** (shape, per-hop
    cost, placement), config, shape, or dtype — must never return the same
    cached plan object; identical inputs always must."""
    hypothesis = require_hypothesis()
    from hypothesis import given, settings, strategies as st

    import dataclasses
    plans = _fresh_plans()
    from repro.core.communicator import Communicator
    from repro.core.config import CommConfig, Transport
    from repro.core.topology import TorusSpec, snake_placement

    specs = st.one_of(
        st.none(),
        st.builds(lambda shape, hop, snake: TorusSpec(
            shape, per_hop_ns=hop,
            placement=snake_placement(shape) if snake else None),
            st.sampled_from([(2, 4), (4, 2), (1, 8), (2, 2)]),
            st.sampled_from([250.0, 500.0]),
            st.booleans()))

    inputs = st.tuples(
        st.sampled_from(["sendrecv", "multi_neighbor", "all_reduce"]),
        st.sampled_from([("x",), ("y",)]),
        specs,
        st.sampled_from([1 << 12, 1 << 16]),        # chunk_bytes
        st.sampled_from(list(Transport)),
        st.sampled_from([(256,), (1024,), (64, 3)]),
        st.sampled_from(["float32", "int8"]),
    )

    def build(inp):
        coll, axes, spec, chunk, transport, shape, dtype = inp
        n = spec.n_ranks if spec is not None else 8
        comm = Communicator(axes, (n,), topo=spec)
        cfg = CommConfig(chunk_bytes=chunk, transport=transport)
        return plans.get_plan(coll, comm, cfg, shape, np.dtype(dtype))

    @settings(max_examples=60, deadline=None)
    @given(a=inputs, b=inputs)
    def prop(a, b):
        pa, pb = build(a), build(b)
        if a == b:
            assert pa is pb
        else:
            assert pa is not pb
        # and replay is stable
        assert build(a) is pa

    prop()


def test_chunk_plan_matches_streaming_layouts():
    """The cached layouts replay exactly what the engines derived inline:
    equal_split == split_chunks/num_chunks, aligned == aligned_chunks."""
    import math
    plans = _fresh_plans()
    import jax.numpy as jnp
    from repro.core import streaming
    from repro.core.config import CommConfig, Transport

    rng = np.random.RandomState(0)
    for _ in range(30):
        size = int(rng.randint(1, 5000))
        align = int(rng.choice([1, 3, 7, 16]))
        cfg = CommConfig(chunk_bytes=int(rng.choice([512, 2048, 1 << 16])),
                         max_chunks=int(rng.choice([2, 8, 16])),
                         transport=Transport.ORDERED,
                         window=int(rng.choice([1, 2, 4])))
        x = jnp.zeros((size,), jnp.float32)
        n_ref = streaming.num_chunks(size * 4, cfg)
        p_eq = plans.chunk_plan((size,), np.float32, cfg, equal_split=True)
        assert p_eq.n_chunks == n_ref
        assert p_eq.chunk_elems == math.ceil(size / n_ref)
        n_al, elems_al = streaming.aligned_chunks(x, cfg, align=align)
        p_al = plans.chunk_plan((size,), np.float32, cfg, align=align)
        assert (p_al.n_chunks, p_al.chunk_elems) == (n_al, elems_al)
        assert elems_al % align == 0
        # ack structure mirrors the ordered-transport window rule
        for i, a in enumerate(p_eq.ack_of):
            assert a == (i - cfg.window if i >= cfg.window else -1)


def test_edge_rounds_and_ring_perm_cached():
    plans = _fresh_plans()
    from repro.core.collectives import edge_color_rounds
    from repro.core.communicator import Communicator

    edges = [(0, 1), (1, 2), (0, 2), (3, 0)]
    r1 = edge_color_rounds(edges)
    r2 = edge_color_rounds(list(edges))
    assert r1 is r2
    # every edge exactly once, every round ppermute-valid
    flat = [e for r in r1 for e in r]
    assert sorted(flat) == sorted(edges)
    for r in r1:
        assert len({s for s, _ in r}) == len(r)
        assert len({d for _, d in r}) == len(r)

    comm = Communicator(("x",), (8,))
    assert comm.ring_perm() == [(i, (i + 1) % 8) for i in range(8)]
    assert comm.reverse_ring_perm(2) == [(i, (i - 2) % 8) for i in range(8)]


def test_validated_perm_still_rejects_invalid():
    """Caching must not swallow the validation errors."""
    plans = _fresh_plans()
    from repro.core.communicator import Communicator
    comm = Communicator(("x",), (4,))
    with pytest.raises(ValueError):
        plans.validated_perm(comm, [(0, 1), (0, 2)])   # duplicate source
    with pytest.raises(ValueError):
        plans.validated_perm(comm, [(0, 9)])           # outside communicator
    assert plans.validated_perm(comm, [(0, 1), (1, 0)]) == ((0, 1), (1, 0))


def test_cache_bypass_env(monkeypatch):
    plans = _fresh_plans()
    from repro.core.config import CommConfig
    monkeypatch.setenv("REPRO_PLAN_CACHE", "0")
    p1 = plans.chunk_plan((100,), np.float32, CommConfig())
    p2 = plans.chunk_plan((100,), np.float32, CommConfig())
    assert p1 is not p2 and p1 == p2       # re-derived, identical values
    assert plans.cache_stats()["plan_hits"] == 0
    monkeypatch.delenv("REPRO_PLAN_CACHE")
    p3 = plans.chunk_plan((100,), np.float32, CommConfig())
    p4 = plans.chunk_plan((100,), np.float32, CommConfig())
    assert p3 is p4


def test_memo_caches_none_result():
    """Regression: a build that legitimately returns None (or any falsy
    value) must be cached like everything else — the old truthiness check
    turned it into a perpetual miss that re-ran the build every call."""
    plans = _fresh_plans()
    calls = []

    def build():
        calls.append(1)
        return None

    r1 = plans._memo("regress", ("none-key",), build,
                     "plan_hits", "plan_misses")
    r2 = plans._memo("regress", ("none-key",), build,
                     "plan_hits", "plan_misses")
    assert r1 is None and r2 is None
    assert len(calls) == 1
    st = plans.cache_stats()
    assert st["plan_hits"] == 1 and st["plan_misses"] == 1


# ----------------------------------------------------------------------
# Jitted-program replay: no retrace on the second call
# ----------------------------------------------------------------------

def test_jitted_program_no_retrace_on_second_call():
    """Trace-count probe: the builder (and the trace it wraps) runs once;
    the second call replays the cached program."""
    plans = _fresh_plans()
    import jax
    import jax.numpy as jnp

    traces = []

    def build():
        def f(x):
            traces.append(1)          # python side effect = one trace
            return x * 2.0
        return jax.jit(f)

    x = jnp.arange(8.0)
    f1 = plans.jitted_program(("probe", 8), build)
    y1 = f1(x)
    f2 = plans.jitted_program(("probe", 8), build)
    y2 = f2(x)
    assert f1 is f2
    assert len(traces) == 1            # no retrace on the second call
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    stats = plans.cache_stats()
    assert stats["program_hits"] == 1 and stats["program_misses"] == 1
    # a different key is a different program
    plans.jitted_program(("probe", 16), build)(x)
    assert len(traces) == 2


def test_commplan_program_replay():
    plans = _fresh_plans()
    import jax
    import jax.numpy as jnp
    from repro.core.config import CommConfig

    plan = plans.get_plan("all_reduce", None, CommConfig(), (8,), np.float32)
    builds = []

    def build():
        builds.append(1)
        return jax.jit(lambda v: v + 1.0)

    p1 = plan.program(build)
    p2 = plan.program(build)
    assert p1 is p2 and len(builds) == 1
    assert float(p1(jnp.zeros(()))) == 1.0


def test_commplan_program_race_builds_once():
    """Regression: CommPlan.program's check-then-set must hold the cache
    lock — concurrent same-key callers used to race past the check and each
    run the (expensive) build."""
    import threading
    import time
    plans = _fresh_plans()
    import jax
    from repro.core.config import CommConfig

    plan = plans.get_plan("all_reduce", None, CommConfig(), (8,), np.float32)
    plans.reset_stats()
    builds = []

    def build():
        builds.append(1)
        time.sleep(0.05)               # widen the race window
        return jax.jit(lambda v: v + 1.0)

    barrier = threading.Barrier(4)
    results = []

    def worker():
        barrier.wait()
        results.append(plan.program(build))

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1
    assert all(r is results[0] for r in results)
    st = plans.cache_stats()
    assert st["program_misses"] == 1 and st["program_hits"] == 3


# ----------------------------------------------------------------------
# Bitwise parity: cached vs uncached across scheduling x transport
# ----------------------------------------------------------------------

def test_cached_vs_uncached_bitwise_parity_matrix():
    """Every (scheduling, transport) combination of sendrecv, multi-neighbor
    exchange, and ring all-reduce must produce bit-identical results with
    the plan cache enabled and bypassed (REPRO_PLAN_CACHE=0)."""
    out = run_multidevice("""
import os
import numpy as np
import jax
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import plans
from repro.core.config import (CommConfig, CommMode, Scheduling, Transport)
from repro.core.communicator import Communicator
from repro.core import collectives

mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(0).randn(8, 130).astype(np.float32)

def run_all(cfg):
    results = []
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def p2p(xs):
        return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]
    results.append(np.asarray(p2p(x)))
    rounds = [comm.ring_perm(1), comm.reverse_ring_perm(1), comm.ring_perm(2)]
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def mn(xs):
        outs = collectives.multi_neighbor_exchange(
            [xs[0]] * len(rounds), rounds, comm, cfg)
        return sum(outs)[None]
    results.append(np.asarray(mn(x)))
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    def ar(xs):
        import dataclasses
        rcfg = dataclasses.replace(cfg, algorithm="ring")
        return collectives.all_reduce(xs[0], comm, rcfg)[None]
    results.append(np.asarray(ar(x)))
    return results

# HOST scheduling lowers the same per-op programs as FUSED (dispatch
# granularity is a caller concern), so FUSED x OVERLAPPED x transports x
# modes covers every distinct traced path.
for mode in (CommMode.STREAMING, CommMode.BUFFERED):
    for sched in (Scheduling.FUSED, Scheduling.OVERLAPPED):
        for tr in (Transport.ORDERED, Transport.UNORDERED):
            cfg = CommConfig(mode=mode, scheduling=sched, transport=tr,
                             chunk_bytes=512, window=2)
            os.environ.pop("REPRO_PLAN_CACHE", None)
            plans.clear_cache(); plans.reset_stats()
            cached = run_all(cfg)
            # the multi-round exchange replays the same chunk/perm plans
            # within one run: the cache was exercised, not bypassed
            assert plans.cache_stats()["plan_hits"] > 0, (mode, sched, tr)
            os.environ["REPRO_PLAN_CACHE"] = "0"
            plans.clear_cache()
            bypassed = run_all(cfg)
            os.environ.pop("REPRO_PLAN_CACHE", None)
            for a, c in zip(cached, bypassed):
                assert a.tobytes() == c.tobytes(), (mode, sched, tr)
print("PLAN PARITY OK")
""", timeout=540)
    assert "PLAN PARITY OK" in out


# ----------------------------------------------------------------------
# Warm sweep: the plan cache must make the second sweep cheaper
# ----------------------------------------------------------------------

def test_warm_sweep_reuses_programs_and_is_faster():
    out = run_multidevice("""
from repro import compat
from repro.core import plans
from repro.tune import TuneDB, run_sweep

mesh = compat.make_mesh((8,), ("x",))
cold, warm = {}, {}
db = run_sweep(mesh=mesh, collectives=("sendrecv",), sizes=(1024,),
               fast=True, max_configs=4, reps=1, inner=2, stats=cold)
db = run_sweep(mesh=mesh, collectives=("sendrecv",), sizes=(1024,),
               fast=True, max_configs=4, reps=1, inner=2, db=db, stats=warm)
assert cold["program_misses"] > 0 and cold["program_hits"] == 0, cold
assert warm["program_hits"] >= cold["program_misses"], (cold, warm)
assert warm["program_misses"] == 0, warm
# wall clock: warm must be at least 30% lower (it skips every compile)
assert warm["wall_s"] < 0.7 * cold["wall_s"], (cold["wall_s"], warm["wall_s"])
print("WARM SWEEP OK", round(cold["wall_s"], 2), round(warm["wall_s"], 2))
""", timeout=540)
    assert "WARM SWEEP OK" in out


# ----------------------------------------------------------------------
# Disk store: a FRESH PROCESS warm-starts from REPRO_PLAN_DIR, bit-identical
# ----------------------------------------------------------------------

_DISK_PARITY_CODE = """
import hashlib
import dataclasses
import numpy as np
import jax
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import plans, collectives
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, CommMode, Scheduling, Transport

plans.reset_stats()
mesh = jax.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(0).randn(8, 130).astype(np.float32)
cfg = CommConfig(mode=CommMode.STREAMING, scheduling=Scheduling.FUSED,
                 transport=Transport.ORDERED, chunk_bytes=512, window=2)

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
def p2p(xs):
    return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]

rcfg = dataclasses.replace(cfg, algorithm="ring")

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
def ar(xs):
    return collectives.all_reduce(xs[0], comm, rcfg)[None]

outs = [np.asarray(p2p(x)), np.asarray(ar(x))]
digest = hashlib.sha256(b"".join(o.tobytes() for o in outs)).hexdigest()
st = plans.cache_stats()
print("DIGEST", digest)
print("DISK", st["disk_hits"], st["disk_misses"], st["disk_writes"])
"""


def _parse_parity(out):
    lines = dict(l.split(" ", 1) for l in out.splitlines()
                 if l.startswith(("DIGEST", "DISK")))
    hits, misses, writes = (int(v) for v in lines["DISK"].split())
    return lines["DIGEST"], hits, misses, writes


def test_disk_store_cross_process_warm_start_bitwise(tmp_path, monkeypatch):
    """The PR's acceptance criterion: a fresh process pointed at a populated
    REPRO_PLAN_DIR reports disk hits and produces bit-identical collective
    results — and both match a run with the cache bypassed entirely."""
    monkeypatch.setenv("REPRO_PLAN_DIR", str(tmp_path / "store"))

    cold_digest, cold_hits, _, cold_writes = _parse_parity(
        run_multidevice(_DISK_PARITY_CODE))
    assert cold_hits == 0 and cold_writes > 0       # populated the store

    warm_digest, warm_hits, _, _ = _parse_parity(
        run_multidevice(_DISK_PARITY_CODE))         # fresh process, warm disk
    assert warm_hits > 0, "fresh process must warm-start from the store"
    assert warm_digest == cold_digest               # bitwise parity

    monkeypatch.setenv("REPRO_PLAN_CACHE", "0")     # disk + memory bypassed
    bypass_digest, bypass_hits, _, bypass_writes = _parse_parity(
        run_multidevice(_DISK_PARITY_CODE))
    assert bypass_hits == 0 and bypass_writes == 0
    assert bypass_digest == cold_digest
