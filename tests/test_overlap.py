"""Overlapped double-buffered halo exchange: the correctness harness.

Every comm path the SWE step can take — scheduling (host / fused /
overlapped) x transport (ordered / unordered) x partition count (1 / 2 / 4)
— must produce BITWISE-identical simulation state: the schedules differ only
in dependency structure, never in arithmetic.  Plus an HLO-level check that
the overlapped step really decouples interior compute from the permutes, and
hypothesis properties for the streaming engine's chunking round-trips.
"""
import numpy as np
import pytest

from helpers import require_hypothesis, run_multidevice


# ----------------------------------------------------------------------
# Parity matrix: scheduling x transport x n_parts, 20 steps, bitwise
# ----------------------------------------------------------------------

def test_parity_matrix_bitwise():
    out = run_multidevice("""
import itertools, jax, numpy as np
from repro.core.config import CommConfig, Scheduling, Transport
from repro.swe import driver
from repro.swe.partition import _rcb

N_STEPS = 20
ELEMENTS = 400

def flatten(sim, s):
    part = _rcb(sim.mesh.centroids, sim.pm.n_parts)
    counts = np.zeros(sim.pm.n_parts, int)
    vals = np.zeros((sim.mesh.n_elements, 3))
    for e in range(sim.mesh.n_elements):
        p = part[e]
        vals[e] = s[p, counts[p]]
        counts[p] += 1
    return vals

mesh1 = jax.make_mesh((1,), ("data",))
ref_sim = driver.build_simulation(ELEMENTS, mesh1, CommConfig())
ref = flatten(ref_sim, np.asarray(
    driver.make_sim_runner(ref_sim, N_STEPS)(ref_sim.state, 0.0)))

checked = 0
for n_parts, sched, transport in itertools.product(
        (1, 2, 4),
        (Scheduling.HOST, Scheduling.FUSED, Scheduling.OVERLAPPED),
        (Transport.ORDERED, Transport.UNORDERED)):
    cfg = CommConfig(scheduling=sched, transport=transport,
                     window=2 if transport == Transport.ORDERED else 4)
    dmesh = jax.make_mesh((n_parts,), ("data",))
    sim = driver.build_simulation(ELEMENTS, dmesh, cfg)
    if sched == Scheduling.HOST:
        s, _ = driver.make_host_scheduled_runner(sim).run(
            sim.state, 0.0, N_STEPS)
    else:
        s = driver.make_sim_runner(sim, N_STEPS)(sim.state, 0.0)
    v = flatten(sim, np.asarray(s))
    assert np.array_equal(ref, v), (
        f"parity broke: parts={n_parts} sched={sched.value} "
        f"transport={transport.value} maxdiff={np.abs(ref - v).max()}")
    checked += 1
assert checked == 18
print("PARITY MATRIX OK", checked)
""", n_devices=4)
    assert "PARITY MATRIX OK 18" in out


# ----------------------------------------------------------------------
# Interior/boundary partition invariants (what makes the scatter exact)
# ----------------------------------------------------------------------

def test_boundary_partition_invariants():
    from repro.swe.dg_solver import initial_state
    from repro.swe.mesh_gen import generate_bight_mesh
    from repro.swe.partition import partition_mesh

    mesh = generate_bight_mesh(800, seed=1)
    for n_parts in (1, 2, 4, 8):
        pm = partition_mesh(mesh, n_parts, initial_state(mesh))
        for p in range(pm.n_parts):
            nb = int(pm.n_boundary[p])
            k = int(pm.valid[p].sum())
            # boundary + interior(core) covers every real element exactly
            assert nb + int(pm.n_core[p]) == k
            real = pm.boundary_idx[p, :nb].tolist()
            assert len(set(real)) == nb                # no duplicates
            # boundary elements are exactly those with a remote edge
            remote = np.where((pm.edge_type[p] == 3).any(axis=1))[0]
            assert sorted(real) == remote.tolist()
            # padding repeats a real boundary row (0 when none exist), so
            # duplicate scatter writes carry identical values
            pad = pm.boundary_idx[p, nb:]
            assert (pad == (real[0] if nb else 0)).all()


# ----------------------------------------------------------------------
# HLO: the overlapped step decouples interior compute from the permutes
# ----------------------------------------------------------------------

def test_overlapped_step_hlo_decouples_compute():
    """The overlapped program must contain substantially more compute that is
    independent of the collective-permutes than the fused one (the property
    that lets a latency-hiding scheduler run it during the transfer).  On
    backends that split permutes into ``collective-permute-start``/``-done``
    pairs, additionally require compute scheduled inside a pair; this host's
    CPU backend emits synchronous permutes, so the dependency-class check is
    the load-bearing one."""
    out = run_multidevice("""
import jax
from repro.core.config import CommConfig, OVERLAPPED_CONFIG
from repro.swe import driver
from repro.launch.hlo_analysis import permute_overlap_stats

mesh = jax.make_mesh((4,), ("data",))
stats = {}
for label, cfg in (("fused", CommConfig()), ("overlapped", OVERLAPPED_CONFIG)):
    sim = driver.build_simulation(500, mesh, cfg)
    run = driver.make_sim_runner(sim, n_inner=1)
    txt = jax.jit(lambda s: run(s, 0.0)).lower(sim.state).compile().as_text()
    stats[label] = permute_overlap_stats(txt)

for label, st in stats.items():
    assert st["sync_permutes"] + st["async_pairs"] >= 1, (label, st)
if stats["overlapped"]["async_pairs"]:
    assert max(stats["overlapped"]["pair_gaps"]) > 0, stats["overlapped"]
assert (stats["overlapped"]["overlappable_compute"]
        > stats["fused"]["overlappable_compute"]), stats
print("HLO OVERLAP OK", stats["fused"]["overlappable_compute"],
      stats["overlapped"]["overlappable_compute"])
""", n_devices=4)
    assert "HLO OVERLAP OK" in out


# ----------------------------------------------------------------------
# Double-buffered exchange == serialized exchange, both transports
# ----------------------------------------------------------------------

def test_double_buffered_exchange_matches_serial():
    out = run_multidevice("""
import jax, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, streaming
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, Transport

mesh = jax.make_mesh((4,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
rounds = [comm.ring_perm(1), comm.reverse_ring_perm(1), comm.ring_perm(2)]
x = np.random.RandomState(0).randn(4, 3, 64).astype(np.float32)

for transport in (Transport.UNORDERED, Transport.ORDERED):
    cfg = CommConfig(transport=transport, window=2, chunk_bytes=512)

    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def serial(xs):
        outs = collectives.multi_neighbor_exchange(
            [xs[0, r] for r in range(3)], rounds, comm, cfg)
        return jax.numpy.stack(outs)[None]

    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def double_buffered(xs):
        _, outs = streaming.double_buffered_exchange(
            [xs[0, r] for r in range(3)], rounds, "x", cfg)
        return jax.numpy.stack(outs)[None]

    a, b = np.asarray(serial(x)), np.asarray(double_buffered(x))
    assert np.array_equal(a, b), transport
print("DOUBLE BUFFER OK")
""", n_devices=4)
    assert "DOUBLE BUFFER OK" in out


# ----------------------------------------------------------------------
# Tuner integration: the sweep space enumerates OVERLAPPED and "auto"
# can select it for the halo exchange
# ----------------------------------------------------------------------

def test_space_enumerates_overlapped_for_halo_only():
    from repro.core.config import Scheduling
    from repro.tune.space import enumerate_configs
    halo = enumerate_configs("multi_neighbor")
    assert any(c.scheduling == Scheduling.OVERLAPPED for c in halo)
    # every other collective executes overlapped == fused: collapsed away
    for coll in ("sendrecv", "all_reduce", "all_gather", "reduce_scatter"):
        assert not any(c.scheduling == Scheduling.OVERLAPPED
                       for c in enumerate_configs(coll)), coll


def test_auto_selects_overlapped_when_fastest(tmp_path):
    out = run_multidevice(f"""
import jax
from repro.core.config import CommConfig, Scheduling
from repro.swe import driver
from repro.tune.db import TuneDB, TuneEntry, topology_key
from repro.tune.space import config_to_dict

topo = topology_key(n_devices=4)
db = TuneDB()
db.add(TuneEntry(topo=topo, collective="multi_neighbor", msg_bytes=1024,
                 config=config_to_dict(CommConfig()), us_per_call=100.0))
db.add(TuneEntry(topo=topo, collective="multi_neighbor", msg_bytes=1024,
                 config=config_to_dict(
                     CommConfig(scheduling=Scheduling.OVERLAPPED)),
                 us_per_call=10.0))
path = db.save(r"{tmp_path / 'tunedb.json'}")

mesh = jax.make_mesh((4,), ("data",))
sim = driver.build_simulation(400, mesh, "auto", tune_db_path=path)
assert sim.comm_cfg.scheduling == Scheduling.OVERLAPPED, sim.comm_cfg
s = driver.make_sim_runner(sim, 3)(sim.state, 0.0)
jax.block_until_ready(s)
print("AUTO OVERLAPPED OK")
""", n_devices=4)
    assert "AUTO OVERLAPPED OK" in out


# ----------------------------------------------------------------------
# Hypothesis properties: streaming engine chunking round-trips
# ----------------------------------------------------------------------

def test_split_chunks_roundtrip_property():
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    import jax.numpy as jnp
    from repro.core import streaming

    dtypes = (jnp.float32, jnp.float16, jnp.int32, jnp.bfloat16)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 7), min_size=1, max_size=4),
           st.integers(0, len(dtypes) - 1),
           st.integers(1, 12))
    def check(shape, dtype_i, n):
        dtype = dtypes[dtype_i]
        size = int(np.prod(shape))
        rng = np.random.RandomState(size * 31 + n)
        x = jnp.asarray(rng.randn(*shape) * 100).astype(dtype)
        chunks, unsplit = streaming.split_chunks(x, n)
        assert chunks.shape[0] == n
        assert chunks.size >= x.size          # zero-padded, never truncated
        back = unsplit(chunks)
        assert back.shape == x.shape and back.dtype == x.dtype
        assert np.array_equal(np.asarray(back), np.asarray(x))

    check()


def test_num_chunks_bounds_property():
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from repro.core.config import CommConfig
    from repro.core.streaming import num_chunks

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10 << 20), st.integers(512, 1 << 20),
           st.integers(1, 64))
    def check(nbytes, chunk_bytes, max_chunks):
        cfg = CommConfig(chunk_bytes=chunk_bytes, max_chunks=max_chunks)
        n = num_chunks(nbytes, cfg)
        assert 1 <= n <= max_chunks
        if n < max_chunks:                   # uncapped: chunks cover the data
            assert n * chunk_bytes >= nbytes

    check()


def test_chunked_permute_roundtrip_property():
    """Identity-perm chunked_permute is a bitwise round-trip for any shape,
    dtype, chunk size, transport, and window (the wire format must never
    lose or reorder data)."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from functools import partial
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import streaming
    from repro.core.config import CommConfig, Transport

    mesh = jax.make_mesh((1,), ("x",))
    dtypes = (jnp.float32, jnp.float16)

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(1, 9), min_size=1, max_size=3),
           st.integers(0, len(dtypes) - 1),
           st.sampled_from((512, 1024, 4096)),
           st.sampled_from((Transport.ORDERED, Transport.UNORDERED)),
           st.integers(1, 4))
    def check(shape, dtype_i, chunk_bytes, transport, window):
        cfg = CommConfig(chunk_bytes=chunk_bytes, transport=transport,
                         window=window)
        rng = np.random.RandomState(int(np.prod(shape)) + window)
        x = jnp.asarray(rng.randn(*shape)).astype(dtypes[dtype_i])

        @partial(compat.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_vma=False)
        def f(v):
            return streaming.chunked_permute(v, [(0, 0)], "x", cfg)

        out = f(x)
        assert out.shape == x.shape and out.dtype == x.dtype
        assert np.array_equal(np.asarray(out), np.asarray(x))

    check()
