"""Overlapped double-buffered halo exchange: the correctness harness.

Every comm path the SWE step can take — scheduling (host / fused /
overlapped) x transport (ordered / unordered) x partition count (1 / 2 / 4)
— must produce BITWISE-identical simulation state: the schedules differ only
in dependency structure, never in arithmetic.  Plus an HLO-level check that
the overlapped step really decouples interior compute from the permutes, and
hypothesis properties for the streaming engine's chunking round-trips.
"""
import numpy as np
import pytest

from helpers import require_hypothesis, run_multidevice


# ----------------------------------------------------------------------
# Parity matrix: scheduling x transport x n_parts, 20 steps, bitwise
# ----------------------------------------------------------------------

def test_parity_matrix_bitwise():
    out = run_multidevice("""
import itertools, jax, numpy as np
from repro.core.config import CommConfig, Scheduling, Transport
from repro.swe import driver
from repro.swe.partition import _rcb

N_STEPS = 20
ELEMENTS = 400

def flatten(sim, s):
    part = _rcb(sim.mesh.centroids, sim.pm.n_parts)
    counts = np.zeros(sim.pm.n_parts, int)
    vals = np.zeros((sim.mesh.n_elements, 3))
    for e in range(sim.mesh.n_elements):
        p = part[e]
        vals[e] = s[p, counts[p]]
        counts[p] += 1
    return vals

mesh1 = jax.make_mesh((1,), ("data",))
ref_sim = driver.build_simulation(ELEMENTS, mesh1, CommConfig())
ref = flatten(ref_sim, np.asarray(
    driver.make_sim_runner(ref_sim, N_STEPS)(ref_sim.state, 0.0)))

checked = 0
for n_parts, sched, transport in itertools.product(
        (1, 2, 4),
        (Scheduling.HOST, Scheduling.FUSED, Scheduling.OVERLAPPED),
        (Transport.ORDERED, Transport.UNORDERED)):
    cfg = CommConfig(scheduling=sched, transport=transport,
                     window=2 if transport == Transport.ORDERED else 4)
    dmesh = jax.make_mesh((n_parts,), ("data",))
    sim = driver.build_simulation(ELEMENTS, dmesh, cfg)
    if sched == Scheduling.HOST:
        s, _ = driver.make_host_scheduled_runner(sim).run(
            sim.state, 0.0, N_STEPS)
    else:
        s = driver.make_sim_runner(sim, N_STEPS)(sim.state, 0.0)
    v = flatten(sim, np.asarray(s))
    assert np.array_equal(ref, v), (
        f"parity broke: parts={n_parts} sched={sched.value} "
        f"transport={transport.value} maxdiff={np.abs(ref - v).max()}")
    checked += 1
assert checked == 18
print("PARITY MATRIX OK", checked)
""", n_devices=4)
    assert "PARITY MATRIX OK 18" in out


# ----------------------------------------------------------------------
# Interior/boundary partition invariants (what makes the scatter exact)
# ----------------------------------------------------------------------

def test_boundary_partition_invariants():
    from repro.swe.dg_solver import initial_state
    from repro.swe.mesh_gen import generate_bight_mesh
    from repro.swe.partition import partition_mesh

    mesh = generate_bight_mesh(800, seed=1)
    for n_parts in (1, 2, 4, 8):
        pm = partition_mesh(mesh, n_parts, initial_state(mesh))
        for p in range(pm.n_parts):
            nb = int(pm.n_boundary[p])
            k = int(pm.valid[p].sum())
            # boundary + interior(core) covers every real element exactly
            assert nb + int(pm.n_core[p]) == k
            real = pm.boundary_idx[p, :nb].tolist()
            assert len(set(real)) == nb                # no duplicates
            # boundary elements are exactly those with a remote edge
            remote = np.where((pm.edge_type[p] == 3).any(axis=1))[0]
            assert sorted(real) == remote.tolist()
            # padding repeats a real boundary row (0 when none exist), so
            # duplicate scatter writes carry identical values
            pad = pm.boundary_idx[p, nb:]
            assert (pad == (real[0] if nb else 0)).all()


# ----------------------------------------------------------------------
# HLO: the overlapped step decouples interior compute from the permutes
# ----------------------------------------------------------------------

def test_overlapped_step_hlo_decouples_compute():
    """The overlapped program must contain substantially more compute that is
    independent of the collective-permutes than the fused one (the property
    that lets a latency-hiding scheduler run it during the transfer).  On
    backends that split permutes into ``collective-permute-start``/``-done``
    pairs, additionally require compute scheduled inside a pair; this host's
    CPU backend emits synchronous permutes, so the dependency-class check is
    the load-bearing one."""
    out = run_multidevice("""
import jax
from repro.core.config import CommConfig, OVERLAPPED_CONFIG
from repro.swe import driver
from repro.launch.hlo_analysis import permute_overlap_stats

mesh = jax.make_mesh((4,), ("data",))
stats = {}
for label, cfg in (("fused", CommConfig()), ("overlapped", OVERLAPPED_CONFIG)):
    sim = driver.build_simulation(500, mesh, cfg)
    run = driver.make_sim_runner(sim, n_inner=1)
    txt = jax.jit(lambda s: run(s, 0.0)).lower(sim.state).compile().as_text()
    stats[label] = permute_overlap_stats(txt)

for label, st in stats.items():
    assert st["sync_permutes"] + st["async_pairs"] >= 1, (label, st)
if stats["overlapped"]["async_pairs"]:
    assert max(stats["overlapped"]["pair_gaps"]) > 0, stats["overlapped"]
assert (stats["overlapped"]["overlappable_compute"]
        > stats["fused"]["overlappable_compute"]), stats
print("HLO OVERLAP OK", stats["fused"]["overlappable_compute"],
      stats["overlapped"]["overlappable_compute"])
""", n_devices=4)
    assert "HLO OVERLAP OK" in out


# ----------------------------------------------------------------------
# Double-buffered exchange == serialized exchange, both transports
# ----------------------------------------------------------------------

def test_double_buffered_exchange_matches_serial():
    out = run_multidevice("""
import jax, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, streaming
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, Transport

mesh = jax.make_mesh((4,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
rounds = [comm.ring_perm(1), comm.reverse_ring_perm(1), comm.ring_perm(2)]
x = np.random.RandomState(0).randn(4, 3, 64).astype(np.float32)

for transport in (Transport.UNORDERED, Transport.ORDERED):
    cfg = CommConfig(transport=transport, window=2, chunk_bytes=512)

    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def serial(xs):
        outs = collectives.multi_neighbor_exchange(
            [xs[0, r] for r in range(3)], rounds, comm, cfg)
        return jax.numpy.stack(outs)[None]

    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def double_buffered(xs):
        _, outs = streaming.double_buffered_exchange(
            [xs[0, r] for r in range(3)], rounds, "x", cfg)
        return jax.numpy.stack(outs)[None]

    a, b = np.asarray(serial(x)), np.asarray(double_buffered(x))
    assert np.array_equal(a, b), transport
print("DOUBLE BUFFER OK")
""", n_devices=4)
    assert "DOUBLE BUFFER OK" in out


# ----------------------------------------------------------------------
# Tuner integration: the sweep space enumerates OVERLAPPED and "auto"
# can select it for the halo exchange
# ----------------------------------------------------------------------

def test_space_enumerates_overlapped_for_overlap_capable_only():
    from repro.core.config import CommMode, Scheduling
    from repro.tune.space import enumerate_configs
    halo = enumerate_configs("multi_neighbor")
    assert any(c.scheduling == Scheduling.OVERLAPPED for c in halo)
    # all_to_all gained chunked-overlap delivery (streaming only)
    a2a = enumerate_configs("all_to_all")
    ov = [c for c in a2a if c.scheduling == Scheduling.OVERLAPPED]
    assert ov and all(c.mode == CommMode.STREAMING for c in ov)
    # ...including both segment sizes (the axis the pruning model separates)
    assert len({c.chunk_bytes for c in ov}) > 1
    # every other collective executes overlapped == fused: collapsed away
    for coll in ("sendrecv", "all_reduce", "all_gather", "reduce_scatter",
                 "hierarchical_all_reduce"):
        assert not any(c.scheduling == Scheduling.OVERLAPPED
                       for c in enumerate_configs(coll)), coll
    # the hierarchical (cross-pod) all-reduce is a first-class sweep target
    assert enumerate_configs("hierarchical_all_reduce")


def test_auto_selects_overlapped_when_fastest(tmp_path):
    out = run_multidevice(f"""
import jax
from repro.core.config import CommConfig, Scheduling
from repro.swe import driver
from repro.tune.db import TuneDB, TuneEntry, topology_key
from repro.tune.space import config_to_dict

topo = topology_key(n_devices=4)
db = TuneDB()
db.add(TuneEntry(topo=topo, collective="multi_neighbor", msg_bytes=1024,
                 config=config_to_dict(CommConfig()), us_per_call=100.0))
db.add(TuneEntry(topo=topo, collective="multi_neighbor", msg_bytes=1024,
                 config=config_to_dict(
                     CommConfig(scheduling=Scheduling.OVERLAPPED)),
                 us_per_call=10.0))
path = db.save(r"{tmp_path / 'tunedb.json'}")

mesh = jax.make_mesh((4,), ("data",))
sim = driver.build_simulation(400, mesh, "auto", tune_db_path=path)
assert sim.comm_cfg.scheduling == Scheduling.OVERLAPPED, sim.comm_cfg
s = driver.make_sim_runner(sim, 3)(sim.state, 0.0)
jax.block_until_ready(s)
print("AUTO OVERLAPPED OK")
""", n_devices=4)
    assert "AUTO OVERLAPPED OK" in out


# ----------------------------------------------------------------------
# Chunk-level halo consume: the overlapped SWE step folds each
# recv_slot-aligned wire chunk as it lands — still bitwise-exact
# ----------------------------------------------------------------------

def test_chunk_level_halo_consume_parity_bitwise():
    out = run_multidevice("""
import jax, numpy as np
import jax.numpy as jnp
from repro.core import streaming
from repro.core.config import CommConfig, Scheduling, Transport
from repro.swe import driver
from repro.swe.partition import _rcb

N_STEPS = 5
ELEMENTS = 16000   # large enough halo that 512B chunks split every round

def flatten(sim, s):
    part = _rcb(sim.mesh.centroids, sim.pm.n_parts)
    counts = np.zeros(sim.pm.n_parts, int)
    vals = np.zeros((sim.mesh.n_elements, 3))
    for e in range(sim.mesh.n_elements):
        p = part[e]
        vals[e] = s[p, counts[p]]
        counts[p] += 1
    return vals

mesh1 = jax.make_mesh((1,), ("data",))
ref_sim = driver.build_simulation(ELEMENTS, mesh1, CommConfig())
ref = flatten(ref_sim, np.asarray(
    driver.make_sim_runner(ref_sim, N_STEPS)(ref_sim.state, 0.0)))

for transport in (Transport.ORDERED, Transport.UNORDERED):
    cfg = CommConfig(scheduling=Scheduling.OVERLAPPED, transport=transport,
                     window=2, chunk_bytes=512)
    dmesh = jax.make_mesh((4,), ("data",))
    sim = driver.build_simulation(ELEMENTS, dmesh, cfg)
    probe = jnp.zeros((sim.pm.s_max, 3), jnp.float32)
    n, L = streaming.aligned_chunks(probe, cfg, align=3)
    assert n > 1, (n, L, sim.pm.s_max)     # multi-chunk rounds exercised
    assert L % 3 == 0                      # recv_slot-aligned chunks
    s = driver.make_sim_runner(sim, N_STEPS)(sim.state, 0.0)
    v = flatten(sim, np.asarray(s))
    assert np.array_equal(ref, v), (transport, np.abs(ref - v).max())
print("CHUNK HALO PARITY OK")
""", n_devices=4)
    assert "CHUNK HALO PARITY OK" in out


# ----------------------------------------------------------------------
# LM overlap parity: TP reduce and MoE all_to_all bitwise vs fused
# across partition counts x transports
# ----------------------------------------------------------------------

def test_lm_tp_reduce_parity_bitwise():
    out = run_multidevice("""
import numpy as np, jax
import jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.config import CommConfig, CommMode, Scheduling, Transport
from repro.models import layers
from repro.models.common import MeshContext, ModelConfig, Runtime

cfg_model = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128)

def run_tp(tp, comm_cfg, x, w):
    mesh = jax.make_mesh((tp,), ("model",))
    rt = Runtime(cfg=cfg_model,
                 mesh=MeshContext(data_axes=(), model_size=tp, data_sizes=()),
                 comm=comm_cfg)
    @partial(compat.shard_map, mesh=mesh,
             in_specs=(P(None, "model"), P("model", None)), out_specs=P(),
             check_vma=False)
    def f(xs, ws):
        return layers.row_parallel(xs, ws, rt)
    return np.asarray(f(x, w))

rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(96, 64), jnp.float32)
w = jnp.asarray(rng.randn(64, 32), jnp.float32)

checked = 0
for tp in (2, 4):
    ref = run_tp(tp, CommConfig(mode=CommMode.BUFFERED,
                                scheduling=Scheduling.FUSED), x, w)
    for transport in (Transport.ORDERED, Transport.UNORDERED):
        for sched in (Scheduling.FUSED, Scheduling.OVERLAPPED):
            c = CommConfig(mode=CommMode.STREAMING, scheduling=sched,
                           transport=transport, window=2, chunk_bytes=512)
            out = run_tp(tp, c, x, w)
            assert np.array_equal(ref, out), (tp, sched, transport)
            checked += 1
assert checked == 8
print("TP REDUCE PARITY OK", checked)
""", n_devices=4)
    assert "TP REDUCE PARITY OK 8" in out


def test_moe_a2a_parity_bitwise():
    """Raw chunked all_to_all AND the full a2a MoE block are bitwise equal
    to the fused path across partition counts and both transports."""
    out = run_multidevice("""
import numpy as np, jax
import jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, CommMode, Scheduling, Transport
from repro.models import moe
from repro.models.common import MeshContext, ModelConfig, Runtime

rng = np.random.RandomState(1)
checked = 0
for dp in (2, 4):
    mesh = jax.make_mesh((dp,), ("data",))
    comm = Communicator.from_mesh(mesh, "data")
    x = jnp.asarray(rng.randn(dp * dp, 8, 24), jnp.float32)

    def run_a2a(c):
        @partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"), check_vma=False)
        def f(v):
            return collectives.all_to_all(v, comm, c, split_axis=0,
                                          concat_axis=0)
        return np.asarray(f(x))

    ref = run_a2a(CommConfig(mode=CommMode.BUFFERED,
                             scheduling=Scheduling.FUSED))
    for transport in (Transport.ORDERED, Transport.UNORDERED):
        c = CommConfig(mode=CommMode.STREAMING,
                       scheduling=Scheduling.OVERLAPPED,
                       transport=transport, window=2, chunk_bytes=512)
        assert np.array_equal(ref, run_a2a(c)), (dp, transport)
        checked += 1

# Full MoE block with a2a dispatch+combine (EP over the data axis)
cfg_model = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128,
                        n_experts=4, n_experts_per_tok=2)
params = moe.init_moe(jax.random.PRNGKey(0), cfg_model, jnp.float32, tp=1)
params = jax.tree.map(lambda a: a, params)
xs = jnp.asarray(rng.randn(4 * 16, 32), jnp.float32)

for dp in (2, 4):
    mesh = jax.make_mesh((dp,), ("data",))
    def run_block(c):
        rt = Runtime(cfg=cfg_model,
                     mesh=MeshContext(data_axes=("data",), model_size=1,
                                      data_sizes=(dp,)),
                     comm=c)
        @partial(compat.shard_map, mesh=mesh,
                 in_specs=(P("data"), P()), out_specs=(P("data"), P()),
                 check_vma=False)
        def f(v, p):
            y, aux = moe.moe_block_a2a(p, v, rt)
            return y, aux
        return f(xs, params)
    ref_y, ref_aux = run_block(CommConfig(mode=CommMode.BUFFERED,
                                          scheduling=Scheduling.FUSED))
    for transport in (Transport.ORDERED, Transport.UNORDERED):
        c = CommConfig(mode=CommMode.STREAMING,
                       scheduling=Scheduling.OVERLAPPED,
                       transport=transport, window=2, chunk_bytes=512)
        y, aux = run_block(c)
        assert np.array_equal(np.asarray(ref_y), np.asarray(y)), (dp, transport)
        assert np.array_equal(np.asarray(ref_aux), np.asarray(aux))
        checked += 1
assert checked == 8
print("MOE A2A PARITY OK", checked)
""", n_devices=4)
    assert "MOE A2A PARITY OK 8" in out


# ----------------------------------------------------------------------
# HLO: the overlapped LM paths decouple their collectives (chunked combines
# are mutually independent; the fused paths have a single dependent chain)
# ----------------------------------------------------------------------

def test_lm_overlap_hlo_decouples_collectives():
    out = run_multidevice("""
import numpy as np, jax
import jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, CommMode, Scheduling
from repro.launch.hlo_analysis import permute_overlap_stats
from repro.models import layers
from repro.models.common import MeshContext, ModelConfig, Runtime

cfg_model = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128)
mesh = jax.make_mesh((4,), ("model",))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(128, 64), jnp.float32)
w = jnp.asarray(rng.randn(64, 32), jnp.float32)

def lower_tp(comm_cfg):
    rt = Runtime(cfg=cfg_model,
                 mesh=MeshContext(data_axes=(), model_size=4, data_sizes=()),
                 comm=comm_cfg)
    @partial(compat.shard_map, mesh=mesh,
             in_specs=(P(None, "model"), P("model", None)), out_specs=P(),
             check_vma=False)
    def f(xs, ws):
        return layers.row_parallel(xs, ws, rt)
    return jax.jit(f).lower(x, w).compile().as_text()

fused = permute_overlap_stats(lower_tp(CommConfig(mode=CommMode.BUFFERED)),
                              ops=("all-reduce",))
ov = permute_overlap_stats(
    lower_tp(CommConfig(mode=CommMode.STREAMING,
                        scheduling=Scheduling.OVERLAPPED, chunk_bytes=512)),
    ops=("all-reduce",))
assert fused["n_collectives"] == 1 and fused["independent_pairs"] == 0, fused
assert ov["n_collectives"] > 1 and ov["independent_pairs"] > 0, ov

# MoE all_to_all: one fused op vs n mutually independent chunk exchanges
dmesh = jax.make_mesh((4,), ("data",))
comm = Communicator.from_mesh(dmesh, "data")
xx = jnp.asarray(rng.randn(16, 8, 24), jnp.float32)

def lower_a2a(c):
    @partial(compat.shard_map, mesh=dmesh, in_specs=P("data"),
             out_specs=P("data"), check_vma=False)
    def f(v):
        return collectives.all_to_all(v, comm, c)
    return jax.jit(f).lower(xx).compile().as_text()

fused_a = permute_overlap_stats(lower_a2a(CommConfig(mode=CommMode.BUFFERED)),
                                ops=("all-to-all",))
ov_a = permute_overlap_stats(
    lower_a2a(CommConfig(mode=CommMode.STREAMING,
                         scheduling=Scheduling.OVERLAPPED, chunk_bytes=512)),
    ops=("all-to-all",))
assert fused_a["independent_pairs"] == 0, fused_a
assert ov_a["n_collectives"] > 1 and ov_a["independent_pairs"] > 0, ov_a
print("LM HLO DECOUPLING OK", ov["independent_pairs"], ov_a["independent_pairs"])
""", n_devices=4)
    assert "LM HLO DECOUPLING OK" in out


# ----------------------------------------------------------------------
# Chunk-level consume edge cases (sizes not divisible by the chunking,
# n_chunks=1 degradation, INT8 wire format at chunk boundaries)
# ----------------------------------------------------------------------

def test_pipelined_consume_alignment_property():
    """Chunk boundaries are align-multiples, consume sees exactly the
    reassembled message, and any size (divisible or not) round-trips
    bitwise."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from functools import partial
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import streaming
    from repro.core.config import CommConfig, Transport

    mesh = jax.make_mesh((1,), ("x",))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 7),
           st.sampled_from((512, 1024)),
           st.sampled_from((Transport.ORDERED, Transport.UNORDERED)),
           st.integers(1, 3))
    def check(rows, align, chunk_bytes, transport, window):
        cfg = CommConfig(chunk_bytes=chunk_bytes, transport=transport,
                         window=window)
        rng = np.random.RandomState(rows * 13 + align)
        x = jnp.asarray(rng.randn(rows, align), jnp.float32)
        n, L = streaming.aligned_chunks(x, cfg, align=align)
        assert L % align == 0                 # never splits a logical row
        assert n * L >= x.size and (n - 1) * L < x.size

        order = []

        @partial(compat.shard_map, mesh=mesh, in_specs=P(),
                 out_specs=(P(), P()), check_vma=False)
        def f(v):
            def consume(chunks, i, chunk):
                order.append(i)
                return chunks + [chunk]
            folded, msg = streaming.pipelined_consume(
                v, [(0, 0)], "x", cfg, consume, [], align=align)
            return jnp.stack(folded), msg

        folded, msg = f(x)
        assert np.array_equal(np.asarray(msg), np.asarray(x))
        assert folded.shape == (n, L)
        assert order == list(range(n))
        # the folded chunks ARE the message: concatenation reassembles it
        flat = np.asarray(folded).reshape(-1)[: x.size]
        assert np.array_equal(flat, np.asarray(x).reshape(-1))

    check()


def test_pipelined_consume_single_chunk_degradation():
    """A message smaller than chunk_bytes degrades to exactly one consume
    call (the n_chunks=1 buffered-equivalent pattern)."""
    from functools import partial
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import streaming
    from repro.core.config import CommConfig

    mesh = jax.make_mesh((1,), ("x",))
    cfg = CommConfig(chunk_bytes=1 << 20)
    x = jnp.arange(300, dtype=jnp.float32).reshape(100, 3)
    calls = []

    @partial(compat.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
             check_vma=False)
    def f(v):
        _, msg = streaming.pipelined_consume(
            v, [(0, 0)], "x", cfg,
            lambda c, i, ch: calls.append(i) or c, None, align=3)
        return msg

    msg = f(x)
    assert calls == [0]
    assert np.array_equal(np.asarray(msg), np.asarray(x))


def test_int8_chunk_boundary_roundtrip_property():
    """INT8 wire compression quantizes each wire chunk independently; the
    reassembled message must equal the per-chunk quantize->dequantize
    reference bitwise for any (size, chunk size) — chunk boundaries must
    never leak across quantization blocks."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from functools import partial
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import plugins, streaming
    from repro.core.config import CommConfig, Compression

    mesh = jax.make_mesh((1,), ("x",))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(10, 400), st.sampled_from((512, 1024)),
           st.sampled_from((16, 64)))
    def check(elems, chunk_bytes, block):
        cfg = CommConfig(chunk_bytes=chunk_bytes, algorithm="ring",
                         compression=Compression.INT8, quant_block=block)
        rng = np.random.RandomState(elems + block)
        x = jnp.asarray(rng.randn(elems) * 10, jnp.float32)

        @partial(compat.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_vma=False)
        def f(v):
            _, msg = streaming.pipelined_consume(
                v, [(0, 0)], "x", cfg, lambda c, i, ch: c, None)
            return msg

        out = np.asarray(f(x))
        # reference: identical chunk geometry, per-chunk quant round-trip
        n, L = streaming.aligned_chunks(x, cfg)
        flat = np.zeros(n * L, np.float32)
        flat[:elems] = np.asarray(x)
        ref_parts = []
        for i in range(n):
            chunk = jnp.asarray(flat[i * L:(i + 1) * L])
            q, s = plugins.quantize_int8(chunk, block)
            ref_parts.append(np.asarray(
                plugins.dequantize_int8(q, s, (L,), jnp.float32)))
        ref = np.concatenate(ref_parts)[:elems]
        assert np.array_equal(out, ref)

    check()


# ----------------------------------------------------------------------
# Hypothesis properties: streaming engine chunking round-trips
# ----------------------------------------------------------------------

def test_split_chunks_roundtrip_property():
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    import jax.numpy as jnp
    from repro.core import streaming

    dtypes = (jnp.float32, jnp.float16, jnp.int32, jnp.bfloat16)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 7), min_size=1, max_size=4),
           st.integers(0, len(dtypes) - 1),
           st.integers(1, 12))
    def check(shape, dtype_i, n):
        dtype = dtypes[dtype_i]
        size = int(np.prod(shape))
        rng = np.random.RandomState(size * 31 + n)
        x = jnp.asarray(rng.randn(*shape) * 100).astype(dtype)
        chunks, unsplit = streaming.split_chunks(x, n)
        assert chunks.shape[0] == n
        assert chunks.size >= x.size          # zero-padded, never truncated
        back = unsplit(chunks)
        assert back.shape == x.shape and back.dtype == x.dtype
        assert np.array_equal(np.asarray(back), np.asarray(x))

    check()


def test_num_chunks_bounds_property():
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from repro.core.config import CommConfig
    from repro.core.streaming import num_chunks

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10 << 20), st.integers(512, 1 << 20),
           st.integers(1, 64))
    def check(nbytes, chunk_bytes, max_chunks):
        cfg = CommConfig(chunk_bytes=chunk_bytes, max_chunks=max_chunks)
        n = num_chunks(nbytes, cfg)
        assert 1 <= n <= max_chunks
        if n < max_chunks:                   # uncapped: chunks cover the data
            assert n * chunk_bytes >= nbytes

    check()


def test_chunked_permute_roundtrip_property():
    """Identity-perm chunked_permute is a bitwise round-trip for any shape,
    dtype, chunk size, transport, and window (the wire format must never
    lose or reorder data)."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from functools import partial
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import streaming
    from repro.core.config import CommConfig, Transport

    mesh = jax.make_mesh((1,), ("x",))
    dtypes = (jnp.float32, jnp.float16)

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(1, 9), min_size=1, max_size=3),
           st.integers(0, len(dtypes) - 1),
           st.sampled_from((512, 1024, 4096)),
           st.sampled_from((Transport.ORDERED, Transport.UNORDERED)),
           st.integers(1, 4))
    def check(shape, dtype_i, chunk_bytes, transport, window):
        cfg = CommConfig(chunk_bytes=chunk_bytes, transport=transport,
                         window=window)
        rng = np.random.RandomState(int(np.prod(shape)) + window)
        x = jnp.asarray(rng.randn(*shape)).astype(dtypes[dtype_i])

        @partial(compat.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_vma=False)
        def f(v):
            return streaming.chunked_permute(v, [(0, 0)], "x", cfg)

        out = f(x)
        assert out.shape == x.shape and out.dtype == x.dtype
        assert np.array_equal(np.asarray(out), np.asarray(x))

    check()
