"""Observability substrate: span tracer, metrics registry, Chrome export,
report CLI, watchdog telemetry — and the zero-overhead guarantee (tracing
off must leave the instrumented collectives bitwise-identical)."""
import json
import os

import pytest

from helpers import run_multidevice

from repro.obs import metrics, trace
from repro.obs import report as obs_report


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Each test starts with tracing off and ends restoring the env gate."""
    trace.configure("0")
    yield
    trace.configure("0")


# ----------------------------------------------------------------------
# trace: disabled path
# ----------------------------------------------------------------------

def test_disabled_span_is_null_singleton():
    assert not trace.enabled()
    s1 = trace.span("a", cat="collective", hops=3)
    s2 = trace.span("b", cat="wire")
    assert s1 is s2 is trace._NULL_SPAN   # no per-call allocation
    with s1 as s:
        s.set(result=1)                   # all no-ops
    trace.instant("x", cat="watchdog")
    assert trace.events() == []
    assert trace.flush() is None
    assert trace.mode() is None


def test_configure_modes(tmp_path):
    assert trace.configure("") is None
    assert trace.configure("0") is None
    t = trace.configure("1")
    assert t is not None and trace.enabled() and trace.mode() == "1"
    path = str(tmp_path / "t.json")
    t = trace.configure(f"chrome:{path}")
    assert t.sink == path and trace.mode() == f"chrome:{path}"
    with pytest.raises(ValueError):
        trace.configure("bogus")


# ----------------------------------------------------------------------
# trace: enabled path
# ----------------------------------------------------------------------

def test_span_nesting_and_args():
    trace.configure("1")
    with trace.span("outer", cat="collective", hops=2):
        with trace.span("inner", cat="wire", chunk=0) as sp:
            sp.set(us_per_call=42.0)
    evs = trace.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert inner["ph"] == outer["ph"] == "X"
    assert inner["args"] == {"chunk": 0, "us_per_call": 42.0}
    assert outer["args"] == {"hops": 2}
    # time containment on the same track = nesting in Perfetto
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert inner["pid"] == outer["pid"]
    assert inner["tid"] == outer["tid"]


def test_instant_and_rank_tracks():
    trace.configure("1")
    trace.instant("watchdog.straggler", cat="watchdog", step=7)
    with trace.span("s", cat="collective", rank=2):
        pass
    evs = trace.events()
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["args"]["step"] == 7
    span_ev = next(e for e in evs if e["ph"] == "X")
    assert span_ev["pid"] == 3        # rank 2 -> pid 3 (pid 0 = host)


def test_ring_buffer_drops_oldest():
    trace._TRACER = trace.Tracer(capacity=8)
    for i in range(20):
        trace.instant(f"e{i}", cat="x")
    assert len(trace.events()) == 8
    assert trace.tracer().dropped == 12
    assert trace.events()[0]["name"] == "e12"
    # the export reports the drop count
    assert (trace.tracer().to_chrome()["otherData"]["dropped_events"]
            == 12)


def test_traced_decorator_checks_enablement_per_call():
    calls = []

    @trace.traced("work", cat="sweep")
    def work():
        calls.append(1)
        return 5

    assert work() == 5 and trace.events() == []   # disabled: plain call
    trace.configure("1")
    assert work() == 5
    assert [e["name"] for e in trace.events()] == ["work"]


def test_chrome_export_schema(tmp_path):
    path = str(tmp_path / "trace.json")
    trace.configure(f"chrome:{path}")
    with trace.span("sendrecv", cat="collective", hops=2, nbytes=1024):
        with trace.span("wire.chunk", cat="wire", chunk=0, of=2):
            pass
    trace.instant("watchdog.step", cat="watchdog", step=0, rank=1)
    out = trace.flush()
    assert out == path
    with open(path) as f:
        payload = json.load(f)
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in x} == {"sendrecv", "wire.chunk"}
    assert all(isinstance(e["dur"], (int, float)) and e["dur"] >= 0
               for e in x)
    assert all(isinstance(e["ts"], (int, float)) for e in x)
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "host" for e in meta)
    assert payload.get("otherData", {}).get("dropped_events", 0) == 0


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------

def test_counter_gauge_and_labels():
    reg = metrics.Registry()
    c = reg.counter("comm.bytes")
    c.inc()
    c.inc(9)
    assert c.value == 10
    assert reg.counter("comm.bytes") is c          # get-or-create
    c2 = reg.counter("comm.edge_bytes", hops=2)
    c3 = reg.counter("comm.edge_bytes", hops=3)
    assert c2 is not c3
    c2.inc(5)
    assert reg.snapshot()["comm.edge_bytes{hops=2}"] == 5
    g = reg.gauge("queue.depth")
    g.set(7)
    assert g.value == 7
    with pytest.raises(TypeError):
        reg.gauge("comm.bytes")                    # type mismatch on a name
    reg.reset()
    assert c.value == 0 and g.value == 0


def test_histogram_percentiles():
    reg = metrics.Registry()
    h = reg.histogram("lat.us")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    # fixed 1-2-5 buckets: percentiles are interpolated, so allow slack
    assert 30 <= s["p50"] <= 70
    assert s["p95"] >= s["p50"] and s["p99"] >= s["p95"]
    assert s["p99"] <= 100.0 * 1.01
    assert s["mean"] == pytest.approx(50.5)
    h.reset()
    assert h.summary()["count"] == 0


def test_find_prefix():
    reg = metrics.Registry()
    reg.counter("sweep.pruned").inc(3)
    reg.histogram("sweep.us", collective="all_reduce").observe(7.0)
    reg.counter("plans.plan_hits").inc()
    found = reg.find("sweep.")
    assert set(found) == {"sweep.pruned", "sweep.us{collective=all_reduce}"}


def test_plans_cache_stats_shim():
    """plans.cache_stats() keeps its dict shape but is backed by the metrics
    registry — the same counters the sweep and report read."""
    from repro.core import plans
    from repro.core.config import CommConfig
    plans.reset_stats()
    base = metrics.registry().counter("plans.plan_misses").value
    plans.chunk_plan((64, 3), "float32", CommConfig())
    st = plans.cache_stats()
    assert set(st) >= {"plan_hits", "plan_misses", "program_hits",
                       "program_misses", "size"}
    assert all(isinstance(v, int) for v in st.values())
    assert metrics.registry().counter("plans.plan_misses").value > base


# ----------------------------------------------------------------------
# watchdog telemetry + bounded retention
# ----------------------------------------------------------------------

def test_watchdog_event_cap_and_dropped_counter():
    from repro.runtime.fault_tolerance import StepWatchdog
    metrics.registry().counter("watchdog.events_dropped").reset()
    wd = StepWatchdog(k=0.0, warmup=1, window=4, max_events=3)
    # k=0: every step beyond the first warmup is a "straggler"
    import time as _t
    for i in range(10):
        wd.start_step(i)
        _t.sleep(0.001 * (1 + i % 3))
        wd.end_step()
    assert len(wd.events) <= 3
    assert wd.events_dropped > 0
    assert (metrics.registry().counter("watchdog.events_dropped").value
            == wd.events_dropped)
    # durations memory is bounded too
    assert wd.durations.maxlen is not None


def test_watchdog_double_end_step_is_noop():
    """Regression: end_step must consume the start mark — a second call at
    the same boundary used to append the duration twice (skewing the median)
    and could emit a phantom straggler."""
    from repro.runtime.fault_tolerance import StepWatchdog
    wd = StepWatchdog(k=0.0, warmup=1, window=4)
    wd.start_step(0)
    wd.end_step()
    assert len(wd.durations) == 1
    assert wd.end_step() is None            # no start mark -> no-op
    assert len(wd.durations) == 1
    assert len(wd.events) == 0
    # the next real step still measures normally
    wd.start_step(1)
    wd.end_step()
    assert len(wd.durations) == 2


def test_watchdog_emits_trace_instants():
    from repro.runtime.fault_tolerance import StepWatchdog
    trace.configure("1")
    wd = StepWatchdog(warmup=100)          # no stragglers, just step marks
    for i in range(3):
        wd.start_step(i)
        wd.end_step()
    steps = [e for e in trace.events() if e["name"] == "watchdog.step"]
    assert len(steps) == 3
    assert all(e["cat"] == "watchdog" and e["ph"] == "i" for e in steps)


# ----------------------------------------------------------------------
# report CLI
# ----------------------------------------------------------------------

def _make_trace(tmp_path):
    path = str(tmp_path / "trace.json")
    trace.configure(f"chrome:{path}")
    for hops in (1, 1, 2):
        with trace.span("sendrecv", cat="collective", hops=hops, nbytes=64):
            with trace.span("wire.chunk", cat="wire", chunk=0, of=1):
                pass
    with trace.span("swe.segment", cat="driver", steps=20):
        pass
    trace.instant("watchdog.step", cat="watchdog", step=0)
    trace.flush()
    return path


def test_report_cli_tables(tmp_path, capsys):
    path = _make_trace(tmp_path)
    assert obs_report.main([path]) == 0
    out = capsys.readouterr().out
    assert "sendrecv@h1" in out and "sendrecv@h2" in out
    assert "wire" in out and "watchdog.step" in out
    assert "collective" in out
    # per-edge rows carry the torus hop distances
    agg = obs_report.summarize(obs_report.load_trace(path))
    assert agg["per_edge"]["sendrecv@h1"]["count"] == 2
    assert agg["per_edge"]["sendrecv@h2"]["hops"] == 2


def test_report_cli_json_and_errors(tmp_path, capsys):
    path = _make_trace(tmp_path)
    assert obs_report.main([path, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert "per_edge" in payload and "instants" in payload
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert obs_report.main([str(bad)]) == 2
    assert obs_report.main([str(tmp_path / "missing.json")]) == 2


# ----------------------------------------------------------------------
# zero-overhead + parity (subprocess: multi-device, env-gated)
# ----------------------------------------------------------------------

_EXCHANGE_CODE = """
import os
os.environ["REPRO_TRACE"] = {trace_mode!r}
import jax, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import CommConfig, CommMode, Transport, Communicator, collectives
from repro.obs import trace

mesh = jax.make_mesh((2,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.random.RandomState(7).randn(2, 384).astype(np.float32)
cfg = CommConfig(mode=CommMode.STREAMING, transport=Transport.ORDERED,
                 chunk_bytes=512, window=1)

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
def g(xs):
    return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]

out = np.asarray(g(x))
assert np.array_equal(out, np.roll(x, 1, axis=0))
print("digest", out.tobytes().hex()[:64])
print("n_events", len(trace.events()))
print("enabled", trace.enabled())
"""


def test_tracing_off_is_zero_cost_and_bitwise_identical():
    """REPRO_TRACE=0 leaves the instrumented exchange bitwise-identical to
    the traced run AND records nothing (the zero-overhead guarantee)."""
    off = run_multidevice(_EXCHANGE_CODE.format(trace_mode="0"), n_devices=2)
    on = run_multidevice(_EXCHANGE_CODE.format(trace_mode="1"), n_devices=2)

    def field(out, key):
        return next(l for l in out.splitlines()
                    if l.startswith(key)).split(" ", 1)[1]

    assert field(off, "digest") == field(on, "digest")   # bitwise parity
    assert field(off, "n_events") == "0"
    assert field(off, "enabled") == "False"
    assert int(field(on, "n_events")) > 0
    assert field(on, "enabled") == "True"


def test_two_rank_exchange_exports_nested_chrome_trace(tmp_path):
    """A 2-rank torus exchange with REPRO_TRACE=chrome:<path> leaves a
    well-formed nested trace: collective spans containing wire chunks."""
    path = str(tmp_path / "trace.json")
    run_multidevice("""
import os
os.environ["REPRO_TRACE"] = "chrome:" + {path!r}
import jax, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import CommConfig, CommMode, Communicator, collectives
from repro.obs import trace

mesh = jax.make_mesh((2,), ("x",))
comm = Communicator.from_mesh(mesh, "x")
x = np.arange(2 * 256, dtype=np.float32).reshape(2, 256)
cfg = CommConfig(mode=CommMode.STREAMING, chunk_bytes=512)

@partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
def g(xs):
    return collectives.sendrecv(xs[0], comm.ring_perm(), comm, cfg)[None]

np.asarray(g(x))
trace.flush()
print("OK")
""".format(path=path), n_devices=2)
    evs = obs_report.load_trace(path)
    colls = [e for e in evs if e.get("cat") == "collective"]
    wires = [e for e in evs if e.get("cat") == "wire"]
    assert colls and wires
    outer = next(e for e in colls if e["name"] == "sendrecv")
    # wire chunks nest inside the collective span (time containment,
    # same track)
    inner = [e for e in wires
             if e["pid"] == outer["pid"] and e["tid"] == outer["tid"]
             and outer["ts"] <= e["ts"]
             and e["ts"] + e["dur"] <= outer["ts"] + outer["dur"] + 1e-3]
    assert len(inner) >= 2            # multiple chunks per message
    assert all(e["args"]["of"] >= 2 for e in inner)
    assert outer["args"]["hops"] == 1 and outer["args"]["nbytes"] == 1024
