"""Data pipeline, checkpointing, fault tolerance, elastic re-meshing."""
import os
import signal
import time

import numpy as np
import pytest

from helpers import run_multidevice


def test_data_pipeline_deterministic_and_shardable():
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    src = SyntheticLM(cfg)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])          # deterministic
    # host-sharded slices reassemble the global batch
    halves = [src.batch_at(5, host_id=h, n_hosts=2) for h in (0, 1)]
    glob = np.concatenate([h["tokens"] for h in halves])
    assert np.array_equal(glob, b1["tokens"])
    # labels = next-token of tokens
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_pipeline_prefetch():
    from repro.data.pipeline import DataConfig, PrefetchLoader, SyntheticLM
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    loader = PrefetchLoader(SyntheticLM(cfg), start_step=3)
    b = next(loader)
    assert b["_step"] == 3
    b = next(loader)
    assert b["_step"] == 4
    loader.close()


def test_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint.checkpointer import Checkpointer
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck = Checkpointer(tmp_path)
    ck.save(7, tree)
    assert ck.latest_step() == 7
    out = ck.restore(7, tree)
    for x, y in zip(np.asarray(out["a"]), np.asarray(tree["a"])):
        assert x == y
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_async_checkpoint_and_emergency(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint.checkpointer import (AsyncCheckpointer,
                                               emergency_save)
    tree = {"w": jnp.full((256,), 3.0)}
    ck = AsyncCheckpointer(tmp_path)
    ck.save(1, tree)
    ck.wait()
    assert ck.latest_step() == 1
    emergency_save(tmp_path, 2, tree)
    assert ck.latest_step() == 2


def test_watchdog_detects_straggler():
    from repro.runtime.fault_tolerance import StepWatchdog
    wd = StepWatchdog(k=5.0, warmup=5)
    for i in range(10):
        wd.start_step(i)
        time.sleep(0.002)
        wd.end_step()           # noisy-host jitter may flag some — ignored
    wd.start_step(10)
    time.sleep(0.08)            # 40x median
    ev = wd.end_step()
    assert ev is not None and ev.step == 10
    assert wd.median_step < 0.02


def test_preemption_guard_drains_training(tmp_path):
    """Software-triggered preemption: the loop checkpoints and stops early."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_smoke_config
    from repro.core.config import CommConfig
    from repro.data.pipeline import DataConfig
    from repro.launch import setup
    from repro.optim import adamw
    from repro.train import loop as loop_mod
    from repro.runtime import fault_tolerance as ft

    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sess = setup.build_session(cfg, mesh, CommConfig(),
                               oc=adamw.OptConfig(lr=1e-3, zero1=False))
    # patch: trigger preemption after 3 steps via the guard's request()
    orig_enter = ft.PreemptionGuard.__enter__
    state = {"n": 0}

    class Probe(ft.PreemptionGuard):
        @property
        def preempted(self):
            state["n"] += 1
            return state["n"] > 3

    real = ft.PreemptionGuard
    loop_mod.PreemptionGuard = Probe
    try:
        hist = loop_mod.train(
            sess, DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             global_batch=4),
            loop_mod.LoopConfig(n_steps=50, ckpt_every=100,
                                ckpt_dir=str(tmp_path), log_every=100,),
            log=lambda *_: None)
    finally:
        loop_mod.PreemptionGuard = real
    assert len(hist) <= 5            # drained early, not 50 steps
    from repro.checkpoint.checkpointer import Checkpointer
    assert Checkpointer(tmp_path).latest_step() is not None   # emergency save


def test_elastic_restore_reshards():
    """Train on a 2x4 mesh, checkpoint, lose half the machine, resume on 2x2;
    losses keep decreasing and params carry over exactly."""
    out = run_multidevice("""
import dataclasses, tempfile
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.launch import setup
from repro.optim import adamw
from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import elastic_restore

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
comm = CommConfig()
oc = adamw.OptConfig(lr=1e-3, zero1=True)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)))}

mesh1 = jax.make_mesh((2, 4), ("data", "model"))
sess = setup.build_session(cfg, mesh1, comm, oc=oc)
bspec = jax.tree.map(lambda _: P(("data",)), batch)
step = setup.make_sharded_train_step(sess, donate=False)(bspec)
p, o = sess.params, sess.opt_state
for _ in range(3):
    p, o, m = step(p, o, batch)
tmp = tempfile.mkdtemp()
Checkpointer(tmp).save(3, p)

# "failure": only 4 devices remain -> 2x2 mesh
mesh2 = jax.make_mesh((2, 2), ("data", "model"))
sess2, start = elastic_restore(tmp, cfg, mesh2, comm, oc)
assert start == 3
# params identical after resharding
for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(sess2.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)
step2 = setup.make_sharded_train_step(sess2, donate=False)(bspec)
p2, o2, m2 = step2(sess2.params, sess2.opt_state, batch)
assert np.isfinite(float(m2["loss"]))
assert float(m2["loss"]) < float(m["loss"]) + 0.5
print("ELASTIC OK", float(m["loss"]), float(m2["loss"]))
""")
    assert "ELASTIC OK" in out
