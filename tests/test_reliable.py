"""Reliable wire transport: protocol simulation + bitwise parity harness.

Three layers of guarantee for :mod:`repro.core.reliable`:

1. Host-side protocol properties: the send-window / ack-timeout /
   retransmit / backoff simulation delivers every chunk exactly once in
   order, under ANY in-window drop/reorder/dup pattern (hypothesis), with
   monotone capped backoff and honest counters.
2. Fault-schedule determinism: seeded :class:`WireFaults` replay
   identically and reject malformed rates.
3. Bitwise parity (subprocess, 4 emulated devices): every streaming path
   (chunked / buffered / pipelined) x scheduling x fault pattern produces
   values identical to the lossless reference, with the wire counters
   attesting that recovery really fired (and stayed silent on the clean
   runs — the zero-fault fast path).
"""
import pytest

from helpers import require_hypothesis, run_multidevice

from repro.core import reliable
from repro.core.config import CommConfig, Reliability
from repro.obs import metrics as obs_metrics


def _plan(n, drops=(), dups=(), order=None, **kw):
    args = dict(window=4, ack_timeout=2, max_retransmits=4,
                backoff_base=1, backoff_cap=4)
    args.update(kw)
    return reliable.simulate_delivery(n, drops=frozenset(drops),
                                      dups=frozenset(dups), order=order,
                                      **args)


# ----------------------------------------------------------------------
# Protocol simulation
# ----------------------------------------------------------------------

def test_clean_message_is_trivial_in_order():
    plan = _plan(6)
    assert [s.action for s in plan.slots] == [reliable.DELIVER] * 6
    assert [s.seq for s in plan.slots] == list(range(6))
    assert plan.retransmits == plan.dup_dropped == plan.timeouts == 0
    assert plan.backoff_holds == 0 and plan.extra_slots == 0


def test_drop_costs_timeout_backoff_and_retransmit():
    plan = _plan(4, drops=[(1, 0)])
    assert plan.retransmits == 1
    assert plan.timeouts == 1
    assert plan.backoff_holds >= 1          # capped-exponential hold rounds
    assert plan.extra_slots > 0             # recovery has a latency price
    assert sorted(plan.delivered_seqs()) == list(range(4))
    actions = [s.action for s in plan.slots]
    assert reliable.LOST in actions and reliable.HOLD in actions


def test_dup_is_dropped_by_receiver_dedup():
    plan = _plan(4, dups=[2])
    assert plan.dup_dropped == 1
    assert plan.retransmits == 0
    assert sorted(plan.delivered_seqs()) == list(range(4))


def test_dropped_duplicate_of_delivered_chunk_terminates():
    # Regression: chunk 0's original is dropped, its retransmit delivers,
    # and only then does the queued wire-duplicate drain — and the wire
    # drops that too.  The lost dup copy must not resurrect chunk 0 into
    # the unacked set (the retransmit loop would spin forever: every retry
    # deduped, the state never cleared).
    plan = _plan(8, window=2, ack_timeout=1, backoff_cap=2,
                 drops=[(1, 0), (4, 0), (0, 0)], dups=[0, 1, 3],
                 order=(0, 2, 1, 3, 4, 5, 6, 7))
    assert sorted(plan.delivered_seqs()) == list(range(8))
    assert plan.retransmits >= 1 and plan.dup_dropped >= 1


def test_reorder_still_reassembles_in_order():
    plan = _plan(5, order=(4, 3, 2, 1, 0))
    assert plan.retransmits == 0
    assert sorted(s.seq for s in plan.slots
                  if s.action == reliable.DELIVER) == list(range(5))
    assert plan.delivered_seqs() == [4, 3, 2, 1, 0]  # wire arrival order


def test_undeliverable_drop_pattern_raises():
    # every attempt of chunk 0 dropped -> exceeds the retransmit cap
    drops = [(0, a) for a in range(6)]
    with pytest.raises(ValueError, match="undeliverable"):
        _plan(2, drops=drops, max_retransmits=4)


def test_order_must_be_a_permutation():
    with pytest.raises(ValueError):
        _plan(3, order=(0, 0, 2))


def test_backoff_monotone_and_capped():
    prev = 0
    for attempt in range(1, 10):
        h = reliable.backoff_holds(attempt, 1, 4)
        assert h >= prev
        assert h <= 4
        prev = h
    assert reliable.backoff_holds(1, 1, 64) == 1
    assert reliable.backoff_holds(4, 1, 64) == 8
    with pytest.raises(ValueError):
        reliable.backoff_holds(0, 1, 4)


def test_window_stalls_without_acks():
    # window=1 + ordered delivery: chunk i+1 cannot launch before chunk i
    # is acked, so a drop of chunk 0 stalls the whole message.
    plan = _plan(3, drops=[(0, 0)], window=1)
    deliver_pos = [i for i, s in enumerate(plan.slots)
                   if s.action == reliable.DELIVER]
    seqs = [plan.slots[i].seq for i in deliver_pos]
    assert seqs == sorted(seqs)             # strictly in-order launches


# ----------------------------------------------------------------------
# WireFaults determinism + plan memoization
# ----------------------------------------------------------------------

def test_wire_faults_deterministic_and_validated():
    a = reliable.WireFaults(seed=3, drop=0.3, dup=0.1, reorder=0.2)
    b = reliable.WireFaults(seed=3, drop=0.3, dup=0.1, reorder=0.2)
    for msg in range(8):
        assert a.outcomes(msg, 6, 4) == b.outcomes(msg, 6, 4)
    # seeded drops never exhaust the retransmit budget (wire relents)
    heavy = reliable.WireFaults(seed=0, drop=0.9)
    for msg in range(16):
        drops, _, _ = heavy.outcomes(msg, 4, 3)
        assert all(a < 3 for _, a in drops)
    with pytest.raises(ValueError, match="rate"):
        reliable.WireFaults(drop=1.0)
    with pytest.raises(ValueError, match="rate"):
        reliable.WireFaults(reorder=-0.1)


def test_plan_for_fast_path_and_best_effort_guard():
    cfg = CommConfig(reliability=Reliability.GUARANTEED)
    assert reliable.plan_for(cfg, 4) is None          # no faults injected
    faults = reliable.WireFaults(seed=0, drop_events=frozenset({(0, 0, 0)}))
    with reliable.inject(faults):
        plan = reliable.plan_for(cfg, 4)
        assert plan is not None and plan.retransmits == 1
    with reliable.inject(faults):
        with pytest.raises(ValueError, match="best_effort|BEST_EFFORT"):
            reliable.plan_for(CommConfig(), 4)
    assert reliable.active() is None                  # context restored


def test_delivery_plan_memoized():
    reg = obs_metrics.registry()
    cfg = CommConfig(reliability=Reliability.GUARANTEED)
    drops = frozenset({(0, 0)})
    reliable.delivery_plan(64, cfg, drops, frozenset(), tuple(range(64)))
    hits0 = reg.counter("plans.plan_hits").value
    p1 = reliable.delivery_plan(64, cfg, drops, frozenset(),
                                tuple(range(64)))
    p2 = reliable.delivery_plan(64, cfg, drops, frozenset(),
                                tuple(range(64)))
    assert p1 is p2
    assert reg.counter("plans.plan_hits").value >= hits0 + 2


# ----------------------------------------------------------------------
# Hypothesis: any in-window fault pattern reassembles to identity
# ----------------------------------------------------------------------

def test_property_delivery_identity_under_faults():
    hypothesis = require_hypothesis()
    from hypothesis import given, settings, strategies as st

    @st.composite
    def fault_case(draw):
        n = draw(st.integers(1, 12))
        max_rt = draw(st.integers(1, 4))
        drops = set()
        for seq in range(n):
            # a contiguous run of failed attempts, within the cap
            k = draw(st.integers(0, max_rt))
            drops.update((seq, a) for a in range(k))
        dups = draw(st.sets(st.integers(0, n - 1), max_size=n))
        order = draw(st.permutations(list(range(n))))
        window = draw(st.integers(1, 8))
        return n, max_rt, drops, dups, tuple(order), window

    @given(fault_case())
    @settings(max_examples=120, deadline=None)
    def check(case):
        n, max_rt, drops, dups, order, window = case
        plan = reliable.simulate_delivery(
            n, window=window, ack_timeout=2, max_retransmits=max_rt,
            backoff_base=1, backoff_cap=4,
            drops=frozenset(drops), dups=frozenset(dups), order=order)
        # exactly-once reassembly: arrival order is a permutation
        assert sorted(plan.delivered_seqs()) == list(range(n))
        delivered = [s.seq for s in plan.slots
                     if s.action == reliable.DELIVER]
        assert sorted(delivered) == list(range(n))
        assert len(delivered) == n                    # dedup: exactly once
        # counters are honest
        assert plan.retransmits == sum(
            1 for s in plan.slots
            if s.attempt > 0 and s.action in (reliable.DELIVER,
                                              reliable.LOST))
        assert plan.extra_slots == len(plan.slots) - n

    check()


def test_property_backoff_monotone_capped():
    require_hypothesis()
    from hypothesis import given, settings, strategies as st

    @given(st.integers(1, 16), st.integers(0, 8), st.integers(0, 64))
    @settings(max_examples=200, deadline=None)
    def check(attempt, base, cap):
        cap = max(cap, base)                 # config invariant
        h = reliable.backoff_holds(attempt, base, cap)
        assert 0 <= h <= cap or h == base    # capped
        assert h <= cap
        if attempt > 1:
            assert h >= reliable.backoff_holds(attempt - 1, base, cap)

    check()


# ----------------------------------------------------------------------
# Bitwise parity matrix (subprocess, 4 emulated devices)
# ----------------------------------------------------------------------

def test_reliable_parity_matrix_bitwise():
    out = run_multidevice("""
import itertools
import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.core import reliable, streaming
from repro.core.config import (CommConfig, CommMode, Reliability,
                               Scheduling, Transport)
from repro.obs import metrics as obs_metrics

mesh = compat.make_mesh((4,), ("x",))
perm = [(i, (i + 1) % 4) for i in range(4)]
N = 8 * 128
x = jnp.arange(4 * N, dtype=jnp.float32).reshape(4, N) * 0.37 + 1.0

# Each traced run sends exactly one message (msg 0), so every explicit
# event pins msg 0.  Out-of-range seqs (e.g. seq 2 on the 1-chunk buffered
# path) are harmless: the protocol never transmits them.  Reorder uses the
# seeded rate, not an explicit order, because an explicit order must match
# the path's chunk count — and buffered's single chunk cannot reorder.
FAULTS = {
    "clean": None,
    "drop": reliable.WireFaults(seed=1, drop_events=frozenset(
        {(0, 0, 0), (0, 2, 0), (0, 2, 1)})),
    "reorder": reliable.WireFaults(seed=1, reorder=0.9),
    "dup": reliable.WireFaults(seed=1, dup_events=frozenset(
        {(0, 0), (0, 3)})),
    "combined": reliable.WireFaults(seed=1, drop=0.25, dup=0.2,
                                    reorder=0.3,
                                    drop_events=frozenset({(0, 0, 0)}),
                                    dup_events=frozenset({(0, 0)})),
}

def run(path, cfg):
    spec = jax.sharding.PartitionSpec("x")
    if path == "chunked":
        body = lambda v: streaming.chunked_permute(v[0], perm, "x",
                                                   cfg)[None]
    elif path == "buffered":
        body = lambda v: streaming.buffered_permute(v[0], perm, "x",
                                                    cfg)[None]
    else:
        def body(v):
            carry, msg = streaming.pipelined_consume(
                v[0], perm, "x", cfg,
                consume=lambda c, i, m: c + jnp.sum(m),
                init=jnp.float32(0.0))
            return (msg + carry)[None]
    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=spec, out_specs=spec,
                                 check_vma=False))
    return np.asarray(f(x))

reg = obs_metrics.registry()
checked = 0
for path, sched in itertools.product(
        ("chunked", "buffered", "pipelined"),
        (Scheduling.FUSED, Scheduling.OVERLAPPED)):
    base = CommConfig(mode=CommMode.STREAMING, scheduling=sched,
                      transport=Transport.UNORDERED, window=2,
                      chunk_bytes=512)
    ref = run(path, base)
    for fname, faults in FAULTS.items():
        cfg = CommConfig(mode=CommMode.STREAMING, scheduling=sched,
                         transport=Transport.UNORDERED, window=2,
                         chunk_bytes=512,
                         reliability=Reliability.GUARANTEED,
                         ack_timeout=1, max_retransmits=4,
                         backoff_base=1, backoff_cap=2)
        before = reliable.wire_counters()
        with reliable.inject(faults):
            got = run(path, cfg)
        after = reliable.wire_counters()
        d = {k: after[k] - before[k] for k in after}
        assert np.array_equal(ref, got), (path, sched, fname)
        if fname == "clean":
            assert all(v == 0 for v in d.values()), (path, sched, d)
        elif fname == "drop":
            assert d["retransmits"] > 0, (path, sched, d)
        elif fname == "dup":
            assert d["dup_dropped"] > 0, (path, sched, d)
        elif fname == "reorder":
            if path == "buffered":
                # a 1-chunk message cannot reorder: stays on the fast path
                assert d["messages_recovered"] == 0, (path, sched, d)
            else:
                assert d["messages_recovered"] > 0, (path, sched, d)
        else:
            assert d["retransmits"] > 0, (path, sched, d)
            # On the 1-chunk buffered path the pinned (0,0,0) drop also
            # swallows the duplicate copy (dups transmit at attempt 0),
            # so only the retransmit witness is guaranteed there.
            if path != "buffered":
                assert d["dup_dropped"] > 0, (path, sched, d)
        checked += 1
print("PARITY MATRIX OK", checked)
""", n_devices=4)
    assert "PARITY MATRIX OK 30" in out
