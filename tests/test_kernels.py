"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
run in Pallas interpret mode on CPU (the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 128, 2, 32),     # exactly one tile
    (2, 200, 4, 32),     # ragged seq
    (1, 384, 8, 64),     # multi-tile, GQA 8:2
])
@pytest.mark.parametrize("mode", ["causal", "full", "window"])
def test_flash_attention_sweep(shape, dtype, mode):
    from repro.kernels.flash_attention import ops
    B, S, H, hd = shape
    KV = max(1, H // 2)
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, S, H, hd), dtype)
    k = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    v = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    kwargs = {"causal": dict(causal=True),
              "full": dict(causal=False),
              "window": dict(causal=True, window=37)}[mode]
    out = ops.flash_attention(q, k, v, **kwargs)
    ref = ops.flash_attention_reference(q, k, v, **kwargs)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_cross_lengths():
    from repro.kernels.flash_attention import ops
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 300, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 300, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    ref = ops.flash_attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ----------------------------------------------------------------------
# ssd scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dims", [
    (1, 32, 2, 8, 8, 16),
    (2, 64, 3, 16, 8, 16),
    (1, 128, 4, 32, 16, 32),
])
def test_ssd_scan_sweep(dims):
    from repro.kernels.ssd_scan import ops
    from repro.models.ssm import ssd_chunked_ref
    B, S, H, P, N, chunk = dims
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.1 + 0.01, jnp.float32)
    a = -jnp.asarray(np.abs(rng.randn(H)) + 0.5, jnp.float32)
    b = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    c = jnp.asarray(rng.randn(B, S, 1, N), jnp.float32)
    y_k, h_k = ops.ssd_chunked(x, dt, a, b, c, chunk)
    y_r, h_r = ssd_chunked_ref(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_sequential_recurrence():
    """SSD chunked == naive per-token state recurrence (the SSM definition)."""
    from repro.models.ssm import ssd_chunked_ref
    rng = np.random.RandomState(2)
    B, S, H, P, N = 1, 32, 2, 8, 4
    x = rng.randn(B, S, H, P).astype(np.float32)
    dt = (np.abs(rng.randn(B, S, H)) * 0.1 + 0.01).astype(np.float32)
    a = -(np.abs(rng.randn(H)) + 0.5).astype(np.float32)
    b = rng.randn(B, S, 1, N).astype(np.float32)
    c = rng.randn(B, S, 1, N).astype(np.float32)
    y, hf = ssd_chunked_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                            jnp.asarray(b), jnp.asarray(c), chunk=8)
    # naive recurrence
    h = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        for hh in range(H):
            da = dt[:, t, hh] * a[hh]
            h[:, hh] = h[:, hh] * np.exp(da)[:, None, None] + \
                dt[:, t, hh][:, None, None] * np.einsum(
                    "bn,bp->bnp", b[:, t, 0], x[:, t, hh])
            ys[:, t, hh] = np.einsum("bn,bnp->bp", c[:, t, 0], h[:, hh])
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), h, atol=1e-3, rtol=1e-3)


# ----------------------------------------------------------------------
# quant
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n", [1024, 3000, 1 << 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_roundtrip_sweep(n, dtype):
    from repro.kernels.quant import ops
    from repro.kernels.quant.ref import quantize_ref
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(n) * 3, dtype)
    q, s = ops.quantize(x)
    qr, sr = quantize_ref(x)
    # allow ±1 code at exact rounding ties (kernel fuses the divide)
    assert np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32)).max() <= 1
    xd = ops.dequantize(q, s, (n,), dtype)
    err = np.abs(np.asarray(xd, np.float32) - np.asarray(x, np.float32)).max()
    scale_bound = float(np.asarray(s).max())
    # bf16 output adds its own rounding (8-bit mantissa) on top of the
    # int8 quantization step
    out_eps = (2.0 ** -8) * float(np.abs(np.asarray(x, np.float32)).max()) \
        if dtype == jnp.bfloat16 else 0.0
    assert err <= scale_bound * 0.51 + out_eps + 1e-6


def test_quant_property_scale_bound():
    """Property: |dequant(quant(x)) - x| <= scale/2 per block, any input."""
    from helpers import require_hypothesis
    require_hypothesis()
    from hypothesis import given, settings, strategies as st
    from repro.kernels.quant.ref import quantize_ref, dequantize_ref

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                    min_size=1, max_size=300))
    def check(vals):
        x = jnp.asarray(np.array(vals, np.float32))
        q, s = quantize_ref(x)
        xd = dequantize_ref(q, s, x.shape, jnp.float32)
        bound = np.repeat(np.asarray(s)[:, 0], 1024)[: x.size] * 0.5 + 1e-5
        assert (np.abs(np.asarray(xd) - np.asarray(x)) <= bound).all()

    check()


# ----------------------------------------------------------------------
# swe step
# ----------------------------------------------------------------------

@pytest.mark.parametrize("E", [100, 512, 1300])
def test_swe_step_sweep(E):
    from repro.kernels.swe_step import ops
    from repro.kernels.swe_step.ref import swe_step_ref
    rng = np.random.RandomState(E)
    u = jnp.asarray(np.abs(rng.randn(E, 3)) * 0.1 + np.array([1.0, 0, 0]),
                    jnp.float32)
    u_n = jnp.asarray(np.abs(rng.randn(E, 3, 3)) * 0.1 + np.array([1.0, 0, 0]),
                      jnp.float32)
    nx = jnp.asarray(rng.randn(E, 3) * 0.01, jnp.float32)
    ny = jnp.asarray(rng.randn(E, 3) * 0.01, jnp.float32)
    et = jnp.asarray(rng.randint(0, 3, (E, 3)), jnp.int32)
    area = jnp.asarray(np.abs(rng.randn(E)) * 1e-3 + 1e-4, jnp.float32)
    valid = jnp.asarray((rng.rand(E) > 0.05).astype(np.float32))
    out = ops.swe_step(u, u_n, nx, ny, et, area, valid, 1.0, dt=1e-4)
    ref = swe_step_ref(u, u_n, nx, ny, et, area, valid, 1.0, dt=1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
