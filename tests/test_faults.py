"""Fault injection, degradation monitoring, and elastic recovery.

Covers the PR 8 contract end to end: deterministic fault schedules, the
wire-layer degraded-link emulation on TorusSpec (hold rounds, reroute,
shrink), the hysteresis-gated DegradationMonitor fed from the metrics
registry, model-based config re-selection (NO sweep during recovery —
asserted via the ``sweep.runs`` counter), preemption-guard semantics
(SIGINT, chaining, nesting), torn-checkpoint recovery, and the two
kill-and-resume end-to-end paths (SWE segment loop, LM train loop) with
bitwise-identical result streams.
"""
import json
import os
import signal
import time

import numpy as np
import pytest

from helpers import run_multidevice


# ----------------------------------------------------------------------
# Fault schedules
# ----------------------------------------------------------------------

def test_schedule_generate_is_deterministic():
    from repro.runtime.faults import FaultSchedule
    a = FaultSchedule.generate(7, 100, n_ranks=8, degraded_links=2,
                               rank_losses=1, stragglers=2, preempts=1)
    b = FaultSchedule.generate(7, 100, n_ranks=8, degraded_links=2,
                               rank_losses=1, stragglers=2, preempts=1)
    assert a == b
    c = FaultSchedule.generate(8, 100, n_ranks=8, degraded_links=2,
                               rank_losses=1, stragglers=2, preempts=1)
    assert a != c
    # events land in the middle 80% so recovery has steps left to run
    assert all(10 <= e.step < 90 for e in a)
    kinds = sorted(e.kind for e in a)
    assert kinds == ["degraded_link", "degraded_link", "preempt",
                     "rank_lost", "straggler", "straggler"]


def test_schedule_parse_compact():
    from repro.runtime.faults import (DegradedLink, FaultSchedule, Preempt,
                                      RankLost, Straggler)
    s = FaultSchedule.parse(
        "degraded_link@5=0-1x3.0; rank_lost@10=r5; straggler@7=r2x4.0;"
        "preempt@30")
    assert DegradedLink(5, (0, 1), 3.0) in s.events
    assert RankLost(10, 5) in s.events
    assert Straggler(7, 2, 4.0) in s.events
    assert Preempt(30) in s.events
    # events come back sorted by step regardless of input order
    assert [e.step for e in s] == sorted(e.step for e in s)
    with pytest.raises(ValueError):
        FaultSchedule.parse("meteor@5=r1")
    with pytest.raises(ValueError):
        FaultSchedule.parse("rank_lost@ten=r1")


def test_schedule_parse_chunk_loss():
    from repro.runtime.faults import ChunkLoss, FaultSchedule
    s = FaultSchedule.parse("chunk_loss@5=0.05")
    assert ChunkLoss(5, drop=0.05) in s.events
    s = FaultSchedule.parse("chunk_loss@3=0.05d0.02r0.1")
    assert ChunkLoss(3, drop=0.05, dup=0.02, reorder=0.1) in s.events
    # a pure dup/reorder wire is a legal schedule (drop may be 0)
    s = FaultSchedule.parse("chunk_loss@0=0d0.2")
    assert ChunkLoss(0, drop=0.0, dup=0.2) in s.events


def test_schedule_parse_rejects_malformed_items():
    """Every malformed compact item raises a ValueError naming the item —
    a bad string must never silently drop or double-fire an event."""
    from repro.runtime.faults import FaultSchedule
    bad = [
        "degraded_link@5",                 # missing argument
        "degraded_link@5=0-1",            # missing slowdown
        "degraded_link@5=2-2x3.0",        # self-loop edge
        "degraded_link@5=0-1x0.5",        # slowdown below 1
        "rank_lost@-1=r0",                 # negative step
        "rank_lost@5",                     # missing rank
        "rank_lost@5=rr3",                 # mangled rank
        "straggler@5=r1",                  # missing factor
        "straggler@5=r1x0.2",              # factor below 1
        "preempt@5=r1",                    # trailing argument
        "preempt",                         # missing '@step'
        "chunk_loss@5",                    # missing rate
        "chunk_loss@5=1.0",                # rate out of [0, 1)
        "chunk_loss@5=-0.1",               # negative rate
        "chunk_loss@5=0.05d1.5",           # dup rate out of range
        "chunk_loss@5=0",                  # all-zero rates
        "chunk_loss@5=oops",               # non-numeric rate
    ]
    for item in bad:
        with pytest.raises(ValueError, match="bad fault item|missing"):
            FaultSchedule.parse(item)


def test_schedule_parse_rejects_exact_duplicates():
    from repro.runtime.faults import FaultSchedule
    with pytest.raises(ValueError, match="would fire twice"):
        FaultSchedule.parse("rank_lost@10=r5; rank_lost@10=r5")
    with pytest.raises(ValueError, match="would fire twice"):
        FaultSchedule.parse("chunk_loss@5=0.05;chunk_loss@5=0.05")
    # same kind at a different step (or args) is fine
    s = FaultSchedule.parse("chunk_loss@5=0.05; chunk_loss@9=0.1")
    assert len(s.events) == 2


def test_schedule_json_roundtrip(tmp_path):
    from repro.runtime.faults import FaultSchedule
    s = FaultSchedule.generate(3, 50, n_ranks=4, degraded_links=1,
                               rank_losses=1)
    assert FaultSchedule.from_json(s.to_json()) == s
    p = s.save(tmp_path / "sched.json")
    assert FaultSchedule.load(p) == s
    bad = json.loads(s.to_json())
    bad["version"] = 99
    with pytest.raises(ValueError):
        FaultSchedule.from_json(json.dumps(bad))


# ----------------------------------------------------------------------
# Injector
# ----------------------------------------------------------------------

def test_injector_fires_each_event_once_across_boundaries():
    from repro.runtime.faults import (FaultInjector, FaultSchedule,
                                      RankLostError)
    # events at steps 3 and 10; the loop only polls every 5 steps
    sched = FaultSchedule.parse("degraded_link@3=0-1x2.5;rank_lost@10=r2")
    inj = FaultInjector(sched)
    assert inj.poll(0) == []
    fired = inj.poll(5)                      # step 3 skipped over -> fires now
    assert [e.kind for e in fired] == ["degraded_link"]
    assert inj.active_slowdowns == {(0, 1): 2.5}
    assert inj.poll(9) == []                 # never fires twice
    with pytest.raises(RankLostError) as ei:
        inj.poll(10)
    assert ei.value.rank == 2 and ei.value.step == 10
    # max-merge on repeat degradation of the same link
    inj2 = FaultInjector(FaultSchedule.parse(
        "degraded_link@1=0-1x3.0;degraded_link@2=1-0x2.0"))
    inj2.poll(5)
    assert inj2.active_slowdowns == {(0, 1): 3.0}


def test_injector_same_boundary_degradation_survives_rank_loss():
    """RankLostError is raised LAST: a degradation due at the same boundary
    is applied before the loop unwinds."""
    from repro.runtime.faults import (FaultInjector, FaultSchedule,
                                      RankLostError)
    inj = FaultInjector(FaultSchedule.parse(
        "degraded_link@5=2-3x2.0;rank_lost@5=r1"))
    with pytest.raises(RankLostError):
        inj.poll(5)
    assert inj.active_slowdowns == {(2, 3): 2.0}


def test_injector_straggler_delay_and_preempt():
    from repro.runtime.faults import FaultInjector, FaultSchedule
    from repro.runtime.fault_tolerance import PreemptionGuard
    slept = []
    inj = FaultInjector(FaultSchedule.parse("straggler@4=r0x3.0;preempt@6"),
                        base_step_s=0.01, sleep=slept.append)
    inj.poll(3)
    assert slept == []
    inj.poll(4)                              # 3x slower: +2 x base per step
    assert slept == [pytest.approx(0.02)]
    assert inj.straggler_delay_s(4 + 5) == 0.0   # default duration is 5 steps
    guard = PreemptionGuard()
    inj.poll(6, guard=guard)
    assert guard.preempted


def test_injector_edge_samples_deterministic():
    from repro.runtime.faults import FaultInjector, FaultSchedule
    sched = FaultSchedule.parse("degraded_link@2=0-1x4.0")
    a, b = FaultInjector(sched), FaultInjector(sched)
    for inj in (a, b):
        inj.poll(2)
    ea = a.edge_latency_samples(7, [(0, 1), (1, 2)])
    eb = b.edge_latency_samples(7, [(0, 1), (1, 2)])
    assert ea == eb                          # seeded by (seed, step, edge)
    assert ea[(0, 1)] > 3.5                  # carries the 4x slowdown
    assert 0.9 < ea[(1, 2)] < 1.1            # healthy edge: noise only


# ----------------------------------------------------------------------
# TorusSpec degradation (wire layer)
# ----------------------------------------------------------------------

def test_degraded_spec_validation_and_identity():
    from repro.core.topology import TorusSpec
    spec = TorusSpec.parse("4x2")
    d = spec.with_link_slowdown(1, 0, 3.0)   # canonicalized to (0, 1)
    assert d.degraded_links == ((0, 1),)
    assert d.link_slowdown(0, 1) == 3.0 and d.link_slowdown(1, 0) == 3.0
    assert d.link_slowdown(0, 2) == 1.0
    # plan-cache identity changes; TuneDB identity (name) does not
    assert d.key() != spec.key()
    assert d.name == spec.name
    assert d.with_reroute(True).key() != d.key()
    assert d.without_degradations().key() == spec.key()
    # a factor of exactly 1.0 is a no-op, not a degradation
    assert spec.with_link_slowdown(0, 1, 1.0).degraded_links == ()
    with pytest.raises(ValueError):
        spec.with_link_slowdown(0, 5, 2.0)   # not a physical 1-hop link
    with pytest.raises(ValueError):
        spec.with_link_slowdown(0, 1, 0.5)   # speedups are not faults


def test_route_reroutes_around_confirmed_degradation():
    from repro.core.topology import TorusSpec, route
    spec = TorusSpec.parse("4x4")
    primary = route(spec, 0, 5)              # rows first: 0 -> 4 -> 5
    assert primary == [0, 4, 5]
    hurt = spec.with_link_slowdown(0, 4, 4.0)
    # physics alone does not move routes: belief lags until confirmation
    assert route(hurt, 0, 5) == primary
    believed = hurt.with_reroute(True)
    assert route(believed, 0, 5) == [0, 1, 5]   # cols first dodges the link
    # ties keep rows-first: healthy fabrics route identically under reroute
    assert route(spec.with_reroute(True), 0, 5) == primary


def test_route_rounds_insert_hold_rounds():
    from repro.core.topology import TorusSpec, route_rounds
    spec = TorusSpec.parse("4x2")
    edges = [(0, 2), (1, 3)]
    healthy = route_rounds(spec, edges)
    hurt = route_rounds(spec.with_link_slowdown(0, 2, 3.0), edges)
    n_h = sum(len(b.rounds) for b in healthy.batches)
    n_d = sum(len(b.rounds) for b in hurt.batches)
    assert n_d == n_h + 2                    # ceil(3.0) - 1 hold rounds
    holds = [r for b in hurt.batches for r in b.rounds
             if all(s == d for s, d in r)]
    assert len(holds) == 2                   # every hold is pure self-forward
    # destinations (the value contract) are untouched by the slowdown
    assert tuple(d for b in hurt.batches for d in b.dests) == \
        tuple(d for b in healthy.batches for d in b.dests)


def test_shrink_factorizations():
    from repro.core.topology import TorusSpec
    spec = TorusSpec.parse("4x2").with_link_slowdown(0, 1, 2.0)
    assert spec.shrink(7).shape == (1, 7)    # prime survivor count -> ring
    assert spec.shrink(6).shape == (2, 3)    # squarest factorization
    assert spec.shrink(4).shape == (2, 2)
    # degradations belong to the dead fabric; survivors start clean
    assert spec.shrink(6).degraded_links == ()
    with pytest.raises(ValueError):
        spec.shrink(9)                       # cannot grow


# ----------------------------------------------------------------------
# Degradation monitor
# ----------------------------------------------------------------------

def _private_monitor(**kw):
    from repro.obs.metrics import Registry
    from repro.runtime.faults import DegradationMonitor
    reg = Registry()
    return DegradationMonitor(registry=reg, **kw), reg


def test_monitor_confirms_only_after_hysteresis():
    mon, _ = _private_monitor(threshold=1.5, hysteresis=3, cooldown=100)
    e = (0, 1)
    assert mon.observe(0, {e: 1.0}) == []    # first sample seeds the baseline
    assert mon.observe(1, {e: 3.0}) == []
    assert mon.observe(2, {e: 3.0}) == []
    assert mon.observe(3, {e: 3.0}) == [e]   # third consecutive flag confirms
    # flagged samples never refresh the baseline (no self-normalization)
    assert mon.baseline(e) == 1.0


def test_monitor_never_flaps_under_steady_noise():
    mon, reg = _private_monitor(threshold=1.5, hysteresis=3, cooldown=5)
    rng = np.random.RandomState(0)
    for step in range(200):
        samples = {(0, 1): 1.0 + 0.3 * rng.rand(),
                   (1, 2): 1.0 + 0.3 * rng.rand()}
        assert mon.observe(step, samples) == []
    assert mon.confirmed == set()
    assert reg.counter("monitor.confirmations").value == 0


def test_monitor_streak_resets_on_healthy_sample():
    mon, _ = _private_monitor(threshold=1.5, hysteresis=3, cooldown=100)
    e = (0, 1)
    mon.observe(0, {e: 1.0})
    for step, x in enumerate((3.0, 3.0, 1.0, 3.0, 3.0), start=1):
        assert mon.observe(step, {e: x}) == []   # the dip breaks the streak
    assert mon.observe(6, {e: 3.0}) == [e]


def test_monitor_cooldown_suppresses_reconfirmation():
    mon, reg = _private_monitor(threshold=1.5, hysteresis=2, cooldown=20)
    e = (2, 3)
    mon.observe(0, {e: 1.0})
    assert mon.observe(1, {e: 4.0}) == []
    assert mon.observe(2, {e: 4.0}) == [e]
    # still degraded, still flagged — but inside the cooldown window
    for step in range(3, 22):
        assert mon.observe(step, {e: 4.0}) == []
    # the persistent degradation re-confirms the moment cooldown expires
    assert mon.observe(22, {e: 4.0}) == [e]
    assert reg.counter("monitor.confirmations").value == 2


def test_monitor_registry_deltas_and_traffic_gate():
    from repro.obs.metrics import Registry
    from repro.runtime.faults import DegradationMonitor
    reg = Registry()
    mon = DegradationMonitor(threshold=1.5, hysteresis=1, registry=reg)
    reg.counter("comm.edge_bytes", hops=1).inc(100)
    reg.counter("comm.edge_bytes", hops=2).inc(40)
    reg.counter("watchdog.stragglers").inc()
    d = mon.registry_deltas()
    assert d["edge_bytes"] == {1: 100, 2: 40}
    assert d["traffic"] == 140 and d["stragglers"] == 1
    d2 = mon.registry_deltas()               # deltas, not totals
    assert d2["traffic"] == 0 and d2["stragglers"] == 0
    # no traffic since last observation -> no verdict (streaks frozen)
    e = (0, 1)
    mon.observe(0, {e: 1.0})
    assert mon.observe(1, {e: 9.0}, require_traffic=True) == []
    reg.counter("comm.edge_bytes", hops=1).inc(10)
    assert mon.observe(2, {e: 9.0}, require_traffic=True) == [e]
    assert mon.last_straggler_delta == 0


def test_injector_chunk_loss_arms_wire_faults():
    from repro.core.reliable import WireFaults
    from repro.runtime.faults import FaultInjector, FaultSchedule
    sched = FaultSchedule.parse("chunk_loss@5=0.05d0.02r0.1")
    inj = FaultInjector(sched)
    assert inj.wire_faults() is None         # not fired yet
    inj.poll(5)
    wf = inj.wire_faults()
    assert isinstance(wf, WireFaults)
    assert (wf.drop, wf.dup, wf.reorder) == (0.05, 0.02, 0.1)
    # a requested drop rate pins the first transmission lost, so short
    # traces deterministically exercise recovery
    assert (0, 0, 0) in wf.drop_events
    # pure dup/reorder wires pin nothing (no drop to guarantee)
    inj2 = FaultInjector(FaultSchedule.parse("chunk_loss@0=0d0.2"))
    inj2.poll(0)
    assert inj2.wire_faults().drop_events == frozenset()


def test_monitor_wire_signal_hysteresis_and_cooldown():
    """Sustained wire.retransmits growth confirms a lossy wire exactly once
    per episode — same streak/cooldown discipline as the edge signal."""
    mon, reg = _private_monitor(threshold=1.5, hysteresis=3, cooldown=10)
    e = (0, 1)
    confirmations = []
    for step in range(8):
        reg.counter("wire.retransmits").inc(2)   # steady retransmit stream
        mon.observe(step, {e: 1.0})
        confirmations.append(mon.wire_confirmed)
    # streak reaches hysteresis at the 3rd observation, then cooldown
    # suppresses re-confirmation while the stream persists
    assert confirmations == [False, False, True,
                             False, False, False, False, False]
    assert mon.wire_confirmations == 1
    assert reg.counter("monitor.wire_confirmations").value == 1
    assert mon.last_retransmit_delta == 2
    # cooldown expiry + persistent loss re-confirms
    for step in range(8, 14):
        reg.counter("wire.retransmits").inc(1)
        mon.observe(step, {e: 1.0})
    assert mon.wire_confirmations == 2


def test_monitor_wire_streak_resets_when_clean():
    mon, reg = _private_monitor(threshold=1.5, hysteresis=3, cooldown=10)
    e = (0, 1)
    for step, delta in enumerate((3, 3, 0, 3, 3)):   # the gap breaks it
        if delta:
            reg.counter("wire.retransmits").inc(delta)
        mon.observe(step, {e: 1.0})
        assert not mon.wire_confirmed
    reg.counter("wire.retransmits").inc(3)
    mon.observe(5, {e: 1.0})
    assert mon.wire_confirmed                        # 3rd consecutive delta
    assert mon.confirmed == set()                    # edge signal untouched


def test_parse_labels_roundtrip():
    from repro.obs.metrics import parse_labels
    assert parse_labels("comm.edge_bytes{hops=2}") == \
        ("comm.edge_bytes", {"hops": "2"})
    assert parse_labels("sweep.runs") == ("sweep.runs", {})
    assert parse_labels("x{a=1,b=two}") == ("x", {"a": "1", "b": "two"})


# ----------------------------------------------------------------------
# Model-based re-selection (no sweep)
# ----------------------------------------------------------------------

def _engineered_db():
    """A synthetic TuneDB whose calibrated Eq. 1 model reorders configs
    across hop distance and link slowdown: at 64 KiB, 1 hop favors buffered
    while 3 hops favor streaming; at 16 KiB / 2 hops, a 3x link slowdown
    flips the streaming chunk size from 4096 to 1024."""
    import dataclasses
    from repro.core import latmodel
    from repro.core.config import CommConfig, CommMode, V5E
    from repro.tune.db import TuneDB, TuneEntry
    from repro.tune.space import config_to_dict
    buf = CommConfig(mode=CommMode.BUFFERED)
    s4k = CommConfig(mode=CommMode.STREAMING, chunk_bytes=4096)
    s1k = CommConfig(mode=CommMode.STREAMING, chunk_bytes=1024)
    hw = dataclasses.replace(V5E, host_dispatch=50e-6, fused_dispatch=2e-6,
                             ici_latency=5e-6, ici_bw=0.25e9, hbm_bw=20e9,
                             ici_hop_latency=20e-6)
    db = TuneDB()
    topo = "cpu:8"
    for cfg in (buf, s4k, s1k):
        for size in (4096, 16384, 65536, 1 << 20):
            for hops in (1, 3):
                sec = latmodel.pingping_latency(size, cfg, hw, hops=hops)
                for coll in ("sendrecv", "multi_neighbor"):
                    db.add(TuneEntry(topo=topo, collective=coll,
                                     msg_bytes=size,
                                     config=config_to_dict(cfg),
                                     us_per_call=sec * 1e6, hops=hops))
    return db, (buf, s4k, s1k)


def test_model_reselect_flips_with_hop_distance():
    from repro.core.config import CommMode
    from repro.tune.elastic import model_reselect
    db, _ = _engineered_db()
    near = model_reselect("multi_neighbor", 65536, db=db, hops=1,
                          topo="cpu:8")
    far = model_reselect("multi_neighbor", 65536, db=db, hops=3,
                         topo="cpu:8")
    assert near.mode == CommMode.BUFFERED
    assert far.mode == CommMode.STREAMING


def test_model_reselect_flips_with_link_slowdown():
    from repro.core.config import CommMode
    from repro.tune.elastic import model_reselect
    db, _ = _engineered_db()
    healthy = model_reselect("multi_neighbor", 16384, db=db, hops=2,
                             link_slowdown=1.0, topo="cpu:8")
    degraded = model_reselect("multi_neighbor", 16384, db=db, hops=2,
                              link_slowdown=3.0, topo="cpu:8")
    assert healthy.mode == CommMode.STREAMING
    assert healthy.chunk_bytes == 4096
    assert degraded.mode == CommMode.STREAMING
    assert degraded.chunk_bytes == 1024      # slower wire -> smaller windows


def test_model_reselect_cold_db_falls_back_without_sweep():
    from repro.core.config import CommConfig, CommMode
    from repro.obs import metrics as obs_metrics
    from repro.tune.db import TuneDB
    from repro.tune.elastic import model_reselect
    reg = obs_metrics.registry()
    sweeps0 = reg.counter("sweep.runs").value
    cold0 = reg.counter("tune.reselect_cold_fallbacks").value
    fb = CommConfig(mode=CommMode.BUFFERED)
    out = model_reselect("multi_neighbor", 4096, db=TuneDB(), fallback=fb)
    assert out == fb
    assert reg.counter("tune.reselect_cold_fallbacks").value == cold0 + 1
    assert reg.counter("sweep.runs").value == sweeps0


def test_reselect_round_configs_per_round_and_no_sweep():
    from repro.core.communicator import Communicator
    from repro.core.config import CommMode
    from repro.core.topology import TorusSpec
    from repro.obs import metrics as obs_metrics
    from repro.tune.elastic import reselect_round_configs
    db, _ = _engineered_db()
    spec = TorusSpec.parse("4x2")
    comm = Communicator(("data",), (8,), topo=spec)
    rounds = [[(0, 2)], [(0, 5)]]            # a 1-hop round and a 3-hop round
    sweeps0 = obs_metrics.registry().counter("sweep.runs").value
    rep, per_round = reselect_round_configs(rounds, comm, 65536, db=db,
                                            topo="cpu:8")
    assert obs_metrics.registry().counter("sweep.runs").value == sweeps0
    assert rep.mode == CommMode.STREAMING    # representative = worst hop
    assert per_round is not None and len(per_round) == 2
    assert per_round[0].mode == CommMode.BUFFERED
    assert per_round[1].mode == CommMode.STREAMING
    # scheduling discipline is unified with the representative
    assert len({c.scheduling for c in per_round}) == 1


# ----------------------------------------------------------------------
# Preemption guard
# ----------------------------------------------------------------------

def test_guard_handles_sigint_by_default():
    from repro.runtime.fault_tolerance import PreemptionGuard
    before = signal.getsignal(signal.SIGINT)
    with PreemptionGuard() as g:
        assert not g.preempted
        signal.raise_signal(signal.SIGINT)   # a Ctrl-C drains, not crashes
        assert g.preempted
    assert signal.getsignal(signal.SIGINT) is before


def test_guard_chains_preexisting_custom_handler():
    from repro.runtime.fault_tolerance import PreemptionGuard
    calls = []
    orig = signal.getsignal(signal.SIGTERM)
    signal.signal(signal.SIGTERM, lambda signum, frame: calls.append(signum))
    try:
        with PreemptionGuard() as g:
            signal.raise_signal(signal.SIGTERM)
            assert g.preempted
            assert calls == [signal.SIGTERM]    # the launcher's hook still ran
        # exit hands the signal back to the custom handler, not the default
        signal.raise_signal(signal.SIGTERM)
        assert calls == [signal.SIGTERM, signal.SIGTERM]
    finally:
        signal.signal(signal.SIGTERM, orig)


def test_guard_nested_restores_in_order():
    from repro.runtime.fault_tolerance import PreemptionGuard
    orig = signal.getsignal(signal.SIGTERM)
    outer, inner = PreemptionGuard(), PreemptionGuard()
    with outer:
        h_outer = signal.getsignal(signal.SIGTERM)
        with inner:
            assert signal.getsignal(signal.SIGTERM) is not h_outer
            signal.raise_signal(signal.SIGTERM)
            assert inner.preempted
            assert outer.preempted           # inner chains to outer's handler
        assert signal.getsignal(signal.SIGTERM) is h_outer
    assert signal.getsignal(signal.SIGTERM) is orig


def test_guard_reentrant_same_instance():
    from repro.runtime.fault_tolerance import PreemptionGuard
    orig = signal.getsignal(signal.SIGTERM)
    g = PreemptionGuard()
    with g:
        with g:                              # eval loop inside the train loop
            pass
        assert signal.getsignal(signal.SIGTERM) is not orig
    assert signal.getsignal(signal.SIGTERM) is orig


# ----------------------------------------------------------------------
# Torn checkpoints
# ----------------------------------------------------------------------

def test_latest_step_skips_torn_checkpoint(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.obs import metrics as obs_metrics
    tree = {"w": np.arange(8, dtype=np.float32)}
    ck = Checkpointer(tmp_path)
    ck.save(1, tree)
    ck.save(2, tree)
    assert ck.latest_step() == 2
    # crash between the npz and the COMMIT marker: step 2 is torn
    os.remove(tmp_path / "ckpt_00000002.COMMIT")
    # plus a leaked tmp from a killed writer — must not crash the scan
    (tmp_path / "ckpt_00000003.12345.tmp.npz").write_bytes(b"garbage")
    skipped0 = obs_metrics.registry().counter("ckpt.skipped_partial").value
    assert ck.latest_step() == 1             # falls back to newest committed
    assert obs_metrics.registry().counter(
        "ckpt.skipped_partial").value == skipped0 + 1
    assert ck.latest_step() == 1             # rescans count each torn step once
    assert obs_metrics.registry().counter(
        "ckpt.skipped_partial").value == skipped0 + 1
    restored = ck.restore(1, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_latest_step_none_when_nothing_committed(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(tmp_path)
    ck.save(5, {"w": np.ones(2, np.float32)})
    os.remove(tmp_path / "ckpt_00000005.COMMIT")
    assert ck.latest_step() is None


def test_emergency_save_carries_opt_state(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer, emergency_save
    params = {"w": np.full((4,), 2.0, np.float32)}
    opt = {"m": np.full((4,), 0.5, np.float32)}
    emergency_save(tmp_path, 7, params, opt_state=opt)
    assert Checkpointer(tmp_path).latest_step() == 7
    opt_ck = Checkpointer(tmp_path / "opt")
    assert opt_ck.latest_step() == 7
    np.testing.assert_array_equal(opt_ck.restore(7, opt)["m"], opt["m"])


# ----------------------------------------------------------------------
# End-to-end: SWE kill-and-resume (subprocess, 8 emulated ranks)
# ----------------------------------------------------------------------

# A TuneDB whose MEASURED multi_neighbor rows favor buffered while the
# calibrated model favors streaming at small halo messages: the initial
# (measured) selection and the recovery-time (model) re-selection then
# provably disagree, which is what the config-changed assertions need.
_SPLIT_DB_SNIPPET = """
import dataclasses
from repro.core import latmodel
from repro.core.config import CommConfig, CommMode, V5E
from repro.tune.db import TuneDB, TuneEntry
from repro.tune.space import config_to_dict

def build_split_db(path):
    buf = CommConfig(mode=CommMode.BUFFERED)
    s4k = CommConfig(mode=CommMode.STREAMING, chunk_bytes=4096)
    s1k = CommConfig(mode=CommMode.STREAMING, chunk_bytes=1024)
    hw = dataclasses.replace(V5E, host_dispatch=50e-6, fused_dispatch=2e-6,
                             ici_latency=5e-6, ici_bw=0.25e9, hbm_bw=20e9,
                             ici_hop_latency=20e-6)
    db = TuneDB()
    for topo in ("cpu:8", "cpu:7", "cpu:4"):
        # model-consistent calibration points (what the Eq. 1 fit reads)
        for cfg in (buf, s4k, s1k):
            for size in (4096, 16384, 65536, 1 << 20):
                for hops in (1, 3):
                    sec = latmodel.pingping_latency(size, cfg, hw, hops=hops)
                    db.add(TuneEntry(topo=topo, collective="sendrecv",
                                     msg_bytes=size,
                                     config=config_to_dict(cfg),
                                     us_per_call=sec * 1e6, hops=hops))
        # "measured" rows for the consumers: buffered wins every lookup
        for coll in ("multi_neighbor", "all_reduce"):
            for cfg, us in ((buf, 1.0), (s4k, 100.0), (s1k, 100.0)):
                for size in (256, 4096, 65536, 1 << 20):
                    for hops in (1, 2, 3):
                        db.add(TuneEntry(topo=topo, collective=coll,
                                         msg_bytes=size,
                                         config=config_to_dict(cfg),
                                         us_per_call=us, hops=hops))
    db.save(path)
    return db
"""


def test_swe_kill_and_resume_bitwise(tmp_path):
    """Lose rank 5 at step 10 of 30 on a 4x2 torus: the run recovers onto 7
    survivors with model-re-selected configs (no sweep), the digest stream
    is bitwise-reproducible across two same-seed faulted runs, and the final
    digest matches the no-fault reference."""
    out = run_multidevice(_SPLIT_DB_SNIPPET + f"""
import numpy as np
from repro.core.topology import TorusSpec
from repro.obs import metrics as obs_metrics
from repro.runtime.elastic import run_swe_elastic
from repro.runtime.faults import FaultSchedule

db_path = {str(tmp_path / "tunedb.json")!r}
build_split_db(db_path)
topo = TorusSpec.parse("4x2")
reg = obs_metrics.registry()

ref = run_swe_elastic(300, 8, topo, n_steps=30, segment=10,
                      tune_db_path=db_path)
assert ref.recoveries == [] and ref.n_parts == [8, 8, 8]

sched = FaultSchedule.parse("rank_lost@10=r5")
resel0 = reg.counter("tune.model_reselects", collective="multi_neighbor").value
runs = [run_swe_elastic(300, 8, topo, n_steps=30, segment=10,
                        schedule=sched, tune_db_path=db_path)
        for _ in range(2)]
f1, f2 = runs

# recovery happened, and on the survivors' sub-torus
assert len(f1.recoveries) == 1 and f1.recoveries[0].kind == "rank_lost"
assert f1.n_parts[-1] == 7
# NO sweep ran during recovery (the counter is the witness)
assert f1.sweep_runs_delta == 0 and ref.sweep_runs_delta == 0
# recovery re-selected from the model, and the configs actually changed
assert reg.counter("tune.model_reselects",
                   collective="multi_neighbor").value > resel0
assert f1.recoveries[0].config_changed()
# bitwise-reproducible across two same-seed faulted runs
assert f1.digests == f2.digests
assert f1.final_digest == f2.final_digest
# recovery is value-preserving: same answer as the no-fault reference
assert f1.final_digest == ref.final_digest
print("SWE KILL-RESUME OK", f1.final_digest[:16])
""")
    assert "SWE KILL-RESUME OK" in out


def test_swe_degraded_link_confirm_and_reroute(tmp_path):
    """A degraded link slows the wire physically at once, but routes and
    configs move only after the monitor confirms (hysteresis); the answer
    stays bitwise-identical to the healthy run throughout."""
    out = run_multidevice(_SPLIT_DB_SNIPPET + f"""
import numpy as np
from repro.core.topology import TorusSpec
from repro.runtime.elastic import run_swe_elastic
from repro.runtime.faults import DegradationMonitor, FaultSchedule

db_path = {str(tmp_path / "tunedb.json")!r}
build_split_db(db_path)
topo = TorusSpec.parse("4x2")

ref = run_swe_elastic(300, 8, topo, n_steps=30, segment=5,
                      tune_db_path=db_path)
sched = FaultSchedule.parse("degraded_link@2=0-1x3.0")
runs = [run_swe_elastic(
            300, 8, topo, n_steps=30, segment=5, schedule=sched,
            tune_db_path=db_path,
            monitor=DegradationMonitor(threshold=1.5, hysteresis=2,
                                       cooldown=100))
        for _ in range(2)]
f1, f2 = runs
assert len(f1.recoveries) == 1 and f1.recoveries[0].kind == "degraded_link"
assert "(0, 1)" in f1.recoveries[0].detail
assert f1.sweep_runs_delta == 0
assert f1.n_parts[-1] == 8                  # degraded-but-alive: no shrink
assert f1.digests == f2.digests             # deterministic recovery
# hold rounds and rerouting are value-preserving
assert f1.final_digest == ref.final_digest
print("SWE DEGRADED OK", f1.recoveries[0].detail)
""")
    assert "SWE DEGRADED OK" in out


# ----------------------------------------------------------------------
# End-to-end: LM train loop survives rank loss (subprocess)
# ----------------------------------------------------------------------

def test_lm_rank_loss_elastic_reselect(tmp_path):
    """RANK_LOST mid-train: the loop emergency-checkpoints the last completed
    step, elastic_restore re-forms on the survivors with a model-re-selected
    CommConfig (no sweep), and the whole faulted flow is bitwise-reproducible
    across two same-seed runs."""
    out = run_multidevice(_SPLIT_DB_SNIPPET + f"""
import dataclasses, shutil
import jax, numpy as np, jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig, CommMode
from repro.core.topology import TorusSpec
from repro.data.pipeline import DataConfig
from repro.launch import setup
from repro.obs import metrics as obs_metrics
from repro.optim import adamw
from repro.runtime.fault_tolerance import elastic_restore
from repro.runtime.faults import FaultInjector, FaultSchedule, RankLostError
from repro.train import loop as loop_mod

db_path = {str(tmp_path / "tunedb.json")!r}
build_split_db(db_path)
cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
oc = adamw.OptConfig(lr=1e-3, zero1=False)
comm = CommConfig(mode=CommMode.BUFFERED)
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
topo = TorusSpec.parse("4x2")
reg = obs_metrics.registry()
sweeps0 = reg.counter("sweep.runs").value

def faulted_run(ckpt_dir):
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    sess = setup.build_session(cfg, mesh, comm, oc=oc)
    inj = FaultInjector(FaultSchedule.parse("rank_lost@3=r7"))
    losses = []
    try:
        loop_mod.train(sess, data,
                       loop_mod.LoopConfig(n_steps=10, ckpt_every=100,
                                           ckpt_dir=ckpt_dir, log_every=100),
                       log=lambda *_: None, faults=inj)
        raise AssertionError("rank loss never fired")
    except RankLostError as e:
        assert e.rank == 7 and e.step == 3
    # the loop drained an emergency checkpoint before unwinding
    from repro.checkpoint.checkpointer import Checkpointer
    assert Checkpointer(ckpt_dir).latest_step() == 3
    # survivors: 4 devices; recovery re-selects from the model, not a sweep
    mesh2 = jax.make_mesh((4, 1), ("data", "model"))
    sess2, start = elastic_restore(ckpt_dir, cfg, mesh2, comm, oc,
                                   reselect=True, tune_db_path=db_path,
                                   topology=topo)
    assert start == 3
    hist = loop_mod.train(sess2, data,
                          loop_mod.LoopConfig(n_steps=3, ckpt_every=100,
                                              ckpt_dir=None, log_every=100),
                          log=lambda *_: None)
    return sess2.rt.comm, hist

cc1, h1 = faulted_run({str(tmp_path / "ck1")!r})
cc2, h2 = faulted_run({str(tmp_path / "ck2")!r})

# the survivors' config was re-selected by the model and actually differs
# from the dead mesh's config
assert cc1.mode != comm.mode, (cc1, comm)
assert cc1 == cc2
assert reg.counter("tune.model_reselects", collective="all_reduce").value >= 2
assert reg.counter("sweep.runs").value == sweeps0     # never swept
# bitwise-reproducible post-recovery loss stream across same-seed runs
assert h1 == h2, (h1, h2)
assert all(np.isfinite(h1))
print("LM RANK-LOSS OK", cc1.mode.value, [round(x, 4) for x in h1])
""")
    assert "LM RANK-LOSS OK" in out


# ----------------------------------------------------------------------
# End-to-end: preemption drain + fresh-process resume (subprocess x2)
# ----------------------------------------------------------------------

_TRAIN_COMMON = """
import dataclasses, json
import jax, numpy as np, jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.core.config import CommConfig
from repro.data.pipeline import DataConfig
from repro.launch import setup
from repro.optim import adamw
from repro.train import loop as loop_mod

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=jnp.float32)
oc = adamw.OptConfig(lr=1e-3, zero1=False)
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

def fresh_session():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return setup.build_session(cfg, mesh, CommConfig(), oc=oc)
"""


def test_preemption_drain_then_fresh_process_resumes(tmp_path):
    """guard.request() drains an emergency checkpoint (params + opt state)
    at the step boundary; a FRESH PROCESS resumes at the same step and the
    combined loss stream is bitwise-identical to the uninterrupted run."""
    ck = tmp_path / "ck"
    # phase 1: reference run + drained run, in one process
    run_multidevice(_TRAIN_COMMON + f"""
from repro.runtime.faults import FaultInjector, FaultSchedule

ref = loop_mod.train(fresh_session(), data,
                     loop_mod.LoopConfig(n_steps=8, ckpt_every=100,
                                         log_every=100),
                     log=lambda *_: None)

# Preempt@4 -> guard.request() -> the loop drains at the step-4 boundary
inj = FaultInjector(FaultSchedule.parse("preempt@4"))
part1 = loop_mod.train(fresh_session(), data,
                       loop_mod.LoopConfig(n_steps=8, ckpt_every=100,
                                           ckpt_dir={str(ck)!r},
                                           log_every=100),
                       log=lambda *_: None, faults=inj)
assert len(part1) == 4, len(part1)
from repro.checkpoint.checkpointer import Checkpointer
assert Checkpointer({str(ck)!r}).latest_step() == 4
json.dump({{"ref": ref, "part1": part1}},
          open({str(tmp_path / "phase1.json")!r}, "w"))
print("PHASE1 OK")
""", n_devices=1)
    # phase 2: a fresh process resumes from the drained checkpoint
    out = run_multidevice(_TRAIN_COMMON + f"""
from repro.runtime.fault_tolerance import resume_session

sess, start = resume_session({str(ck)!r}, fresh_session())
assert start == 4
part2 = loop_mod.train(sess, data,
                       loop_mod.LoopConfig(n_steps=4, ckpt_every=100,
                                           log_every=100),
                       log=lambda *_: None)
saved = json.load(open({str(tmp_path / "phase1.json")!r}))
resumed = saved["part1"] + part2
assert len(resumed) == len(saved["ref"]) == 8
# opt state rode the drain: the resumed stream is bitwise identical
assert resumed == saved["ref"], (resumed, saved["ref"])
print("RESUME OK", [round(x, 4) for x in part2])
""", n_devices=1)
    assert "RESUME OK" in out
