"""Virtual multi-hop torus transport: the collective-conformance harness.

Three layers of guarantees, mirroring ACCL+'s per-topology conformance
matrix:

1. **Topology math** (host-side): placements, hop distances, routes, and the
   translation perms of the hop-distance sweep axis — up to the paper's 48
   ranks (a 6x8 torus).
2. **Bitwise parity** (8 host devices): every perm-based collective
   (sendrecv, multi-neighbor exchange, ring all-reduce) plus all_to_all and
   the hierarchical all-reduce produce bit-identical values on a torus-placed
   communicator vs the flat mesh, across torus shapes x placements x
   transports x scheduling modes, with and without the plan cache.
3. **Per-edge selection** (deterministic model timer): a >= 3-hop-distance
   sweep records ``TuneEntry.hops`` per measured edge and makes
   ``select_config(hops=...)`` return *different* winners per edge — the
   jumbo-segment config wins the direct link, small segments win the routed
   edge (chunk wormholing) — and the SWE driver turns that into distinct
   per-round configs.
"""
import dataclasses

import numpy as np
import pytest

from helpers import run_multidevice


# ----------------------------------------------------------------------
# Topology math (host-side, up to 48 ranks)
# ----------------------------------------------------------------------

def test_torus_spec_parse_and_validation():
    from repro.core.topology import TorusSpec, snake_placement

    spec = TorusSpec.parse("4x4")
    assert spec.shape == (4, 4) and spec.n_ranks == 16
    assert spec.diameter == 4
    snake = TorusSpec.parse("2x4:snake")
    assert snake.placement == snake_placement((2, 4))
    assert snake.name == "2x4:snake" and spec.name == "4x4"
    with pytest.raises(ValueError):
        TorusSpec.parse("4by4")
    with pytest.raises(ValueError):
        TorusSpec.parse("4x4:spiral")
    with pytest.raises(ValueError):
        TorusSpec((2, 4), placement=(0, 1, 2, 3, 4, 5, 6, 6))
    with pytest.raises(ValueError):
        TorusSpec((0, 4))


def test_torus_hops_and_placement():
    from repro.core.topology import TorusSpec, snake_placement

    spec = TorusSpec((4, 4))
    assert spec.hops(0, 1) == 1
    assert spec.hops(0, 15) == 2          # wrap both dims
    assert spec.hops(0, 10) == 4          # (0,0)->(2,2)
    for a in range(16):
        for b in range(16):
            assert spec.hops(a, b) == spec.hops(b, a) <= spec.diameter

    # placement permutes which RANKS are close, not the torus itself
    snake = TorusSpec((2, 4), placement=snake_placement((2, 4)))
    ring = [(i, (i + 1) % 8) for i in range(8)]
    assert snake.max_hops(ring) == 1
    assert TorusSpec((2, 4)).max_hops(ring) == 2   # row-major wrap edges


def test_routes_are_minimal_and_valid_up_to_48_ranks():
    """Dimension-ordered routes: length == hop distance; the hop-distance
    translation perms schedule in ONE lockstep batch whose every sub-round
    is a valid ppermute (unique sources and destinations) — on the paper's
    48-rank torus."""
    from repro.core.topology import TorusSpec, route, route_rounds

    spec = TorusSpec((6, 8))           # 48 ranks
    rng = np.random.RandomState(3)
    for _ in range(50):
        a, b = rng.randint(0, 48, size=2)
        r = route(spec, int(a), int(b))
        assert r[0] == a and r[-1] == b
        assert len(r) == spec.hops(int(a), int(b)) + 1
        assert len(set(r)) == len(r)   # no revisits on a minimal route

    for d in range(1, spec.diameter + 1):
        perm = spec.hop_perm(d)
        assert all(spec.hops(s, t) == d for s, t in perm)
        rp = route_rounds(spec, perm)
        assert len(rp.batches) == 1 and rp.n_rounds == d
        for rnd in rp.batches[0].rounds:
            srcs = [s for s, _ in rnd]
            dsts = [t for _, t in rnd]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
        assert sorted(rp.batches[0].dests) == sorted(t for _, t in perm)


def test_route_rounds_batches_cover_irregular_patterns():
    """Irregular (RCB-style) edge lists split into conflict-free batches;
    every destination is delivered exactly once."""
    from repro.core.topology import TorusSpec, route_rounds

    spec = TorusSpec((2, 4))
    rng = np.random.RandomState(0)
    for _ in range(20):
        ranks = list(rng.permutation(8))
        k = int(rng.randint(2, 5))
        edges = list(zip(ranks[:k], ranks[k:2 * k]))
        edges = [(int(s), int(d)) for s, d in edges if s != d]
        if not edges:
            continue
        rp = route_rounds(spec, edges)
        assert sorted(d for b in rp.batches for d in b.dests) == \
            sorted(d for _, d in edges)
        for b in rp.batches:
            for rnd in b.rounds:
                srcs = [s for s, _ in rnd]
                dsts = [t for _, t in rnd]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)


def test_communicator_topology_integration():
    from repro.core.communicator import Communicator
    from repro.core.topology import RoutedPerm, TorusSpec, routed_perm

    spec = TorusSpec((2, 4))
    comm = Communicator(("x",), (8,), topo=spec)
    assert comm.torus_hops(0, 6) == spec.hops(0, 6)
    assert comm.hop_perm(2) == spec.hop_perm(2)
    # spec size must match the communicator
    with pytest.raises(ValueError):
        Communicator(("x",), (8,), topo=TorusSpec((4, 4)))
    with pytest.raises(ValueError):
        Communicator(("x",), (8,)).hop_perm(1)
    # direct edges stay plain perms; multi-hop edges get routed
    assert routed_perm(comm, [(0, 1)]) == ((0, 1),)
    assert isinstance(routed_perm(comm, [(0, 6)]), RoutedPerm)
    assert routed_perm(Communicator(("x",), (8,)), [(0, 6)]) == ((0, 6),)


def test_predicted_latency_monotone_in_hops():
    """Eq. 1 with the route term: every enumerable config's predicted
    latency strictly increases with hop count (the conformance matrix's
    model-side invariant)."""
    from repro.core import latmodel
    from repro.core.config import V5E
    from repro.tune.space import enumerate_configs

    for cfg in enumerate_configs(None):
        for msg in (1 << 10, 1 << 20):
            prev = None
            for h in range(1, 6):
                t = latmodel.pingping_latency(msg, cfg, V5E, hops=h)
                if prev is not None:
                    assert t > prev, (cfg, msg, h)
                prev = t
            # hops=1 must match the classic (pre-route-term) model shape:
            # streaming pipelining adds nothing at depth 1
            assert latmodel.pingping_latency(msg, cfg, V5E, hops=1) == \
                pytest.approx(latmodel.pingping_latency(msg, cfg, V5E))


def test_torus_hardware_spec_carries_hop_constants():
    from repro.core.config import V5E
    from repro.core.topology import TorusSpec

    spec = TorusSpec((4, 4), per_hop_ns=750.0, bisection_gbps=100.0)
    hw = spec.hardware(V5E)
    assert hw.ici_hop_latency == pytest.approx(750e-9)
    assert hw.ici_bw == pytest.approx(100e9 / 16)   # 4*min(shape) links
    assert hw.ici_bw < V5E.ici_bw


def test_calibration_fits_hop_term():
    """A multi-distance sweep resolves the per-hop constant; a
    single-distance sweep keeps the default."""
    from repro.core import latmodel
    from repro.core.config import CommConfig, CommMode, HardwareSpec
    from repro.tune.calibrate import CalibrationResult, fit_latency_model

    hw = HardwareSpec(host_dispatch=25e-6, fused_dispatch=0.8e-6,
                      ici_latency=1.5e-6, ici_hop_latency=2.0e-6,
                      ici_bw=40e9, hbm_bw=600e9)
    meas = []
    for mode in CommMode:
        for size in (1 << 10, 1 << 14, 1 << 17, 1 << 20):
            for hops in (1, 2, 4):
                cfg = CommConfig(mode=mode)
                meas.append((cfg, size,
                             latmodel.pingping_latency(size, cfg, hw,
                                                       hops=hops), hops))
    r = fit_latency_model(meas)
    assert r.hop_latency == pytest.approx(hw.ici_hop_latency, rel=0.2)
    cal = r.to_hardware_spec(hw)
    for cfg, size, sec, hops in meas:
        assert latmodel.pingping_latency(size, cfg, cal, hops=hops) == \
            pytest.approx(sec, rel=0.1)
    # single-distance: hop column untouched, default retained
    r1 = fit_latency_model([m[:3] for m in meas if m[3] == 1])
    assert r1.hop_latency == CalibrationResult.hop_latency
    # single distance > 1: the constant hop cost is collinear with l0 —
    # the fit must price it at the retained default, NOT absorb it into l0
    # and then re-add the default at prediction time (double count)
    m4 = [m for m in meas if m[3] == 4]
    r4 = fit_latency_model(m4)
    assert r4.hop_latency == CalibrationResult.hop_latency
    cal4 = r4.to_hardware_spec(hw)
    for cfg, size, sec, hops in m4:
        assert latmodel.pingping_latency(size, cfg, cal4, hops=hops) == \
            pytest.approx(sec, rel=0.1)


def test_flat_caller_prefers_flat_entries_over_torus_entries():
    """The torus filter works both ways: a flat-mesh caller (torus="")
    whose ring wrap edge happens to share a hop count with a routed torus
    measurement must not be answered by the store-and-forward-tuned config
    — and the torus caller keeps its own."""
    from repro.core.config import CommConfig
    from repro.tune.db import TuneDB, TuneEntry, select_config
    from repro.tune.space import config_to_dict

    flat_cfg = CommConfig(chunk_bytes=1 << 20)
    torus_cfg = CommConfig(chunk_bytes=1 << 14)
    db = TuneDB()
    db.add(TuneEntry(topo="cpu:9", collective="sendrecv", msg_bytes=1024,
                     config=config_to_dict(flat_cfg), us_per_call=10.0,
                     hops=1, torus=""))
    db.add(TuneEntry(topo="cpu:9", collective="sendrecv", msg_bytes=1024,
                     config=config_to_dict(torus_cfg), us_per_call=500.0,
                     hops=2, torus="3x3"))
    assert select_config("sendrecv", 1024, db=db, topo="cpu:9", hops=2,
                         torus="") == flat_cfg
    assert select_config("sendrecv", 1024, db=db, topo="cpu:9", hops=2,
                         torus="3x3") == torus_cfg


def test_auto_config_derives_hops_from_torus_spec():
    """PR 4 pinned that auto_config derives+passes ring hops; on a virtual
    torus the derivation must follow the SPEC's placement, not the flat
    factorization (regression for the multi-hop TorusSpec path)."""
    from repro.core.communicator import Communicator
    from repro.core.topology import TorusSpec, snake_placement
    import repro.tune

    seen = {}
    orig = repro.tune.select_config

    def spy(collective, msg_bytes, **kw):
        seen.update(kw)
        return orig(collective, msg_bytes, **kw)

    repro.tune.select_config = spy
    try:
        flat = Communicator(("data",), (8,))
        flat.auto_config("all_reduce", 1024)
        assert seen.get("hops") == 2       # row-major 2x4 wrap edges

        snake = flat.with_topology(
            TorusSpec((2, 4), placement=snake_placement((2, 4))))
        snake.auto_config("all_reduce", 1024)
        assert seen.get("hops") == 1       # hop-1 rank ring by placement

        tall = flat.with_topology(TorusSpec((1, 8)))
        tall.auto_config("all_reduce", 1024)
        assert seen.get("hops") == 1       # an 8-ring's steps are all direct
    finally:
        repro.tune.select_config = orig


def test_multi_neighbor_rejects_mixed_overlapped_round_cfgs():
    import jax.numpy as jnp
    from repro.core import collectives
    from repro.core.communicator import Communicator
    from repro.core.config import CommConfig, Scheduling

    comm = Communicator(("x",), (4,))
    over = CommConfig(scheduling=Scheduling.OVERLAPPED)
    rounds = [[(0, 1), (1, 0)], [(2, 3), (3, 2)]]
    payloads = [jnp.zeros((4,)), jnp.zeros((4,))]
    with pytest.raises(ValueError):
        collectives.multi_neighbor_exchange(
            payloads, rounds, comm,
            [over, dataclasses.replace(over, window=2)])
    with pytest.raises(ValueError):
        collectives.multi_neighbor_exchange(payloads, rounds, comm, [over])


# ----------------------------------------------------------------------
# Hop-distance sweep -> per-edge winners (deterministic model timer)
# ----------------------------------------------------------------------

class _FakeDev:
    platform = "cpu"


class _FakeDevs:
    def __init__(self, n):
        self.shape = (n,)
        self.size = n
        self.flat = [_FakeDev()] * n


class _FakeMesh:
    """Just enough mesh surface for run_sweep with an injected timer (no
    program is ever built, so no real devices are needed)."""

    def __init__(self, n):
        self.axis_names = ("x",)
        self.devices = _FakeDevs(n)
        self.shape = {"x": n}


def _model_timer(hw):
    from repro.core import latmodel

    def timer(op, mesh, msg_bytes, cfg, cache_key=None, **kw):
        hop_d = (cache_key[3] or 1) if cache_key else 1
        return latmodel.pingping_latency(msg_bytes, cfg, hw, hops=hop_d)

    return timer


def _hop_hw():
    from repro.core.config import HardwareSpec
    return HardwareSpec(host_dispatch=30e-6, fused_dispatch=0.5e-6,
                        ici_latency=1e-6, ici_hop_latency=0.5e-6,
                        ici_bw=50e9)


def test_hop_sweep_yields_per_edge_winners():
    """The acceptance matrix's selection arm: a sweep over >= 3 hop
    distances on a virtual torus records ``TuneEntry.hops`` per measured
    edge, and ``select_config(hops=...)`` returns DIFFERENT winners for at
    least one edge pair — the jumbo segment wins the direct link (fewest
    scheduled commands), small segments win the routed edge (chunk
    wormholing across hops)."""
    from repro.core.topology import TorusSpec
    from repro.tune import TuneDB, select_config
    from repro.tune.sweep import run_sweep

    spec = TorusSpec((2, 4))
    db = run_sweep(mesh=_FakeMesh(8), collectives=("sendrecv",),
                   sizes=(1 << 20,), fast=True, topology=spec,
                   hop_distances=(1, 2, 3), timer=_model_timer(_hop_hw()))
    assert sorted({e.hops for e in db.entries}) == [1, 2, 3]
    assert all(e.torus == "2x4" for e in db.entries)
    topo = db.entries[0].topo

    winners = {h: select_config("sendrecv", 1 << 20, db=db, topo=topo,
                                hops=h) for h in (1, 2, 3)}
    assert winners[1] != winners[3], "hop distance must change the winner"
    assert winners[1].chunk_bytes > winners[3].chunk_bytes
    # the per-edge answer survives the JSON round-trip (hops + torus fields)
    import json
    payload = json.loads(json.dumps(
        {"h": [dataclasses.asdict(e) for e in db.entries]}))
    from repro.tune.db import TuneEntry
    back = TuneDB([TuneEntry(**e) for e in payload["h"]])
    for h, cfg in winners.items():
        assert select_config("sendrecv", 1 << 20, db=back, topo=topo,
                             hops=h) == cfg


def test_hop_sweep_prunes_at_measured_distance():
    """Model-guided pruning prices candidates at the hop distance the sweep
    is about to measure them at: the candidate kept at 3 hops differs from
    the 1-hop incumbent's shadow."""
    from repro.core.config import CommConfig
    from repro.tune.calibrate import fit_latency_model
    from repro.core import latmodel
    from repro.tune.prune import prune_candidates

    hw = _hop_hw()
    meas = []
    for size in (1 << 14, 1 << 20):
        for hops in (1, 2, 3):
            for cfg in (CommConfig(), CommConfig(chunk_bytes=1 << 16)):
                meas.append((cfg, size,
                             latmodel.pingping_latency(size, cfg, hw,
                                                       hops=hops), hops))
    cal = fit_latency_model(meas)
    jumbo = CommConfig(chunk_bytes=1 << 20)
    small = CommConfig(chunk_bytes=1 << 16)
    kept1, skipped1 = prune_candidates([jumbo, small], 1 << 20, cal,
                                       ratio=1.2, collective="sendrecv",
                                       hops=1)
    kept3, skipped3 = prune_candidates([jumbo, small], 1 << 20, cal,
                                       ratio=1.2, collective="sendrecv",
                                       hops=3)
    assert jumbo in kept1 and small in skipped1
    assert small in kept3 and jumbo in skipped3


def test_hop_distances_validation():
    from repro.core.topology import TorusSpec
    from repro.tune.sweep import run_sweep

    with pytest.raises(ValueError):
        run_sweep(mesh=_FakeMesh(8), collectives=("sendrecv",),
                  sizes=(1024,), hop_distances=(1, 2),
                  timer=_model_timer(_hop_hw()))
    with pytest.raises(ValueError):
        run_sweep(mesh=_FakeMesh(8), collectives=("sendrecv",),
                  sizes=(1024,), topology=TorusSpec((2, 4)),
                  hop_distances=(0, 9), timer=_model_timer(_hop_hw()))


def test_driver_selects_distinct_per_round_configs(tmp_path):
    """The SWE driver's per-edge selection: rounds at different hop
    distances get different autotuned configs (unit-level — the live-mesh
    version runs in the conformance subprocess)."""
    from repro.core.communicator import Communicator
    from repro.core.config import CommConfig
    from repro.core.topology import TorusSpec
    from repro.swe.driver import _select_round_configs
    from repro.tune.db import TuneDB, TuneEntry, topology_key
    from repro.tune.space import config_to_dict

    topo = topology_key(n_devices=8)
    jumbo, small = CommConfig(chunk_bytes=1 << 20), CommConfig(chunk_bytes=1 << 16)
    db = TuneDB()
    for msg in (1024, 1 << 16):
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(jumbo),
                         us_per_call=10.0, hops=1))
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(small),
                         us_per_call=12.0, hops=1))
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(jumbo),
                         us_per_call=40.0, hops=2))
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(small),
                         us_per_call=20.0, hops=2))
    path = tmp_path / "tunedb.json"
    db.save(path)

    comm = Communicator(("data",), (8,), topo=TorusSpec((2, 4)))
    rounds = [[(0, 1), (1, 0)],            # direct links
              [(0, 6), (6, 0)]]            # 2-hop routed edges
    cfgs = _select_round_configs(rounds, comm, 1024, tune_db_path=path)
    assert cfgs[0].chunk_bytes == 1 << 20
    assert cfgs[1].chunk_bytes == 1 << 16
    assert len(set(cfgs)) == 2


# ----------------------------------------------------------------------
# Bitwise parity: torus vs flat, across the conformance matrix
# ----------------------------------------------------------------------

def test_torus_parity_matrix_perm_collectives():
    """sendrecv, the multi-neighbor exchange, and the ring all-reduce are
    bit-identical on torus-placed communicators vs the flat mesh over torus
    shapes x placements x (mode, scheduling, transport)."""
    out = run_multidevice("""
import dataclasses
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, CommMode, Scheduling, Transport
from repro.core.topology import TorusSpec, snake_placement

mesh = compat.make_mesh((8,), ("x",))
x = np.random.RandomState(0).randn(8, 66).astype(np.float32)

def run_all(comm, cfg):
    results = []
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def p2p(xs):
        return collectives.sendrecv(
            xs[0], [(i, (i + 3) % 8) for i in range(8)], comm, cfg)[None]
    results.append(np.asarray(p2p(x)))
    rounds = [comm.ring_perm(1), comm.reverse_ring_perm(1), comm.ring_perm(2)]
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def mn(xs):
        outs = collectives.multi_neighbor_exchange(
            [xs[0]] * len(rounds), rounds, comm, cfg)
        return sum(outs)[None]
    results.append(np.asarray(mn(x)))
    rcfg = dataclasses.replace(cfg, algorithm="ring")
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def ar(xs):
        return collectives.all_reduce(xs[0], comm, rcfg)[None]
    results.append(np.asarray(ar(x)))
    return results

shuffled = (3, 6, 0, 5, 2, 7, 1, 4)
# HOST scheduling lowers the same per-op programs as FUSED (dispatch
# granularity is a caller concern), so a latin square over mode x
# scheduling x transport covers every distinct traced path: both modes
# under both schedulings, both transports under both modes.  The identity
# placement runs the full square; the other placements (snake, shuffled,
# transposed shape) run the two most distinct corners — routing is
# placement-independent code, so the cross-check needs breadth, not the
# full product per placement (keeps the tier-1 matrix affordable).
FULL = [CommConfig(mode=m, scheduling=s, transport=t, chunk_bytes=512,
                   window=2)
        for m, s, t in (
            (CommMode.STREAMING, Scheduling.FUSED, Transport.UNORDERED),
            (CommMode.STREAMING, Scheduling.OVERLAPPED, Transport.ORDERED),
            (CommMode.BUFFERED, Scheduling.FUSED, Transport.ORDERED),
            (CommMode.BUFFERED, Scheduling.OVERLAPPED, Transport.UNORDERED))]
SPECS = [(TorusSpec((2, 4)), FULL),
         (TorusSpec((2, 4), placement=snake_placement((2, 4))), FULL[:2]),
         (TorusSpec((4, 2), placement=shuffled), FULL[1:3])]

flat = Communicator.from_mesh(mesh, "x")
refs = {id(cfg): run_all(flat, cfg) for cfg in FULL}
for spec, cfgs in SPECS:
    for cfg in cfgs:
        got = run_all(flat.with_topology(spec), cfg)
        for i, (r, g) in enumerate(zip(refs[id(cfg)], got)):
            assert r.tobytes() == g.tobytes(), (spec.name, cfg, i)
print("TORUS PARITY MATRIX OK")
""", timeout=900)
    assert "TORUS PARITY MATRIX OK" in out


def test_torus_parity_a2a_hierarchical_and_cache_bypass():
    """all_to_all and the hierarchical all-reduce under a torus spec, plus
    the plan-cache arm: REPRO_PLAN_CACHE=0 stays bitwise-identical under
    the torus transport (routing is re-derived, never re-valued)."""
    out = run_multidevice("""
import os
import dataclasses
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, plans
from repro.core.communicator import Communicator
from repro.core.config import CommConfig, CommMode, Scheduling, Transport
from repro.core.topology import TorusSpec

mesh = compat.make_mesh((8,), ("x",))
x = np.random.RandomState(1).randn(8, 64).astype(np.float32)

flat = Communicator.from_mesh(mesh, "x")
spec = TorusSpec((2, 4))
torus = flat.with_topology(spec)

def a2a(comm, cfg):
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def f(xs):
        return collectives.all_to_all(
            xs[0].reshape(8, 8), comm, cfg).reshape(1, 64)
    return np.asarray(f(x))

for cfg in (CommConfig(),
            CommConfig(scheduling=Scheduling.OVERLAPPED, chunk_bytes=512),
            CommConfig(mode=CommMode.BUFFERED)):
    assert a2a(flat, cfg).tobytes() == a2a(torus, cfg).tobytes(), cfg

# hierarchical: 2-axis mesh, inner communicator placed on a 2x2 torus
mesh2 = compat.make_mesh((4, 2), ("inner", "outer"))
inner_flat = Communicator.from_mesh(mesh2, "inner")
inner_torus = inner_flat.with_topology(TorusSpec((2, 2)))
outer = Communicator.from_mesh(mesh2, "outer")
x2 = np.random.RandomState(2).randn(8, 48).astype(np.float32)

def hier(inner, cfg):
    @partial(compat.shard_map, mesh=mesh2,
             in_specs=P(("inner", "outer")), out_specs=P(("inner", "outer")),
             check_vma=False)
    def f(xs):
        return collectives.hierarchical_all_reduce(
            xs[0], inner, outer, cfg)[None]
    return np.asarray(f(x2))

for cfg in (CommConfig(algorithm="ring", chunk_bytes=512), CommConfig()):
    assert hier(inner_flat, cfg).tobytes() == hier(inner_torus, cfg).tobytes()

# plan-cache bypass parity under the torus transport
def perm_ops(cfg):
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def p2p(xs):
        return collectives.sendrecv(
            xs[0], [(i, (i + 3) % 8) for i in range(8)], torus, cfg)[None]
    rounds = [torus.ring_perm(1), torus.ring_perm(2)]
    @partial(compat.shard_map, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
             check_vma=False)
    def mn(xs):
        outs = collectives.multi_neighbor_exchange(
            [xs[0]] * 2, rounds, torus, cfg)
        return sum(outs)[None]
    return [np.asarray(p2p(x)), np.asarray(mn(x))]

for cfg in (CommConfig(chunk_bytes=512, transport=Transport.ORDERED,
                       window=2),
            CommConfig(scheduling=Scheduling.OVERLAPPED, chunk_bytes=512)):
    os.environ.pop("REPRO_PLAN_CACHE", None)
    plans.clear_cache(); plans.reset_stats()
    cached = perm_ops(cfg)
    assert plans.cache_stats()["plan_hits"] > 0
    os.environ["REPRO_PLAN_CACHE"] = "0"
    plans.clear_cache()
    bypassed = perm_ops(cfg)
    os.environ.pop("REPRO_PLAN_CACHE", None)
    for a, b in zip(cached, bypassed):
        assert a.tobytes() == b.tobytes(), cfg
print("TORUS A2A/HIER/BYPASS OK")
""", timeout=540)
    assert "TORUS A2A/HIER/BYPASS OK" in out


def test_measured_latency_grows_with_hop_distance():
    """The physical arm of the emulation: a real (wall-clock) hop-distance
    sweep measures a 3-hop translation strictly slower than the direct link
    — each extra hop is one more executed permute of the full payload."""
    out = run_multidevice("""
from repro import compat
from repro.core.config import OPTIMIZED_CONFIG
from repro.core.communicator import Communicator
from repro.core.topology import TorusSpec
from repro.tune.sweep import _build_op, _time_program

mesh = compat.make_mesh((8,), ("x",))
comm = Communicator.from_mesh(mesh, "x", topo=TorusSpec((2, 4)))
# One device-scheduled streaming config (dispatch amortized over the
# compiled loop): the timing is dominated by the permutes themselves, and
# the 3-hop translation executes 3x the permutes of the direct link.
# 4 MiB payload: the host backend's fixed per-collective cost (~4 ms)
# would otherwise swamp the per-hop bandwidth term on a loaded machine.
cfg = OPTIMIZED_CONFIG
times = {}
for d in (1, 3):
    op = _build_op("sendrecv", comm, cfg, hop_distance=d)
    times[d] = _time_program(op, mesh, 1 << 22, cfg, reps=3, inner=8)
ratio = times[3] / times[1]
assert ratio > 1.1, (times, "3-hop routing should cost measurably more")
print("MEASURED HOP SCALING OK", round(ratio, 2))
""", timeout=540)
    assert "MEASURED HOP SCALING OK" in out


def test_swe_driver_on_torus_matches_flat_and_selects_per_edge():
    """Live-mesh conformance of the SWE step on a virtual torus: per-edge
    auto-selection picks distinct round configs from a hop-split TuneDB,
    and the torus simulation stays bitwise-identical to the flat mesh under
    both the serial and the overlapped schedule."""
    out = run_multidevice("""
import numpy as np, jax, dataclasses, tempfile
from repro import compat
from repro.core.config import CommConfig, Scheduling
from repro.core.communicator import Communicator
from repro.core.topology import TorusSpec
from repro.swe import driver
from repro.tune.db import TuneDB, TuneEntry, topology_key
from repro.tune.space import config_to_dict

db = TuneDB()
topo = topology_key(n_devices=8)
jumbo = CommConfig(chunk_bytes=1 << 20)
small = CommConfig(chunk_bytes=1 << 16)
for msg in (1024, 1 << 16):
    for cfg, us1, us2 in ((jumbo, 10.0, 40.0), (small, 12.0, 20.0)):
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(cfg),
                         us_per_call=us1, hops=1))
        db.add(TuneEntry(topo=topo, collective="multi_neighbor",
                         msg_bytes=msg, config=config_to_dict(cfg),
                         us_per_call=us2, hops=2))
path = tempfile.mktemp(suffix=".json"); db.save(path)

dmesh = compat.make_mesh((8,), ("data",))
spec = TorusSpec((2, 4))
sim = driver.build_simulation(400, dmesh, "auto", tune_db_path=path,
                              topology=spec)
comm = Communicator(("data",), (8,), topo=spec)
round_hops = [comm.max_hops(r) for r in sim.pm.rounds]
if len(set(round_hops)) > 1:
    assert sim.round_cfgs is not None, round_hops
    assert len({c.chunk_bytes for c in sim.round_cfgs}) > 1, \
        [c.chunk_bytes for c in sim.round_cfgs]

s_torus = np.asarray(jax.block_until_ready(
    driver.make_sim_runner(sim, 5)(sim.state, 0.0)))
flat = driver.build_simulation(400, dmesh, sim.comm_cfg)
s_flat = np.asarray(jax.block_until_ready(
    driver.make_sim_runner(flat, 5)(flat.state, 0.0)))
assert s_torus.tobytes() == s_flat.tobytes()

ov = dataclasses.replace(sim.comm_cfg, scheduling=Scheduling.OVERLAPPED)
sim_ov = driver.build_simulation(400, dmesh, ov, topology=spec)
s_ov = np.asarray(jax.block_until_ready(
    driver.make_sim_runner(sim_ov, 5)(sim_ov.state, 0.0)))
assert s_ov.tobytes() == s_flat.tobytes()
print("SWE TORUS CONFORMANCE OK", round_hops)
""", timeout=540)
    assert "SWE TORUS CONFORMANCE OK" in out
